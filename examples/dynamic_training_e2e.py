"""End-to-end driver: train the paper's ~110M-parameter Bert-base trunk
for a few hundred steps under a memory budget, with dynamic input sizes.

This is the full-size counterpart of quickstart.py — the exact model the
paper evaluates (12 encoders, d=768, 110M params).  On this CPU container
a step takes a few seconds; pass --steps to shorten.

    PYTHONPATH=src python examples/dynamic_training_e2e.py --steps 200
"""
import argparse
import time

import jax
import numpy as np

from repro.core import MimosePlanner
from repro.data.pipeline import make_batches
from repro.models.lm import build_model
from repro.models.registry import get_config
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train import checkpoint as ckpt
from repro.train.trainer import Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch-size", type=int, default=4)
ap.add_argument("--budget-gb", type=float, default=2.5)
ap.add_argument("--save", default="/tmp/bert_base_mimose.msgpack")
args = ap.parse_args()

cfg = get_config("bert_base_paper")          # full 110M config
lm = build_model(cfg)
params = lm.init(jax.random.PRNGKey(0))
n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
print(f"training {cfg.name}: {n / 1e6:.0f}M params, "
      f"{cfg.num_layers} encoders, budget {args.budget_gb} GB")

planner = MimosePlanner(lm, args.budget_gb * 2**30, warmup_samples=3,
                        quantum=64)
opt = AdamW(lr=cosine_schedule(1e-4, 20, args.steps))
trainer = Trainer(lm, planner, opt)
opt_state = opt.init(params)

t0 = time.time()
for i, batch in enumerate(make_batches(
        "qqp", batch_size=args.batch_size, vocab_size=cfg.vocab_size,
        num_batches=args.steps, quantum=64, seed=0)):
    params, opt_state, loss = trainer.step(params, opt_state, batch)
    if i % 10 == 0:
        st = trainer.history[-1]
        print(f"step {i:4d}  loss {loss:7.4f}  S={batch['tokens'].shape[1]:4d}"
              f"  remat {st.remat_units:2d}/12  {st.step_time_s:6.2f}s"
              f"  plan {1e3 * st.plan_time_s:7.2f}ms")

print(f"\n{args.steps} steps in {(time.time() - t0) / 60:.1f} min")
print("summary:", trainer.summary())
print("planner:", planner.stats)
ckpt.save(args.save, params)
print("checkpoint written to", args.save)
