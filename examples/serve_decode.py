"""Serving example: continuous batching by default, classic batched
prefill+decode with ``--sequential``.

    PYTHONPATH=src python examples/serve_decode.py --arch qwen3-1.7b
    PYTHONPATH=src python examples/serve_decode.py --arch mamba2-1.3b
    PYTHONPATH=src python examples/serve_decode.py --sequential

The default path drives ``repro.train.engine.ServeEngine`` with a
concurrent open-loop trace (Poisson arrivals): requests of different
lengths share bucketed cache pools, admission is input-aware under
``--hbm-gb``, and the report shows tokens/s, latency percentiles, and
the compile audit.  ``--sequential`` keeps the old one-request-batch
``generate`` path for comparison at the same budget.
"""
import argparse
import time

import jax
import numpy as np

from repro.data.trace import gen_trace
from repro.launch.report import serve_report
from repro.models.lm import build_model
from repro.models.registry import get_config
from repro.train.engine import ServeEngine
from repro.train.serve import cached_serve_step, generate

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-1.7b")
ap.add_argument("--sequential", action="store_true",
                help="old path: one batched generate(), no engine")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=16)
ap.add_argument("--new-tokens", type=int, default=32)
ap.add_argument("--num-requests", type=int, default=12)
ap.add_argument("--rate-rps", type=float, default=16.0)
ap.add_argument("--hbm-gb", type=float, default=0.5)
args = ap.parse_args()

cfg = get_config(args.arch).reduced(num_layers=4, d_model=256,
                                    dtype="float32")
lm = build_model(cfg)
params = lm.init(jax.random.PRNGKey(0))
print(f"serving {cfg.name} (reduced: {cfg.num_layers}L d={cfg.d_model}, "
      f"family={cfg.family})")

if args.sequential:
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 1,
                                cfg.vocab_size)
    t0 = time.time()
    out = generate(lm, params, prompt, args.new_tokens, temperature=0.8)
    dt = time.time() - t0
    total = args.batch * args.new_tokens
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({total / dt:.1f} tok/s incl. prefill + compile)")

    # steady-state decode rate on the shared compiled step
    step = cached_serve_step(lm)
    cache = lm.init_cache(args.batch, args.prompt_len + args.new_tokens + 8)
    tok = prompt[:, :1]
    logits, cache = step(params, tok, cache, 0)   # compile
    t0 = time.time()
    N = 20
    for i in range(N):
        logits, cache = step(params, tok, cache, i + 1)
    logits.block_until_ready()
    print(f"steady-state decode: {1e3 * (time.time() - t0) / N:.1f} ms/step "
          f"({args.batch * N / (time.time() - t0):.1f} tok/s)")
    print("sample tokens:", out[0, :16].tolist())
else:
    trace = gen_trace(num_requests=args.num_requests,
                      vocab_size=cfg.vocab_size, rate_rps=args.rate_rps,
                      max_new_tokens=args.new_tokens, prompt_scale=0.25,
                      seed=1)
    lens = [len(r.prompt) for r in trace]
    print(f"trace: {len(trace)} concurrent requests, prompt lens "
          f"{min(lens)}..{max(lens)}")
    engine = ServeEngine(lm, params, hbm_bytes=args.hbm_gb * 1e9,
                         quantum=64, max_slots=4)
    result = engine.run(trace)
    print(serve_report(engine, result))
    rid = trace[0].rid
    print("sample tokens (rid 0):",
          np.asarray(result.outputs.get(rid, []))[:16].tolist())
