"""Batched serving example: prefill a prompt batch, then decode with the
per-family cache (attention KV / SSM state / hybrid both).

    PYTHONPATH=src python examples/serve_decode.py --arch qwen3-1.7b
    PYTHONPATH=src python examples/serve_decode.py --arch mamba2-1.3b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.models.lm import build_model
from repro.models.registry import get_config
from repro.train.serve import generate, make_serve_step

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-1.7b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=16)
ap.add_argument("--new-tokens", type=int, default=32)
args = ap.parse_args()

cfg = get_config(args.arch).reduced(num_layers=4, d_model=256,
                                    dtype="float32")
lm = build_model(cfg)
params = lm.init(jax.random.PRNGKey(0))
print(f"serving {cfg.name} (reduced: {cfg.num_layers}L d={cfg.d_model}, "
      f"family={cfg.family})")

prompt = jax.random.randint(jax.random.PRNGKey(1),
                            (args.batch, args.prompt_len), 1, cfg.vocab_size)
t0 = time.time()
out = generate(lm, params, prompt, args.new_tokens, temperature=0.8)
dt = time.time() - t0
total = args.batch * args.new_tokens
print(f"generated {out.shape} in {dt:.2f}s "
      f"({total / dt:.1f} tok/s incl. prefill + compile)")

# steady-state decode rate
step = jax.jit(make_serve_step(lm))
cache = lm.init_cache(args.batch, args.prompt_len + args.new_tokens + 8)
tok = prompt[:, :1]
logits, cache = step(params, tok, cache, 0)   # compile
t0 = time.time()
N = 20
for i in range(N):
    logits, cache = step(params, tok, cache, i + 1)
logits.block_until_ready()
print(f"steady-state decode: {1e3 * (time.time() - t0) / N:.1f} ms/step "
      f"({args.batch * N / (time.time() - t0):.1f} tok/s)")
print("sample tokens:", out[0, :16].tolist())
