"""Quickstart: train a small causal LM under a memory budget with the
input-aware Mimose planner.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import MimosePlanner, ShuttlingCollector
from repro.core.planner import fixed_train_bytes
from repro.data.pipeline import make_batches
from repro.models.lm import build_model
from repro.models.registry import get_config
from repro.optim.adamw import AdamW
from repro.train.trainer import Trainer

# 1. a model (the paper's Bert-base trunk, reduced for CPU)
cfg = get_config("bert_base_paper").reduced(
    num_layers=6, d_model=192, d_ff=384, vocab_size=512)
lm = build_model(cfg)
params = lm.init(jax.random.PRNGKey(0))

# 2. a memory budget: fixed state + 50% of the peak activation footprint
fixed = fixed_train_bytes(params)
probe = {"tokens": jnp.ones((8, 160), jnp.int32)}
acts = ShuttlingCollector(lm).collect(params, probe).total_activation_bytes()
budget = fixed + acts // 2
print(f"budget: {budget / 2**20:.0f} MiB "
      f"(fixed {fixed / 2**20:.0f} + 50% of {acts / 2**20:.0f} activation)")

# 3. the input-aware planner + trainer
planner = MimosePlanner(lm, budget, warmup_samples=3, quantum=32)
trainer = Trainer(lm, planner, AdamW(lr=1e-3))

# 4. train on dynamically-sized batches (SWAG length distribution)
opt_state = trainer.optimizer.init(params)
for batch in make_batches("swag", batch_size=8, vocab_size=cfg.vocab_size,
                          num_batches=30, quantum=32, seed=0):
    params, opt_state, loss = trainer.step(params, opt_state, batch)
    st = trainer.history[-1]
    print(f"S={batch['tokens'].shape[1]:4d} loss={loss:6.3f} "
          f"remat={st.remat_units}/{lm.num_plan_units()} "
          f"plan={1e3 * st.plan_time_s:6.2f} ms")

print("\nsummary:", trainer.summary())
print("planner stats:", planner.stats)
print(f"plans generated: {len(planner.cache)} "
      f"(cache hits: {planner.stats['cache_hits']})")
