"""Differential tests for the optimal-plan solver tier.

Every planner tier is pinned against ``tests/oracle.py`` — a brute
force over ALL ``3^n × k`` plans that owes nothing to the solver's
internals (independent ``itertools`` walk, scalar simulator replay):

* ``solve() == oracle()`` on randomized small instances, for BOTH the
  exhaustive fallback and the chain DP;
* ``solve() <= greedy()`` always, including on large instances where
  only the DP runs;
* feasibility (and the optimum itself) is monotone in the budget;
* ``BackgroundSolver``'s cache swap is atomic under a concurrent
  trainer loop, recompiles at most the bucket it replaces, and drops
  stale solves when the cache entry was invalidated underneath it.

The randomized accum/pad knobs are threaded IDENTICALLY to the planner
calls and the simulator replays — the two default differently
(``MICROBATCH_OVERHEAD_S`` vs 0), and letting them diverge turns every
comparison into noise.

Marked ``solver`` (own CI job — the oracle enumeration is slow);
hypothesis draws are seeded + deadline-disabled for CI stability.
"""
import math
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from oracle import oracle
from repro.core import MimosePlanner, simulate, solve
from repro.core.scheduler import Plan, greedy_plan_adaptive
from repro.core.solver import SolveResult, enumerate_plans
from repro.actions import Action

pytestmark = pytest.mark.solver


# ---------------------------------------------------------------------------
# randomized instances
# ---------------------------------------------------------------------------
def _instance(draw, n_min, n_max):
    """One randomized planning instance: byte vectors, flops, roofline
    constants, budget, and per-k pad overheads."""
    n = draw(st.integers(min_value=n_min, max_value=n_max))
    f = st.floats(min_value=0.0, max_value=1.0,
                  allow_nan=False, allow_infinity=False)
    act = [1.0 + 99.0 * draw(f) for _ in range(n)]
    out = [30.0 * draw(f) for _ in range(n)]
    off = [120.0 * draw(f) for _ in range(n)]
    fl = [1e12 * draw(f) for _ in range(n)]
    fixed = 50.0 * draw(f)
    # from hopeless through roomy — both feasibility regimes covered
    budget = fixed + (0.05 + 1.2 * draw(f)) * (sum(act) + sum(out) + 1.0)
    pcie = 1e9 + 31e9 * draw(f)
    overlap = draw(f)
    accum = 1e-3 * draw(f)
    pads = {1: 0.0, 2: 2e-5 * draw(f), 3: 3e-5 * draw(f),
            4: 4e-5 * draw(f)}

    def vectors_of_k(k):
        sc = 1.0 / k
        return {"est_mem": np.array(act) * sc,
                "output_bytes": np.array(out) * sc,
                "offload_bytes": np.array(off) * sc,
                "flops": np.array(fl) * sc,
                "pad_overhead_s": pads[k]}

    return {"vok": vectors_of_k, "budget": budget, "fixed": fixed,
            "pcie": pcie, "overlap": overlap, "accum": accum, "n": n}


small_instances = st.composite(lambda draw: _instance(draw, 0, 5))()
large_instances = st.composite(lambda draw: _instance(draw, 10, 28))()


def _solve(inst, **kw):
    kw.setdefault("candidate_ks", [1, 2, 3])
    return solve(inst["vok"], inst["budget"], inst["fixed"],
                 pcie_bytes_per_s=inst["pcie"],
                 offload_overlap=inst["overlap"],
                 accum_overhead_s=inst["accum"], **kw)


def _replay(inst, plan):
    v = inst["vok"](plan.microbatch)
    sim = simulate(v["est_mem"], plan.actions, inst["fixed"],
                   v["output_bytes"], v["flops"],
                   offload_bytes=v["offload_bytes"],
                   pcie_bytes_per_s=inst["pcie"],
                   overlap=inst["overlap"], microbatch=plan.microbatch,
                   accum_overhead_s=inst["accum"])
    return sim, sim.step_overhead_s + v["pad_overhead_s"]


# ---------------------------------------------------------------------------
# solve == oracle (small n), both methods
# ---------------------------------------------------------------------------
@given(small_instances)
@settings(max_examples=15, deadline=None)
def test_solve_matches_oracle_small_n(inst):
    truth = oracle(inst["vok"], inst["budget"], inst["fixed"],
                   candidate_ks=[1, 2, 3],
                   pcie_bytes_per_s=inst["pcie"],
                   offload_overlap=inst["overlap"],
                   accum_overhead_s=inst["accum"])
    for method in ("exhaustive", "dp"):
        res = _solve(inst, method=method)
        assert res.feasible == truth.feasible, (method, inst["n"])
        if truth.feasible:
            assert math.isclose(res.score, truth.score,
                                rel_tol=1e-9, abs_tol=1e-12), \
                (method, inst["n"], res.score, truth.score)


@given(small_instances)
@settings(max_examples=10, deadline=None)
def test_solve_never_worse_than_greedy_small_n(inst):
    greedy = greedy_plan_adaptive(inst["vok"], inst["budget"],
                                  inst["fixed"], candidate_ks=[1, 2, 3],
                                  pcie_bytes_per_s=inst["pcie"],
                                  offload_overlap=inst["overlap"],
                                  accum_overhead_s=inst["accum"])
    gsim, gscore = _replay(inst, greedy)
    res = _solve(inst)
    if gsim.peak_bytes <= inst["budget"] + 1e-6:
        assert res.feasible
        assert res.score <= gscore + 1e-12


@given(large_instances)
@settings(max_examples=10, deadline=None)
def test_solve_never_worse_than_greedy_large_n(inst):
    """Only the DP runs at this size — exact while the Pareto frontier
    fits, conservatively grid-quantised beyond; either way the greedy
    candidate competes, so <= holds unconditionally."""
    greedy = greedy_plan_adaptive(inst["vok"], inst["budget"],
                                  inst["fixed"], candidate_ks=[1, 2],
                                  pcie_bytes_per_s=inst["pcie"],
                                  offload_overlap=inst["overlap"],
                                  accum_overhead_s=inst["accum"])
    gsim, gscore = _replay(inst, greedy)
    res = _solve(inst, candidate_ks=[1, 2], method="dp")
    if gsim.peak_bytes <= inst["budget"] + 1e-6:
        assert res.feasible
        assert res.score <= gscore + 1e-12


# ---------------------------------------------------------------------------
# monotonicity in budget
# ---------------------------------------------------------------------------
@given(small_instances)
@settings(max_examples=10, deadline=None)
def test_feasibility_and_score_monotone_in_budget(inst):
    """A bigger budget can only grow the feasible set: feasibility is
    monotone and the optimal score never increases."""
    prev_feasible, prev_score = False, float("inf")
    for mult in (0.25, 0.5, 1.0, 2.0, 4.0):
        budget = inst["fixed"] + mult * (inst["budget"] - inst["fixed"])
        res = solve(inst["vok"], budget, inst["fixed"],
                    candidate_ks=[1, 2, 3],
                    pcie_bytes_per_s=inst["pcie"],
                    offload_overlap=inst["overlap"],
                    accum_overhead_s=inst["accum"])
        if prev_feasible:
            assert res.feasible, f"feasible at smaller budget, not {mult}x"
            assert res.score <= prev_score + 1e-12
        if res.feasible:
            prev_feasible, prev_score = True, res.score


# ---------------------------------------------------------------------------
# solver internals
# ---------------------------------------------------------------------------
def test_enumerate_plans_covers_all_rows():
    A = enumerate_plans(3)
    assert A.shape == (27, 3)
    assert len({tuple(r) for r in A.tolist()}) == 27
    assert enumerate_plans(0).shape == (1, 0)
    with pytest.raises(ValueError):
        enumerate_plans(13)


def test_solve_timeout_returns_best_so_far():
    inst = {"vok": lambda k: {"est_mem": np.full(6, 10.0) / k},
            "budget": 100.0, "fixed": 0.0, "pcie": 16e9,
            "overlap": 0.5, "accum": 0.0}
    res = _solve(inst, deadline_s=1e-9)
    # the greedy candidate is evaluated before the deadline gate, so a
    # timed-out solve still returns a plan — never worse than greedy
    assert res.timed_out
    assert res.plan is not None and res.feasible


def test_solve_reports_infeasible_min_peak():
    vok = lambda k: {"est_mem": np.full(4, 100.0) / k}  # noqa: E731
    res = solve(vok, 1.0, 50.0, candidate_ks=[1])
    assert not res.feasible
    assert res.plan is not None
    assert res.peak_bytes > 1.0


# ---------------------------------------------------------------------------
# BackgroundSolver: swap protocol against a live planner + trainer
# ---------------------------------------------------------------------------
HBM = 1e12


@pytest.fixture(scope="module")
def solver_setup():
    import jax
    from repro.models.lm import build_model
    from repro.models.registry import get_config
    cfg = get_config("bert_base_paper").reduced(
        num_layers=2, d_model=64, d_ff=128, vocab_size=256,
        dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


def _copy(params):
    """Fresh buffers per test — the jitted step donates its inputs, so
    reusing the module-scoped params would hand later tests deleted
    arrays."""
    import jax
    import jax.numpy as jnp
    return jax.tree_util.tree_map(jnp.copy, params)


def _batch(S, B=4, vocab=256, seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": rng.integers(0, vocab, (B, S)).astype(np.int32),
            "labels": rng.integers(0, vocab, (B, S)).astype(np.int32)}


def _forced_win(baseline):
    """A fake solve() outcome: a feasible plan with a DIFFERENT action
    mask and a strictly better score — deterministic where a real
    strict win depends on the instance geometry."""
    n = len(baseline.actions)
    actions = tuple(Action.REMAT if a == Action.KEEP else Action.KEEP
                    for a in baseline.actions)
    plan = Plan([], 0.0, 0.0, 0.0, actions=actions,
                microbatch=baseline.microbatch)
    # the baseline replays to overhead 0 at these budgets, so the fake
    # score must be strictly below 0 to clear the strict-win margin
    return SolveResult(plan, True, -1.0, -1.0, 0.0, "dp")


def test_swap_recompiles_only_replaced_buckets(solver_setup, monkeypatch):
    """The headline compile-count property: after the solver swaps K
    bucket plans, the next pass over every bucket compiles exactly K
    new executables — the swapped ones — and the pass after that zero."""
    import jax.numpy as jnp  # noqa: F401  (trainer deps)
    from repro.optim.adamw import AdamW
    from repro.train.trainer import Trainer
    import repro.core.solver as solver_mod
    _, lm, params = solver_setup
    planner = MimosePlanner(lm, HBM, quantum=32, warmup_samples=1,
                            solver="dp", solver_budget_ms=1e4)
    monkeypatch.setattr(
        solver_mod, "solve",
        lambda *a, **kw: _forced_win(kw["seed_plans"][0]))
    tr = Trainer(lm, planner, AdamW(lr=1e-3))
    p = _copy(params)
    opt_state = tr.optimizer.init(p)
    sizes = (32, 64)
    for S in sizes:
        p, opt_state, _ = tr.step(p, opt_state, _batch(S))
    bs = planner.background_solver
    assert bs.drain(timeout=30.0)
    assert bs.errors == 0
    assert planner.stats["solver_wins"] == len(sizes)
    assert planner.stats["solver_swaps"] == len(sizes)
    for key in list(planner.cache.keys()):
        assert planner.cache[key].source == "dp"
    # pass 1: each swapped bucket recompiles exactly once
    c0 = tr.cache_stats["compiles"]
    for S in sizes:
        p, opt_state, _ = tr.step(p, opt_state, _batch(S, seed=1))
    assert tr.cache_stats["compiles"] - c0 == len(sizes)
    # pass 2: the swapped plans are now the steady state — zero compiles
    c1 = tr.cache_stats["compiles"]
    for S in sizes:
        p, opt_state, _ = tr.step(p, opt_state, _batch(S, seed=2))
    assert tr.cache_stats["compiles"] == c1
    # a solved plan is terminal: no re-submission happened for it
    assert planner.stats["solves"] == len(sizes)


def test_swap_atomicity_under_concurrent_trainer_loop(solver_setup,
                                                      monkeypatch):
    """Solver thread swapping mid-training must never produce a torn
    read: every step sees either the greedy baseline or the complete
    solved plan, and the loop finishes with zero solver errors."""
    from repro.optim.adamw import AdamW
    from repro.train.trainer import Trainer
    import repro.core.solver as solver_mod
    _, lm, params = solver_setup
    planner = MimosePlanner(lm, HBM, quantum=32, warmup_samples=1,
                            solver="dp")

    def slow_win(*a, **kw):
        time.sleep(0.05)          # overlap the swap with live steps
        return _forced_win(kw["seed_plans"][0])

    monkeypatch.setattr(solver_mod, "solve", slow_win)
    tr = Trainer(lm, planner, AdamW(lr=1e-3))
    p = _copy(params)
    opt_state = tr.optimizer.init(p)
    seen_sources = set()
    for i in range(8):
        p, opt_state, loss = tr.step(p, opt_state, _batch(32, seed=i))
        assert np.isfinite(loss)
        info_plan = tr.history[-1]
        assert info_plan.remat_units in (0, lm.num_plan_units())
        key = planner.plan_key(tr._prepare(_batch(32)))
        with planner._cache_lock:
            cached = planner.cache.get(key)
        assert cached is not None
        seen_sources.add(cached.source)
    assert planner.background_solver.drain(timeout=30.0)
    assert planner.background_solver.errors == 0
    assert "dp" in seen_sources   # the swap really landed mid-loop


def test_stale_solve_dropped_after_invalidation(solver_setup, monkeypatch):
    """The PR-6 invalidation paths (drift-audit refit, poisoned-plan
    escalation) install NEW cache objects; a solve that started from
    the old object must be dropped, not swapped over them."""
    from repro.optim.adamw import AdamW
    from repro.train.trainer import Trainer
    import repro.core.solver as solver_mod
    _, lm, params = solver_setup
    planner = MimosePlanner(lm, HBM, quantum=32, warmup_samples=1,
                            solver="dp")
    started = threading.Event()
    release = threading.Event()

    def blocked_win(*a, **kw):
        started.set()
        release.wait(timeout=30.0)
        return _forced_win(kw["seed_plans"][0])

    monkeypatch.setattr(solver_mod, "solve", blocked_win)
    tr = Trainer(lm, planner, AdamW(lr=1e-3))
    p = _copy(params)
    opt_state = tr.optimizer.init(p)
    p, opt_state, _ = tr.step(p, opt_state, _batch(32))
    assert started.wait(timeout=30.0)
    key = planner.plan_key(tr._prepare(_batch(32)))
    # invalidate underneath the in-flight solve, as escalate/refit do
    replacement = None
    with planner._cache_lock:
        old = planner.cache[key]
        import dataclasses
        replacement = dataclasses.replace(old)
        planner.cache[key] = replacement
    release.set()
    assert planner.background_solver.drain(timeout=30.0)
    assert planner.stats["solver_swaps"] == 0
    with planner._cache_lock:
        assert planner.cache[key] is replacement


def test_background_timeout_counted(solver_setup):
    """A real (un-mocked) solve under an impossible budget times out,
    books solver_timeouts, and leaves the greedy plan in place."""
    from repro.optim.adamw import AdamW
    from repro.train.trainer import Trainer
    _, lm, params = solver_setup
    planner = MimosePlanner(lm, HBM, quantum=32, warmup_samples=1,
                            solver="dp", solver_budget_ms=1e-6)
    tr = Trainer(lm, planner, AdamW(lr=1e-3))
    p = _copy(params)
    opt_state = tr.optimizer.init(p)
    p, opt_state, _ = tr.step(p, opt_state, _batch(32))
    assert planner.background_solver.drain(timeout=30.0)
    assert planner.background_solver.errors == 0
    assert planner.stats["solver_timeouts"] >= 1
    assert planner.stats["solver_swaps"] == 0
    key = planner.plan_key(tr._prepare(_batch(32)))
    assert planner.cache[key].source == "greedy"


def test_solver_off_by_default(solver_setup):
    _, lm, _ = solver_setup
    planner = MimosePlanner(lm, HBM, quantum=32, warmup_samples=1)
    assert planner.background_solver is None
    with pytest.raises(ValueError):
        MimosePlanner(lm, HBM, solver="milp")
