"""Brute-force ground truth for the optimal-plan solver tier.

``oracle()`` enumerates EVERY (microbatch k, action assignment) pair —
all ``3^n`` rows per candidate k, ``n <= 8`` — replays each through the
scalar liveness simulator, and returns the optimum under exactly the
conventions ``repro.core.solver.solve`` uses:

* feasibility is ``peak_bytes <= budget + 1e-6`` (the scheduler's
  replay tolerance);
* the score is ``step_overhead_s + pad_overhead_s`` at the plan's k,
  with the SAME ``accum_overhead_s`` passed everywhere (the planner
  and the simulator default differently — a differential test that
  lets them diverge compares apples to oranges);
* ties break on ``(score, k, n_offload)``, matching the solver's
  preference for the smaller split and fewer host round-trips.

The differential suite (``tests/test_solver.py``) pins
``solve() == oracle()`` on randomized instances and
``solve() <= greedy()`` always; the exhaustive fallback inside
``solve`` shares ``enumerate_plans`` with this module, so the oracle
deliberately does its own independent ``itertools.product`` walk —
two enumerators agreeing is evidence, one enumerator agreeing with
itself is not.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.simulator import simulate

_FEAS_TOL = 1e-6
_INF = float("inf")
_MAX_UNITS = 8


@dataclasses.dataclass
class OracleResult:
    """Ground-truth optimum over every (k, action) assignment."""
    actions: Optional[Tuple[int, ...]]
    microbatch: int
    feasible: bool
    score: float                  # step overhead + pad overhead
    peak_bytes: float
    n_evaluated: int              # how many plans the walk replayed


def oracle(vectors_of_k, budget_bytes: float, fixed_bytes: float = 0.0, *,
           candidate_ks: Sequence[int] = (1,),
           pcie_bytes_per_s: float = 16e9, offload_overlap: float = 0.5,
           accum_overhead_s: float = 0.0) -> OracleResult:
    """Exhaustively optimal (k, actions) under ``budget_bytes``.

    Same ``vectors_of_k(k)`` contract as ``greedy_plan_adaptive`` and
    ``solve``: ``est_mem`` required, ``output_bytes`` / ``flops`` /
    ``offload_bytes`` / ``pad_overhead_s`` optional.  Returns the
    infeasible min-peak assignment (``feasible=False``) when nothing
    fits — mirroring the solver's fallback so the differential tests
    can compare that path too.
    """
    budget = float(budget_bytes)
    fixed = float(fixed_bytes)
    best = None                   # (score, k, n_off, actions, peak)
    best_peak = None              # (peak, k, actions) when nothing fits
    n_eval = 0
    for k in sorted(set(int(k) for k in candidate_ks)):
        v = vectors_of_k(k)
        est = np.asarray(v["est_mem"], dtype=float)
        n = est.size
        if n > _MAX_UNITS:
            raise ValueError(
                f"oracle enumerates 3^n plans; n={n} > {_MAX_UNITS}")
        pad = float(v.get("pad_overhead_s", 0.0))
        for acts in itertools.product((0, 1, 2), repeat=n):
            sim = simulate(est, acts, fixed, v.get("output_bytes"),
                           v.get("flops"),
                           offload_bytes=v.get("offload_bytes"),
                           pcie_bytes_per_s=pcie_bytes_per_s,
                           overlap=offload_overlap, microbatch=k,
                           accum_overhead_s=accum_overhead_s)
            n_eval += 1
            if sim.peak_bytes <= budget + _FEAS_TOL:
                cand = (sim.step_overhead_s + pad, k,
                        sum(1 for a in acts if a == 2), acts,
                        sim.peak_bytes)
                if best is None or cand[:3] < best[:3]:
                    best = cand
            elif best is None:
                cand_peak = (sim.peak_bytes, k, acts)
                if best_peak is None or cand_peak[0] < best_peak[0]:
                    best_peak = cand_peak
    if best is not None:
        score, k, _n_off, acts, peak = best
        return OracleResult(tuple(acts), k, True, score, peak, n_eval)
    if best_peak is not None:
        peak, k, acts = best_peak
        return OracleResult(tuple(acts), k, False, _INF, peak, n_eval)
    return OracleResult(None, 0, False, _INF, _INF, n_eval)
