"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles
(interpret mode on CPU; the kernel body is identical on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import flash_attention_reference, ssd_reference
from repro.models.mamba2 import ssd_chunked

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-5)


FLASH_CASES = [
    # (B, S, H, Hkv, hd, causal, window, dtype)
    (1, 64, 2, 2, 32, True, 0, jnp.float32),
    (2, 128, 4, 2, 64, True, 0, jnp.float32),
    (1, 256, 8, 1, 32, True, 0, jnp.float32),     # extreme GQA
    (1, 96, 4, 4, 32, True, 32, jnp.float32),     # sliding window
    (2, 128, 4, 2, 64, True, 64, jnp.float32),
    (1, 128, 2, 2, 32, False, 0, jnp.float32),    # bidirectional
    (1, 128, 4, 2, 64, True, 0, jnp.bfloat16),
    (1, 80, 2, 2, 16, True, 0, jnp.float32),      # non-128-multiple S
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_reference(case):
    B, S, H, Hkv, hd, causal, window, dtype = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    ref = flash_attention_reference(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal,
        window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


GRAD_CASES = [
    # (B, S, H, Hkv, hd, causal, window)
    (1, 64, 2, 2, 32, True, 0),
    (2, 96, 4, 2, 16, True, 0),       # GQA group reduce in dk/dv
    (1, 128, 2, 2, 32, True, 32),     # sliding window backward
    (1, 64, 4, 1, 16, False, 0),      # bidirectional, extreme GQA
]


@pytest.mark.parametrize("case", GRAD_CASES)
def test_flash_bwd_kernel_matches_reference(case):
    """The Pallas blockwise backward (dq/dkv kernels) vs autodiff of the
    reference."""
    B, S, H, Hkv, hd, causal, window = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))

    def f_kernel(q, k, v):
        return (ops.flash_attention(q, k, v, causal=causal,
                                    window=window) ** 2).sum()

    def f_ref(q, k, v):
        o = flash_attention_reference(q.transpose(0, 2, 1, 3),
                                      k.transpose(0, 2, 1, 3),
                                      v.transpose(0, 2, 1, 3),
                                      causal=causal, window=window)
        return (o.transpose(0, 2, 1, 3) ** 2).sum()

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4,
                                   err_msg=f"d{name} mismatch {case}")


def test_flash_attention_gradients_match_reference():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jax.random.normal(ks[2], (1, 64, 2, 32))

    def f_kernel(q, k, v):
        return (ops.flash_attention(q, k, v) ** 2).sum()

    def f_ref(q, k, v):
        o = flash_attention_reference(q.transpose(0, 2, 1, 3),
                                      k.transpose(0, 2, 1, 3),
                                      v.transpose(0, 2, 1, 3))
        return (o.transpose(0, 2, 1, 3) ** 2).sum()

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_flash_attention_residuals_are_linear_in_seq():
    """The custom VJP's saved residuals are O(S) — the flash signature."""
    def resid_bytes(S):
        q = jax.ShapeDtypeStruct((1, S, 2, 32), jnp.float32)
        vjp_struct = jax.eval_shape(
            lambda q_, k_, v_: jax.vjp(
                lambda a, b, c: ops.flash_attention(a, b, c), q_, k_, v_)[1],
            q, q, q)
        return sum(int(np.prod(l.shape)) * 4
                   for l in jax.tree_util.tree_leaves(vjp_struct))
    r128, r256 = resid_bytes(128), resid_bytes(256)
    assert r256 <= 2.05 * r128          # linear, not quadratic


SSD_CASES = [
    # (B, S, H, P, N, chunk, dtype)
    (1, 64, 2, 16, 8, 16, jnp.float32),
    (2, 128, 4, 32, 16, 32, jnp.float32),
    (1, 100, 2, 16, 8, 32, jnp.float32),          # padding path
    (1, 128, 1, 64, 32, 64, jnp.float32),
    (1, 64, 2, 16, 8, 16, jnp.bfloat16),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_matches_reference(case):
    B, S, H, P, N, chunk, dtype = case
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N), dtype)
    Cm = jax.random.normal(ks[4], (B, S, N), dtype)
    y = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    yr, _ = ssd_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-3,
                               atol=2e-1 if dtype == jnp.bfloat16 else 1e-3)


def test_ssd_chunked_jnp_matches_reference_and_state():
    """The model-internal chunked SSD (used in training) equals the naive
    recurrence including the carried state."""
    ks = jax.random.split(KEY, 5)
    B, S, H, P, N = 2, 96, 4, 16, 8
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y1, s1 = ssd_chunked(x, dt, A, Bm, Cm, 32)
    y2, s2 = ssd_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-3, atol=1e-3)


def test_ssd_gradients_finite():
    ks = jax.random.split(KEY, 5)
    B, S, H, P, N = 1, 64, 2, 8, 4
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    g = jax.grad(lambda x_: ssd_chunked(x_, dt, A, Bm, Cm, 16)[0].sum())(x)
    assert bool(jnp.all(jnp.isfinite(g)))
