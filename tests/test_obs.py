"""Unified telemetry layer tests (repro.obs): metrics registry under
concurrent writers, event-log schema round-trip, Perfetto trace
well-formedness, disabled-path no-op guarantees, and the
predicted-vs-actual drift series agreeing with the planner's refit
trigger."""
import importlib.util
import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MimosePlanner
from repro.models.lm import build_model
from repro.models.registry import get_config
from repro.obs import (NULL_SPAN, SCHEMA_VERSION, EventLog, MetricsRegistry,
                       NullEventLog, NullTracer, SpanTracer, StatsView,
                       Telemetry, TRACK_STEP, build_telemetry,
                       flush_telemetry, read_events)
from repro.optim.adamw import AdamW
from repro.train.trainer import Trainer

pytestmark = pytest.mark.obs


@pytest.fixture(scope="module")
def small():
    cfg = get_config("bert_base_paper").reduced(
        num_layers=4, d_model=128, d_ff=256, vocab_size=512)
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


def _batch(S, B=2, vocab=512):
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32)}


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_snapshot_under_concurrent_writers():
    """No lost increments: every (labelset, thread) cell has exactly one
    writer, so N threads x K bumps must sum exactly — the property the
    background solver thread relies on when it shares planner counters
    with the training thread."""
    reg = MetricsRegistry()
    c = reg.counter("hits", "test counter")
    h = reg.histogram("lat", "test histogram")
    N, K = 8, 5000

    def worker(i):
        for _ in range(K):
            c.inc()
            c.inc(1.0, bucket=i % 2)
            h.observe(0.001 * (i + 1))

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(N)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value() == N * K
    assert c.value(bucket=0) == (N // 2) * K
    assert c.value(bucket=1) == (N // 2) * K
    assert c.total() == 2 * N * K
    assert h.total() == N * K
    snap = reg.snapshot()
    assert snap["hits"]["total"] == 2 * N * K
    assert snap["hits"]["kind"] == "counter"
    assert snap["lat"]["kind"] == "histogram"


def test_statsview_mapping_and_adopt_merge():
    """StatsView serves legacy dict call sites; attach() re-homes its
    metrics into another registry, merging same-named counters into one
    shared object (how planner and watchdog oom_events converge)."""
    r1, r2 = MetricsRegistry(), MetricsRegistry()
    a = StatsView(r1, scalars={"oom_events": "oom_total"},
                  labeled={"by_bucket": ("oom_total", "bucket")})
    b = StatsView(r2, scalars={"oom_events": "oom_total"})
    a.inc("oom_events", bucket=128)
    b.inc("oom_events")
    b.attach(r1)                      # merge: both now back onto r1
    assert a["oom_events"] == 2
    assert b["oom_events"] == 2
    assert a.metric("oom_events") is b.metric("oom_events")
    assert dict(a["by_bucket"]) == {128: 1}
    # absolute set replaces the unlabeled cells; labeled cells
    # (bucket=128 above) are a separate labelset and keep counting
    c = StatsView(r1, scalars={"retries": "retry_total"})
    c["retries"] = 7
    assert c["retries"] == 7
    c["retries"] += 1
    assert c["retries"] == 8
    a["free_form"] = [1, 2]           # unknown keys -> aux passthrough
    assert dict(a)["free_form"] == [1, 2]
    with pytest.raises(TypeError):
        a["by_bucket"] = {}           # label views are not assignable


def test_prometheus_export_shape():
    reg = MetricsRegistry()
    reg.counter("c", "help c").inc(2, bucket=64)
    reg.histogram("h").observe(0.5)
    text = reg.to_prometheus()
    assert "# TYPE c counter" in text
    assert 'c{bucket="64"} 2' in text
    assert "# TYPE h histogram" in text
    assert 'h_bucket{le="1.0"}' in text
    assert "h_count 1" in text
    json.loads(reg.to_json())         # valid JSON doc


# ---------------------------------------------------------------------------
# event log
# ---------------------------------------------------------------------------

def test_event_schema_roundtrip(tmp_path):
    path = str(tmp_path / "events.jsonl")
    with EventLog(capacity=8, path=path) as log:
        log.emit("plan", bucket=np.int64(128), source="greedy",
                 est=np.array([1.0, 2.0]))
        log.emit("drift", bucket=128, rel_err=0.25, refit=True)
        for i in range(10):
            log.emit("tick", i=i)
    recs = list(read_events(path))
    assert len(recs) == 12            # the file sink keeps everything
    assert all(r["v"] == SCHEMA_VERSION for r in recs)
    assert recs[0]["kind"] == "plan"
    assert recs[0]["bucket"] == 128   # numpy degraded to plain JSON
    assert recs[0]["est"] == [1.0, 2.0]
    assert recs[1]["refit"] is True
    assert [r["i"] for r in read_events(path, kind="tick")] == list(range(10))
    # the in-memory ring is bounded: only the newest 8 survive
    with EventLog(capacity=8) as ring:
        for i in range(20):
            ring.emit("tick", i=i)
        assert len(ring) == 8
        assert [r["i"] for r in ring.tail(3)] == [17, 18, 19]


def test_event_log_skips_malformed_lines(tmp_path):
    path = str(tmp_path / "events.jsonl")
    with EventLog(path=path) as log:
        log.emit("a")
    with open(path, "a") as f:
        f.write("not json\n")
    with open(path, "a") as f:
        f.write(json.dumps({"v": 1, "ts": 0, "kind": "b"}) + "\n")
    assert [r["kind"] for r in read_events(path)] == ["a", "b"]


# ---------------------------------------------------------------------------
# span tracer / Perfetto
# ---------------------------------------------------------------------------

def test_perfetto_trace_wellformed(tmp_path):
    tr = SpanTracer()
    with tr.span("plan", TRACK_STEP, args={"bucket": 128}):
        pass
    tr.complete("execute", 1.0, 0.5, TRACK_STEP)
    tr.instant("oom", TRACK_STEP, args={"bucket": 128})
    path = str(tmp_path / "trace.json")
    tr.save(path)
    doc = json.load(open(path))
    assert isinstance(doc["traceEvents"], list)
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    for e in evs:
        assert {"name", "ph", "pid", "tid"} <= set(e)
    xs = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"plan", "execute"}
    assert all(e["dur"] >= 0 and "ts" in e for e in xs)
    ex = next(e for e in xs if e["name"] == "execute")
    assert ex["ts"] == pytest.approx(1.0e6)      # seconds -> microseconds
    assert ex["dur"] == pytest.approx(0.5e6)
    assert [e for e in evs if e["ph"] == "i" and e["name"] == "oom"]
    # exactly one thread_name metadata record for the one track used
    metas = [e for e in evs if e["ph"] == "M"]
    assert len(metas) == 1 and metas[0]["args"]["name"] == "train.step"


def test_tracer_capacity_bounded():
    tr = SpanTracer(capacity=5)
    for i in range(50):
        tr.complete(f"s{i}", 0.0, 0.001, TRACK_STEP)
    assert len([e for e in tr.events() if e["ph"] == "X"]) <= 5


# ---------------------------------------------------------------------------
# disabled path
# ---------------------------------------------------------------------------

def test_disabled_telemetry_is_noop():
    tel = Telemetry.disabled()
    assert not tel.events_on and not tel.trace_on
    assert isinstance(tel.events, NullEventLog)
    assert isinstance(tel.tracer, NullTracer)
    # zero allocation on the hot path: every span is the one shared
    # singleton, not a fresh object per call
    s1 = tel.tracer.span("plan", TRACK_STEP)
    s2 = tel.tracer.span("execute", TRACK_STEP, args={"k": 1})
    assert s1 is NULL_SPAN and s2 is NULL_SPAN
    with s1:
        pass
    tel.events.emit("anything", x=1)
    assert len(tel.events) == 0
    tel.close()


def test_build_and_flush_telemetry(tmp_path):
    mp = str(tmp_path / "metrics.json")
    ep = str(tmp_path / "events.jsonl")
    tp = str(tmp_path / "trace.json")
    tel = build_telemetry(metrics_path=mp, events_path=ep, trace_path=tp)
    assert tel.events_on and tel.trace_on
    tel.metrics.counter("n").inc(3)
    tel.events.emit("x")
    with tel.tracer.span("s", TRACK_STEP):
        pass
    written = flush_telemetry(tel)
    assert written == {"metrics": mp, "events": ep, "trace": tp}
    assert json.load(open(mp))["n"]["total"] == 3
    assert [r["kind"] for r in read_events(ep)] == ["x"]
    assert json.load(open(tp))["traceEvents"]
    # no sinks requested -> fully disabled, nothing written
    off = build_telemetry()
    assert not off.events_on and not off.trace_on
    assert flush_telemetry(off) == {}


# ---------------------------------------------------------------------------
# drift series vs the refit trigger
# ---------------------------------------------------------------------------

def test_drift_series_matches_refit_trigger(small):
    """Every ``drift`` event must satisfy refit == (rel_err >
    audit_tol), and the per-bucket predicted/actual gauges must track
    the latest drift point — the series the drift audit is built on."""
    _, lm, params = small
    tel = Telemetry.enabled()
    planner = MimosePlanner(lm, budget_bytes=1e12, warmup_samples=2,
                            quantum=8, audit_every=1, telemetry=tel)
    for S in (32, 48):
        planner.plan(params, _batch(S))
    # corrupt the fitted coefficients to force drift on the next miss
    planner.estimator.fit()
    planner.estimator._coeffs = planner.estimator._coeffs * 3.0
    planner.plan(params, _batch(96))
    drifts = tel.events.tail(100, kind="drift")
    assert drifts, "drift events must be recorded"
    assert any(d["refit"] for d in drifts)
    for d in drifts:
        assert d["refit"] == (d["rel_err"] > planner.audit_tol)
    assert planner.stats["refits"] == sum(d["refit"] for d in drifts)
    # gauges carry the latest point per bucket
    last = drifts[-1]
    pred = tel.metrics.get("plan_predicted_peak_bytes")
    act = tel.metrics.get("plan_actual_peak_bytes")
    assert pred.value(bucket=last["bucket"]) == last["predicted_bytes"]
    assert act.value(bucket=last["bucket"]) == last["actual_bytes"]


# ---------------------------------------------------------------------------
# end-to-end: a short training run with full telemetry
# ---------------------------------------------------------------------------

def test_trainer_telemetry_end_to_end(small, tmp_path):
    _, lm, params = small
    ep = str(tmp_path / "events.jsonl")
    tp = str(tmp_path / "trace.json")
    tel = build_telemetry(events_path=ep, trace_path=tp)
    planner = MimosePlanner(lm, budget_bytes=1e12, quantum=8,
                            warmup_samples=1)
    tr = Trainer(lm, planner, AdamW(), telemetry=tel)
    p = jax.tree_util.tree_map(jnp.copy, params)
    opt_state = tr.optimizer.init(p)
    for _ in range(3):
        p, opt_state, loss = tr.step(p, opt_state, _batch(32))
        assert np.isfinite(loss)
    flush_telemetry(tel)
    steps = [r for r in read_events(ep) if r["kind"] == "train_step"]
    assert len(steps) == 3
    for r in steps:
        assert {"step", "bucket", "loss", "plan_source",
                "predicted_peak_bytes"} <= set(r)
    # the per-bucket predicted-vs-actual series is present
    assert [r for r in read_events(ep) if r["kind"] == "drift"]
    doc = json.load(open(tp))
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert {"plan", "execute"} <= names
    # stats mappings stayed dict-shaped for legacy consumers
    assert tr.cache_stats["compiles"] >= 1
    assert dict(tr.cache_stats["bucket_steps"])


# ---------------------------------------------------------------------------
# tools/trace_view.py CLI
# ---------------------------------------------------------------------------

def _load_trace_view():
    path = os.path.join(os.path.dirname(__file__), "..", "tools",
                        "trace_view.py")
    spec = importlib.util.spec_from_file_location("trace_view", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_trace_view_cli(tmp_path, capsys):
    tv = _load_trace_view()
    tp = str(tmp_path / "trace.json")
    tr = SpanTracer()
    tr.complete("execute", 0.0, 0.25, TRACK_STEP)
    tr.complete("plan", 0.3, 0.05, TRACK_STEP)
    tr.save(tp)
    tv.main([tp, "--top", "5"])
    out = capsys.readouterr().out
    assert "execute" in out and "total ms" in out
    ep = str(tmp_path / "events.jsonl")
    with EventLog(path=ep) as log:
        log.emit("plan", bucket=64, source="greedy", k=1,
                 n_remat=0, n_offload=0)
        log.emit("solver_swap", bucket=64, greedy_s=0.02, solved_s=0.015,
                 improvement_pct=25.0)
        log.emit("admit", rid=0, bucket=64, wait_s=0.1)
        log.emit("defer", rid=1, bucket=128)
    tv.main([ep])
    out = capsys.readouterr().out
    assert "solver_swap" in out and "admission outcomes" in out
