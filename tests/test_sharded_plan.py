"""Sharding-aware planning: per-device byte accounting, mesh budgets,
plan feasibility per device, and mesh-keyed caches.

MeshBudget is pure axis-size math (no jax.Mesh, no fake devices), so a
(16, 16) pod budget is exercised here on the single CPU device.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MeshBudget, MimosePlanner, fixed_train_bytes,
                        fixed_train_bytes_per_device, greedy_plan_sharded,
                        simulate_sharded)
from repro.core.collector import ShuttlingCollector, unit_residual_bytes
from repro.launch.mesh import make_production_mesh, parse_mesh_shape
from repro.models.lm import PlanUnit, build_model
from repro.models.registry import get_config
from repro.sharding import specs as SP
from repro.sharding.budget import spec_divisor


@pytest.fixture(scope="module")
def toy():
    cfg = get_config("bert_base_paper").reduced(
        num_layers=4, d_model=128, d_ff=256, vocab_size=512,
        dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((4, 64), jnp.int32),
             "labels": jnp.ones((4, 64), jnp.int32)}
    return lm, params, batch


def _collect(lm, params, batch, budget=None):
    return ShuttlingCollector(lm, mesh_budget=budget).collect(params, batch)


# ---------------------------------------------------------------------------
# divisor accounting
# ---------------------------------------------------------------------------

def test_unit_divisors_exact_on_handmade_unit():
    """Per-device bytes match the specs.py divisor rules exactly on a
    unit whose vjp closure is known: two matmuls with a relu between.

    Closure leaves: x (B, S, d) boundary tensor (saved for the x*x
    term), the relu mask (B, S, f) bool and h = relu(x @ w1) (B, S, f)
    float — both tensor-parallel intermediates — plus the two weights
    (which must be excluded: they live in the fixed per-device bytes)."""
    B, S, d, f = 8, 16, 32, 64
    w1 = jnp.ones((d, f), jnp.float32)
    w2 = jnp.ones((f, d), jnp.float32)

    def apply(p, x):
        return jax.nn.relu(x @ p["w1"]) @ p["w2"] + x * x

    unit = PlanUnit("toy", 0, {"w1": w1, "w2": w2}, apply)
    x = jax.ShapeDtypeStruct((B, S, d), jnp.float32)
    x_bytes = B * S * d * 4
    h_bytes = B * S * f * 4
    mask_bytes = B * S * f * 1                     # bool relu mask

    info = unit_residual_bytes(unit, x)
    assert info["activation_bytes"] == x_bytes + h_bytes + mask_bytes
    assert info["device_activation_bytes"] == info["activation_bytes"]

    # data-only mesh: every leaf shards the batch axis over 4 ways
    b4 = MeshBudget.from_shape((4,), 1e9)
    info4 = unit_residual_bytes(unit, x, b4)
    assert info4["device_activation_bytes"] == (x_bytes + h_bytes
                                                + mask_bytes) // 4

    # (data=4, model=2): the boundary tensor (last dim == d_model) stays
    # replicated over model; the intermediates divide by data * model
    b42 = MeshBudget.from_shape((4, 2), 1e9)
    info42 = unit_residual_bytes(unit, x, b42)
    assert info42["device_activation_bytes"] == (x_bytes // 4
                                                 + (h_bytes + mask_bytes)
                                                 // 8)

    # seq-parallel shards the boundary tensor's sequence axis over model
    b42sp = MeshBudget.from_shape((4, 2), 1e9, seq_parallel=True)
    info42sp = unit_residual_bytes(unit, x, b42sp)
    assert info42sp["device_activation_bytes"] == (x_bytes
                                                   + h_bytes
                                                   + mask_bytes) // 8

    # non-divisible batch: the data axis cannot shard, divisor falls back
    b3 = MeshBudget.from_shape((3,), 1e9)
    info3 = unit_residual_bytes(unit, x, b3)
    assert info3["device_activation_bytes"] == (x_bytes + h_bytes
                                                + mask_bytes)


def test_model_level_divisors_bounded_and_consistent(toy):
    """On a real model the per-device vector obeys the divisor algebra:
    identical without a mesh, divided by up to data*model ways with one,
    monotone in the mesh size."""
    lm, params, batch = toy
    g = _collect(lm, params, batch).device_activation_vector()
    d1 = _collect(lm, params, batch,
                  MeshBudget.from_shape((1,), 1e9)).device_activation_vector()
    d4 = _collect(lm, params, batch,
                  MeshBudget.from_shape((4,), 1e9)).device_activation_vector()
    d22 = _collect(lm, params, batch,
                   MeshBudget.from_shape((2, 2), 1e9)
                   ).device_activation_vector()
    # a 1-device mesh shards nothing
    np.testing.assert_array_equal(d1, np.floor(d1))
    assert (d1 >= g * 0.99).all() and (d1 <= g * 1.01).all()
    # batch=4 over data=4: every batch-led leaf divides by 4 (scalars and
    # broadcast constants may not), so the vector sits in [g/4, g]
    assert (d4 >= d1 / 4 * 0.99).all() and (d4 < d1).all()
    assert (d4 <= d1 / 4 * 1.01).all()          # bert residuals all batch-led
    # (2,2): data 2 always, model 2 only on TP intermediates
    assert (d22 >= d1 / 4 * 0.99).all() and (d22 <= d1 / 2).all()


def test_fixed_bytes_per_device_matches_param_spec(toy):
    """The per-device fixed bytes equal the leaf-wise sum over
    specs.param_spec divisors (params + grads + fp32 moments)."""
    lm, params, batch = toy
    budget = MeshBudget.from_shape((4, 2), 1e9)
    got = fixed_train_bytes_per_device(params, budget, scanned=False)

    expected = 0.0
    axis = budget.axis_dict

    def one(path, leaf):
        nonlocal expected
        spec = SP.param_spec(path, leaf, scanned=False, mesh=None,
                             model_dim=2)
        div = spec_divisor(spec, axis)
        nbytes = int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
        n = int(np.prod(leaf.shape))
        expected += 2 * nbytes / div + 2 * 4 * n / div
        return leaf

    jax.tree_util.tree_map_with_path(one, params)
    assert got == pytest.approx(expected)
    # mesh (1,) degenerates to the global fixed bytes
    assert fixed_train_bytes_per_device(
        params, MeshBudget.from_shape((1,), 1e9)) == pytest.approx(
        fixed_train_bytes(params))


def test_attn_replicated_policy_raises_fixed_bytes(toy):
    """attn_replicated keeps attention projections replicated per
    device (specs.param_spec), so the per-device fixed bytes must grow
    and the budget signature must change — the dry-run passes the same
    policy flags it shards the real params with."""
    lm, params, batch = toy
    tp = MeshBudget.from_shape((4, 2), 1e9)
    rep = MeshBudget.from_shape((4, 2), 1e9, attn_replicated=True)
    assert fixed_train_bytes_per_device(params, rep) > \
        fixed_train_bytes_per_device(params, tp)
    assert rep.sig() != tp.sig()


def test_zero1_shards_moments_only(toy):
    lm, params, batch = toy
    plain = fixed_train_bytes_per_device(
        params, MeshBudget.from_shape((4, 2), 1e9))
    z1 = fixed_train_bytes_per_device(
        params, MeshBudget.from_shape((4, 2), 1e9, zero1=True))
    assert z1 < plain
    # params + grads are untouched; only the 8-bytes-per-param moments
    # shrink, by at most the data ways
    assert z1 >= plain - (plain * 8 / 16)


# ---------------------------------------------------------------------------
# planning under per-device budgets
# ---------------------------------------------------------------------------

def test_greedy_plan_respects_per_device_budget(toy):
    """A (4,) and a (2, 2) mesh get different per-device vectors and
    budgets; both plans must keep the scheduler's modelled footprint
    under their own per-device budget."""
    lm, params, batch = toy
    for shape in ((4,), (2, 2)):
        budget = MeshBudget.from_shape(
            shape, 0.9 * fixed_train_bytes(params), zero1=True)
        planner = MimosePlanner(lm, mesh_budget=budget, warmup_samples=1,
                                quantum=32)
        mask, info = planner.plan(params, batch)
        col = planner.collector.collect(params, batch)
        act = col.device_activation_vector()
        fixed = planner.resolve_fixed_bytes(params)
        # mask is a typed action tuple now: KEEP units are the saved ones
        saved = float(act[np.asarray(mask, dtype=int) == 0].sum())
        assert fixed + saved <= budget.hbm_per_device_bytes, shape
        # and the scheduler helper agrees with the planner's plan
        p2 = greedy_plan_sharded(act, budget, fixed)
        assert list(p2.remat) == list(mask)


def test_sharded_feasible_where_single_device_is_not(toy):
    """The acceptance scenario: one per-device HBM below the global
    fixed bytes is infeasible on 1 device but plannable on a mesh."""
    lm, params, batch = toy
    hbm = 0.75 * fixed_train_bytes(params)

    one = MeshBudget.from_shape((1,), hbm)
    p1 = MimosePlanner(lm, mesh_budget=one, warmup_samples=1, quantum=32)
    mask1, _ = p1.plan(params, batch)
    col1 = p1.collector.collect(params, batch)
    sim1 = simulate_sharded(col1.device_activation_vector(), mask1,
                            p1.resolve_fixed_bytes(params), 1)
    assert not sim1.fits(hbm)            # fixed bytes alone blow the budget

    mesh = MeshBudget.from_shape((4, 2), hbm, zero1=True)
    col = ShuttlingCollector(lm, mesh_budget=mesh).collect(params, batch)
    margin = 2 * float(col.device_activation_vector().max())
    pm = MimosePlanner(lm, max(hbm - margin, 0.0), mesh_budget=mesh,
                       warmup_samples=1, quantum=32)
    mask, _ = pm.plan(params, batch)
    sim = simulate_sharded(col.device_activation_vector(), mask,
                           pm.resolve_fixed_bytes(params), mesh.n_devices)
    assert sim.fits(hbm)
    assert sim.n_devices == 8
    assert sim.global_peak_bytes == pytest.approx(
        8 * sim.peak_bytes_per_device)


def test_cache_key_distinguishes_mesh_shapes(toy):
    lm, params, batch = toy
    a = MimosePlanner(lm, 1e9, mesh_budget=MeshBudget.from_shape((4,), 1e9),
                      warmup_samples=1, quantum=32)
    b = MimosePlanner(lm, 1e9, mesh_budget=MeshBudget.from_shape((2, 2), 1e9),
                      warmup_samples=1, quantum=32)
    c = MimosePlanner(lm, 1e9, warmup_samples=1, quantum=32)
    keys = {a.plan_key(batch), b.plan_key(batch), c.plan_key(batch)}
    assert len(keys) == 3                # same batch, three distinct keys
    # bucket component is shared; only the mesh signature differs
    assert len({k[0] for k in keys}) == 1
    # zero1 / seq-parallel flip the signature too (different divisors)
    z = MeshBudget.from_shape((4,), 1e9, zero1=True)
    assert z.sig() != MeshBudget.from_shape((4,), 1e9).sig()
    a.plan(params, batch)
    assert list(a.cache) == [a.plan_key(batch)]


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------

def test_make_production_mesh_explicit_shape():
    m = make_production_mesh(shape=(1, 1))
    assert m.axis_names == ("data", "model")
    m = make_production_mesh(shape=(1,))
    assert m.axis_names == ("data",)
    with pytest.raises(ValueError, match="positive"):
        make_production_mesh(shape=(0, 2))
    with pytest.raises(ValueError, match="axis_names"):
        make_production_mesh(shape=(1, 1, 1, 1))
    with pytest.raises(ValueError, match="does not match"):
        make_production_mesh(shape=(1,), axis_names=("data", "model"))
    if len(jax.devices()) < 8:
        with pytest.raises(RuntimeError, match="device_count"):
            make_production_mesh(shape=(4, 2))


def test_parse_mesh_shape():
    assert parse_mesh_shape("4x2") == (4, 2)
    assert parse_mesh_shape("2x16x16") == (2, 16, 16)
    with pytest.raises(ValueError):
        parse_mesh_shape("4x")
    with pytest.raises(ValueError):
        parse_mesh_shape("0x2")


def test_mesh_budget_validation():
    with pytest.raises(ValueError, match="positive"):
        MeshBudget.from_shape((), 1e9)
    with pytest.raises(ValueError, match="axis_names"):
        MeshBudget.from_shape((2, 2, 2, 2), 1e9)
    b = MeshBudget.from_shape((2, 4, 8), 1e9)
    assert b.n_devices == 64 and b.data_ways == 8 and b.model_ways == 8
