"""Tests for typed action plans + hybrid remat/offload (ISSUE 4):
Action/bool back-compat, the hybrid scheduler's feasibility gap and
floor property, offload liveness simulation, model-level OFFLOAD
execution, trainer action cache keys + offload stats, the bounded LRU
caches, and the baseline bucket-key PlanInfo fix."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.actions import Action, as_actions
from repro.core import (LRUCache, MimosePlanner, NonePlanner,
                        ShuttlingCollector, SublinearPlanner, greedy_plan,
                        offload_transfer_s, simulate)
from repro.core.planner import fixed_train_bytes
from repro.core.scheduler import Plan
from repro.models.lm import build_model
from repro.models.registry import get_config
from repro.optim.adamw import AdamW
from repro.train.trainer import Trainer

PCIE = 16e9


@pytest.fixture(scope="module")
def small():
    cfg = get_config("bert_base_paper").reduced(
        num_layers=4, d_model=128, d_ff=256, vocab_size=512)
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


@pytest.fixture(scope="module")
def vectors(small):
    """Collected per-unit byte/cost vectors + fixed bytes + headroom."""
    _, lm, params = small
    col = ShuttlingCollector(lm).collect(params, _batch(64))
    act = col.activation_vector()
    out = col.output_vector()
    off = col.offloadable_vector()
    fl = col.flops_vector()
    fixed = fixed_train_bytes(params)
    # liveness-replay transient headroom: fwd charges act+out on top of
    # saved; bwd resurrects an offloaded unit's residuals under its own
    # grad working set (2x act)
    margin = 2 * float(act.max()) + float(out.max())
    return act, out, off, fl, fixed, margin


def _batch(S, B=2):
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32)}


# ---------------------------------------------------------------------------
# Action / Plan back-compat
# ---------------------------------------------------------------------------

def test_bool_mask_normalises_to_actions():
    assert as_actions([True, False]) == (Action.REMAT, Action.KEEP)
    assert as_actions((0, 1, 2)) == (Action.KEEP, Action.REMAT,
                                     Action.OFFLOAD)
    # bool/int value compatibility both ways
    assert Action.REMAT == 1 == True          # noqa: E712
    assert Action.KEEP == 0 == False          # noqa: E712


def test_plan_as_tuple_matches_bool_semantics_without_offload():
    """Acceptance: Plan.as_tuple() equals the old boolean semantics when
    no unit is OFFLOAD."""
    p = Plan([True, False, True], 0.0, 0.0, 0.0)
    assert p.as_tuple() == (True, False, True)
    assert p.as_actions() == (Action.REMAT, Action.KEEP, Action.REMAT)
    assert p.n_remat == 2 and p.n_offload == 0
    # and the typed construction round-trips
    q = Plan([], 0.0, 0.0, 0.0,
             actions=(Action.OFFLOAD, Action.REMAT, Action.KEEP))
    assert q.as_tuple() == (False, True, False)   # OFFLOAD is not recompute
    assert q.n_remat == 1 and q.n_offload == 1


def test_plan_with_flops_counts_only_remat_units():
    p = Plan([], 0.0, 0.0, 0.0,
             actions=(Action.REMAT, Action.OFFLOAD, Action.KEEP))
    p.with_flops([10.0, 100.0, 1000.0])
    assert p.recompute_flops == 10.0


# ---------------------------------------------------------------------------
# hybrid scheduler
# ---------------------------------------------------------------------------

def _min_bool_plan_peak(act, out, fl, fixed):
    """Exhaustive minimum simulated peak over every boolean remat mask —
    the true remat-only feasibility floor (small n only)."""
    import itertools
    n = len(act)
    return min(simulate(act, mask, fixed, out, fl).peak_bytes
               for mask in itertools.product([False, True], repeat=n))


def test_hybrid_fits_budget_infeasible_for_every_bool_plan(vectors):
    """The headline capability: a budget no boolean remat mask can fit
    (REMAT must keep boundary checkpoints on device; KEEP keeps
    everything) that OFFLOAD's host eviction still fits."""
    act, out, off, fl, fixed, _ = vectors
    bool_floor = _min_bool_plan_peak(act, out, fl, fixed)
    all_off = simulate(act, [Action.OFFLOAD] * len(act), fixed, out, fl,
                       offload_bytes=off, pcie_bytes_per_s=PCIE)
    assert all_off.peak_bytes < bool_floor     # the gap exists
    budget = 0.5 * (all_off.peak_bytes + bool_floor)
    plan = greedy_plan(act, budget, fixed, flops=fl, output_bytes=out,
                       offload_bytes=off, pcie_bytes_per_s=PCIE)
    sim = simulate(act, plan.actions, fixed, out, fl, offload_bytes=off,
                   pcie_bytes_per_s=PCIE)
    assert plan.n_offload > 0
    assert sim.fits(budget)


def test_hybrid_floor_property_randomized():
    """At equal budget the hybrid plan's simulated step overhead
    (recompute + non-overlapped transfer) is never worse than the
    remat-only plan's, and feasibility is never lost."""
    rng = np.random.default_rng(7)
    feasible_trials = 0
    for trial in range(60):
        n = int(rng.integers(2, 24))
        act = rng.uniform(1e5, 1e7, n)
        out = act * rng.uniform(0.01, 0.3, n)
        fl = rng.uniform(1e8, 1e12, n)
        off = act * rng.uniform(0.5, 1.0, n)
        fixed = float(rng.uniform(0, 1e7))
        budget = (fixed + float(rng.uniform(0.3, 1.2)) * act.sum()
                  + 2 * act.max() + out.max())
        hyb = greedy_plan(act, budget, fixed, flops=fl, output_bytes=out,
                          offload_bytes=off, pcie_bytes_per_s=PCIE)
        ro = greedy_plan(act, budget, fixed, flops=fl)
        sim_h = simulate(act, hyb.actions, fixed, out, fl,
                         offload_bytes=off, pcie_bytes_per_s=PCIE)
        sim_r = simulate(act, ro.remat, fixed, out, fl,
                         offload_bytes=off, pcie_bytes_per_s=PCIE)
        if sim_r.fits(budget):
            feasible_trials += 1
            assert sim_h.fits(budget), trial
            assert (sim_h.step_overhead_s
                    <= sim_r.step_overhead_s + 1e-12), trial
    assert feasible_trials >= 10    # the property was actually exercised


def test_hybrid_prefers_offload_when_transfer_is_free():
    """With the transfer fully overlapped, OFFLOAD is strictly cheaper
    than any recompute, so a plan under pressure offloads."""
    act = np.full(8, 1e7)
    out = np.full(8, 1e5)
    off = act.copy()
    fl = np.full(8, 1e12)                     # expensive recompute
    budget = 0.4 * act.sum() + 2 * act.max() + out.max()
    plan = greedy_plan(act, budget, 0.0, flops=fl, output_bytes=out,
                       offload_bytes=off, pcie_bytes_per_s=PCIE,
                       offload_overlap=1.0)
    assert plan.n_offload > 0 and plan.n_remat == 0
    sim = simulate(act, plan.actions, 0.0, out, fl, offload_bytes=off,
                   pcie_bytes_per_s=PCIE, overlap=1.0)
    ro = greedy_plan(act, budget, 0.0, flops=fl)
    sim_r = simulate(act, ro.remat, 0.0, out, fl)
    assert sim.step_overhead_s < sim_r.step_overhead_s


def test_hybrid_no_offload_when_budget_ample(vectors):
    act, out, off, fl, fixed, _ = vectors
    plan = greedy_plan(act, 1e18, fixed, flops=fl, output_bytes=out,
                       offload_bytes=off, pcie_bytes_per_s=PCIE)
    assert plan.actions == (Action.KEEP,) * len(act)


def test_byte_only_ignores_offload(vectors):
    """byte_only=True keeps the paper's Algorithm 1 oracle untouched."""
    act, out, off, fl, fixed, _ = vectors
    a = greedy_plan(act, fixed + act.sum() * 0.5, fixed, flops=fl,
                    byte_only=True, output_bytes=out, offload_bytes=off)
    b = greedy_plan(act, fixed + act.sum() * 0.5, fixed, byte_only=True)
    assert a.remat == b.remat and a.n_offload == 0


# ---------------------------------------------------------------------------
# simulator offload accounting
# ---------------------------------------------------------------------------

def test_simulate_offload_traffic_and_peak():
    n = 6
    act = [100.0] * n
    out = [10.0] * n
    off = [80.0] * n
    plan = (Action.OFFLOAD, Action.KEEP) * 3
    sim = simulate(act, plan, 0.0, out, offload_bytes=off,
                   pcie_bytes_per_s=10.0, overlap=0.25)
    assert sim.offload_units == 3
    assert sim.offload_bytes == pytest.approx(240.0)
    # round trip over the 10 B/s link
    assert sim.offload_time_s == pytest.approx(2 * 240.0 / 10.0)
    assert sim.exposed_transfer_s == pytest.approx(sim.offload_time_s * 0.75)
    assert sim.step_overhead_s == pytest.approx(sim.exposed_transfer_s)
    # offload frees more than remat: the boundary checkpoint goes too
    sim_all_off = simulate(act, [Action.OFFLOAD] * n, 0.0, out,
                           offload_bytes=act, pcie_bytes_per_s=10.0)
    sim_all_re = simulate(act, [True] * n, 0.0, out)
    assert sim_all_off.peak_bytes < sim_all_re.peak_bytes
    assert offload_transfer_s(160.0, 10.0) == pytest.approx(32.0)


def test_simulate_bool_plan_unchanged_by_new_args():
    """Regression: the legacy bool path is bit-identical whatever the
    new offload kwargs default to."""
    act = [5.0, 7.0, 11.0]
    a = simulate(act, [True, False, True], 3.0)
    assert a.offload_units == 0 and a.offload_time_s == 0.0
    assert a.step_overhead_s == a.recompute_time_s


# ---------------------------------------------------------------------------
# model execution of OFFLOAD actions
# ---------------------------------------------------------------------------

def test_forward_accepts_bool_and_action_masks(small):
    _, lm, params = small
    batch = _batch(48)
    mask_b = (True, False, True, False)
    l_bool, _ = lm.loss(params, batch, remat_mask=mask_b)
    l_act, _ = lm.loss(params, batch, remat_mask=as_actions(mask_b))
    assert float(l_bool) == float(l_act)


def test_offload_action_loss_and_grads_match(small):
    """OFFLOAD changes residual placement, never values: loss and grads
    match the no-plan baseline."""
    _, lm, params = small
    batch = _batch(48)
    plan = (Action.OFFLOAD, Action.KEEP, Action.REMAT, Action.OFFLOAD)
    l0, _ = lm.loss(params, batch)
    l1, _ = lm.loss(params, batch, remat_mask=plan)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)
    g0 = jax.jit(jax.grad(lambda p: lm.loss(p, batch)[0]))(params)
    g1 = jax.jit(jax.grad(
        lambda p: lm.loss(p, batch, remat_mask=plan)[0]))(params)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# trainer: action keys + offload stats
# ---------------------------------------------------------------------------

def test_trainer_offload_planner_end_to_end(vectors, small):
    _, lm, params = small
    act, out, off, fl, fixed, _ = vectors
    all_off = simulate(act, [Action.OFFLOAD] * len(act), fixed, out, fl,
                       offload_bytes=off, pcie_bytes_per_s=PCIE)
    budget = 0.5 * (all_off.peak_bytes
                    + _min_bool_plan_peak(act, out, fl, fixed))
    planner = MimosePlanner(lm, budget, quantum=32, warmup_samples=1,
                            offload=True)
    tr = Trainer(lm, planner, AdamW(lr=1e-3))
    p = jax.tree_util.tree_map(jnp.copy, params)
    opt_state = tr.optimizer.init(p)
    p, opt_state, loss = tr.step(p, opt_state, {
        "tokens": np.ones((2, 60), np.int32),
        "labels": np.ones((2, 60), np.int32)})
    assert np.isfinite(loss)
    st = tr.history[-1]
    assert st.offload_units > 0
    assert tr.summary()["mean_offload_units"] > 0


def test_mesh_planning_prices_flops_per_device(small):
    """Regression: the hybrid selection compares recompute seconds
    against per-device transfer seconds, so under a mesh budget the
    flops vector must be divided down to the per-device frame too —
    global flops would inflate remat cost by n_devices and over-offload."""
    from repro.core import MeshBudget
    _, lm, params = small
    budget = MeshBudget.from_shape((4, 2), 1e18)
    planner = MimosePlanner(lm, mesh_budget=budget, warmup_samples=1,
                            quantum=32, offload=True)
    fl = np.array([8.0, 16.0])
    np.testing.assert_allclose(planner.planning_flops(fl), fl / 8.0)
    # global mode: untouched
    g = MimosePlanner(lm, 1e18, warmup_samples=1)
    assert g.planning_flops(fl) is fl
    # and the sharded hybrid plan path runs end to end
    plan, info = planner.plan(params, _batch(64))
    assert len(plan) == lm.num_plan_units()


def test_offload_requires_cost_aware(small):
    _, lm, _ = small
    with pytest.raises(ValueError, match="cost_aware"):
        MimosePlanner(lm, 1e9, offload=True, cost_aware=False)
    with pytest.raises(ValueError, match="cost_aware"):
        SublinearPlanner(lm, 1e9, max_input_size=128, offload=True,
                         cost_aware=False)


# ---------------------------------------------------------------------------
# bounded LRU caches (trainer jit-step cache + planner plan cache)
# ---------------------------------------------------------------------------

def test_lru_cache_evicts_least_recently_used():
    c = LRUCache(2)
    c["a"] = 1
    c["b"] = 2
    assert c["a"] == 1          # touch "a": "b" becomes the LRU victim
    c["c"] = 3
    assert "b" not in c and "a" in c and "c" in c
    assert c.evictions == 1
    c.clear()
    assert len(c) == 0 and c.evictions == 1   # clear() is not an eviction


def test_trainer_step_cache_bounded_and_counted(small):
    _, lm, params = small
    planner = MimosePlanner(lm, budget_bytes=1e12, quantum=64,
                            warmup_samples=2)
    tr = Trainer(lm, planner, AdamW(lr=1e-3), max_cached_steps=1)
    p = jax.tree_util.tree_map(jnp.copy, params)
    opt_state = tr.optimizer.init(p)
    for S in (40, 100, 40):     # bucket 64, bucket 128, bucket 64 again
        p, opt_state, loss = tr.step(p, opt_state, {
            "tokens": np.ones((2, S), np.int32),
            "labels": np.ones((2, S), np.int32)})
        assert np.isfinite(loss)
    assert len(tr._step_cache) == 1
    # the third step re-compiled bucket 64 (evicted by bucket 128)
    assert tr.cache_stats["compiles"] == 3
    assert tr.cache_stats["evictions"] == 2
    assert tr.summary()["step_cache_evictions"] == 2


def test_planner_plan_cache_bounded_and_counted(small):
    _, lm, params = small
    planner = MimosePlanner(lm, budget_bytes=1e12, quantum=8,
                            warmup_samples=1, max_plans=2)
    for S in (16, 32, 48, 64):
        planner.plan(params, _batch(S))
    assert len(planner.cache) == 2
    assert planner.stats["evictions"] == 2
    # the still-cached newest bucket is a hit
    _, info = planner.plan(params, _batch(64))
    assert info.cache_hit


# ---------------------------------------------------------------------------
# baseline PlanInfo bucket keys (satellite fix)
# ---------------------------------------------------------------------------

def test_baselines_report_real_bucket_key(small):
    _, lm, params = small
    batch = _batch(50)
    n_elems = 2 * 50
    _, info = NonePlanner(lm).plan(params, batch)
    assert info.quantized_size == n_elems        # quantum 1: bucket == size
    sub = SublinearPlanner(lm, 1e12, max_input_size=2 * 256,
                           warmup_samples=2)
    _, info = sub.plan(params, batch)
    assert info.quantized_size == n_elems
