"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned architectures: instantiate a REDUCED variant
of the same family (2 layers, d_model <= 512, <= 4 experts) and run one
forward + one train step on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only by launch/dryrun.py (abstract).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import INPUT_SHAPES
from repro.models.lm import build_model
from repro.models.registry import ARCH_IDS, get_config
from repro.optim.adamw import AdamW

ASSIGNED = [a for a in ARCH_IDS if a != "bert_base_paper"]


def _batch_for(cfg, B=2, S=32, key=0):
    rng = np.random.default_rng(key)
    text_len = S - cfg.vision_tokens if cfg.family == "vlm" else S
    batch = {
        "tokens": jnp.asarray(rng.integers(1, cfg.vocab_size,
                                           (B, text_len)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                           (B, text_len)), jnp.int32),
        "weights": jnp.ones((B, text_len), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_tokens, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.dtype(cfg.dtype))
    return batch


@pytest.fixture(scope="module", params=ASSIGNED)
def arch(request):
    return request.param


def _reduced(arch_id):
    cfg = get_config(arch_id).reduced(dtype="float32")
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    return cfg


def test_full_config_exact(arch):
    """The full config matches the assignment table."""
    cfg = get_config(arch)
    expected = {
        "mamba2_1p3b": dict(num_layers=48, d_model=2048, vocab_size=50280,
                            ssm_state=128, d_ff=0),
        "seamless_m4t_large_v2": dict(num_layers=24, d_model=1024,
                                      num_heads=16, num_kv_heads=16,
                                      d_ff=8192, vocab_size=256206),
        "granite_moe_1b_a400m": dict(num_layers=24, d_model=1024,
                                     num_heads=16, num_kv_heads=8,
                                     moe_d_ff=512, vocab_size=49155,
                                     num_experts=32, experts_per_token=8),
        "gemma3_12b": dict(num_layers=48, d_model=3840, num_heads=16,
                           num_kv_heads=8, d_ff=15360, vocab_size=262144),
        "yi_9b": dict(num_layers=48, d_model=4096, num_heads=32,
                      num_kv_heads=4, d_ff=11008, vocab_size=64000),
        "stablelm_3b": dict(num_layers=32, d_model=2560, num_heads=32,
                            num_kv_heads=32, d_ff=6912, vocab_size=50304),
        "qwen2_vl_7b": dict(num_layers=28, d_model=3584, num_heads=28,
                            num_kv_heads=4, d_ff=18944, vocab_size=152064,
                            mrope=True),
        "qwen3_1p7b": dict(num_layers=28, d_model=2048, num_heads=16,
                           num_kv_heads=8, d_ff=6144, vocab_size=151936,
                           qk_norm=True),
        "hymba_1p5b": dict(num_layers=32, d_model=1600, num_heads=25,
                           num_kv_heads=5, d_ff=5504, vocab_size=32001,
                           ssm_state=16),
        "kimi_k2_1t_a32b": dict(num_layers=61, d_model=7168, num_heads=64,
                                num_kv_heads=8, moe_d_ff=2048,
                                vocab_size=163840, num_experts=384,
                                experts_per_token=8),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_forward_shapes_no_nan(arch):
    cfg = _reduced(arch)
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    logits, aux = lm.forward(params, batch)
    B = batch["tokens"].shape[0]
    S_total = batch["tokens"].shape[1] + (cfg.vision_tokens
                                          if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_total, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert not bool(jnp.isnan(aux))


def test_one_train_step(arch):
    cfg = _reduced(arch)
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)

    def step(p, s, b):
        (loss, _), grads = jax.value_and_grad(
            lambda pp: lm.loss(pp, b), has_aux=True)(p)
        np_, ns = opt.update(grads, s, p)
        return np_, ns, loss

    p1, s1, l1 = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(l1))
    # params actually changed
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p1)))
    assert changed
    # a second step decreases or roughly maintains loss on the same batch
    _, _, l2 = jax.jit(step)(p1, s1, batch)
    assert float(l2) < float(l1) + 0.5


def test_remat_mask_is_numerically_invariant(arch):
    cfg = _reduced(arch)
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(1))
    batch = _batch_for(cfg)
    n = lm.num_plan_units()
    base, _ = lm.loss(params, batch)
    for mask in ([True] * n, [True] + [False] * (n - 1)):
        loss, _ = lm.loss(params, batch, remat_mask=mask)
        np.testing.assert_allclose(float(loss), float(base), rtol=1e-5)


def test_decode_matches_forward(arch):
    cfg = _reduced(arch)
    if cfg.family == "encdec":
        pytest.skip("enc-dec decode covered in test_system (needs frames)")
    if cfg.family == "vlm":
        pytest.skip("vlm decode covered via dry-run (prefix cache setup)")
    if cfg.num_experts:
        # GShard capacity routing drops tokens in the batched forward;
        # disable drops so decode (per-token, never drops) is comparable.
        cfg = dataclasses.replace(cfg,
                                  moe_capacity_factor=float(cfg.num_experts))
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(2))
    T = 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, T), 1,
                              cfg.vocab_size)
    logits_full, _ = lm.forward(params, {"tokens": toks})
    cache = lm.init_cache(1, T)
    outs = []
    for t in range(T):
        lg, cache = lm.decode_step(params, toks[:, t:t + 1], cache, t)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               rtol=1e-3, atol=1e-3)


def test_scan_matches_unrolled(arch):
    cfg = _reduced(arch)
    if cfg.family == "encdec":
        pytest.skip("encdec is unrolled-only")
    cfg_s = dataclasses.replace(cfg, remat_mode="scan", scan_chunks=2)
    lm_u, lm_s = build_model(cfg), build_model(cfg_s)
    pu = lm_u.init(jax.random.PRNGKey(4))
    ps = dict(pu)
    ps["blocks"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                          *pu["blocks"])
    batch = _batch_for(cfg)
    lu, _ = lm_u.loss(pu, batch)
    ls, _ = lm_s.loss(ps, batch)
    np.testing.assert_allclose(float(lu), float(ls), rtol=1e-5)
