"""Tests for real asynchronous overlapped offload (ISSUE 8): the
double-buffered ``TransferLane``, host-memory capability probes, the
SPMD offload probe + visible degradation counters, OFFLOAD_OPT
planning (simulator / greedy / solver / planner wiring) and split-step
execution in the trainer, the Pallas DMA copy kernel, bandwidth
calibration, and snapshot restore under calibrated-bandwidth drift.

Marked ``offload`` (own CI job); everything here is CPU-safe and fast
so the full local run still includes it."""
import importlib.util
import json
import pathlib
import time
import types
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.actions import Action
from repro.core import MimosePlanner, greedy_plan, simulate
from repro.core.planner import PlanInfo, PlannerBase
from repro.core.scheduler import Plan
from repro.core.solver import solve
from repro.kernels.offload_dma import dma_copy
from repro.kernels.ops import residual_dma_copy
from repro.launch.report import engine_report
from repro.models import lm as lm_mod
from repro.models.lm import (build_model, configure_offload,
                             host_offload_policy, spmd_offload_supported)
from repro.models.registry import get_config
from repro.train.resilience import planner_state, restore_planner_state
from repro.train.transfer import (CALIBRATION_ENV, PCIE_ENV, TransferLane,
                                  calibrated_pcie_gbps, measure_pcie_gbps,
                                  write_calibration)
from repro.train.trainer import Trainer

pytestmark = pytest.mark.offload

HBM = 8e9
PCIE = 16e9
ROOT = pathlib.Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("bert_base_paper").reduced(
        num_layers=4, d_model=64, d_ff=128, vocab_size=256)
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


def _batch(S, B=2):
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32)}


class StubPlanner(PlannerBase):
    """Fixed-action planner: lets trainer tests pick the exact plan."""
    name = "stub"
    quantum = 1

    def __init__(self, actions):
        self.actions = tuple(Action(int(a)) for a in actions)
        self.stats = {}

    def plan(self, params, batch):
        plan = Plan([a is Action.REMAT for a in self.actions],
                    0.0, 0.0, 0.0, actions=self.actions)
        return plan.as_actions(), PlanInfo(0, 0, False, False, plan)


# ---------------------------------------------------------------------------
# simulator: OFFLOAD_OPT semantics
# ---------------------------------------------------------------------------

def test_simulate_opt_offload_reduces_peak_by_parked_bytes():
    act = [10.0] * 4
    opt = [7.0, 5.0, 3.0, 2.0]
    plan = [Action.OFFLOAD_OPT, Action.KEEP, Action.KEEP,
            Action.OFFLOAD_OPT]
    base = simulate(act, [Action.KEEP] * 4, 100.0, opt_bytes=opt)
    parked = simulate(act, plan, 100.0, opt_bytes=opt,
                      pcie_bytes_per_s=PCIE, overlap=0.5)
    # parked moments leave the fixed footprint for the WHOLE step, so
    # every liveness sample — and therefore the peak — drops by exactly
    # the parked bytes
    assert parked.peak_bytes == base.peak_bytes - (7.0 + 2.0)
    assert parked.opt_offload_bytes == 9.0
    assert parked.opt_offload_units == 2
    assert parked.opt_transfer_s == pytest.approx(2.0 * 9.0 / PCIE)
    assert parked.exposed_transfer_s == pytest.approx(
        0.5 * 2.0 * 9.0 / PCIE)


def test_simulate_opt_traffic_is_per_step_not_per_microbatch():
    act = [10.0] * 4
    opt = [8.0] * 4
    plan = [Action.OFFLOAD_OPT] + [Action.KEEP] * 3
    one = simulate(act, plan, 50.0, opt_bytes=opt, microbatch=1,
                   pcie_bytes_per_s=PCIE)
    four = simulate(act, plan, 50.0, opt_bytes=opt, microbatch=4,
                    pcie_bytes_per_s=PCIE)
    # the optimizer update runs once per step: its round trip must not
    # scale with the gradient-accumulation split
    assert four.opt_transfer_s == one.opt_transfer_s
    assert four.opt_offload_bytes == one.opt_offload_bytes


def test_simulate_without_opt_vector_makes_offload_opt_a_free_noop():
    act = [10.0] * 3
    w = simulate(act, [Action.OFFLOAD_OPT, Action.KEEP, Action.KEEP],
                 40.0)
    k = simulate(act, [Action.KEEP] * 3, 40.0)
    # back-compat: plans replayed without a moment vector behave exactly
    # as 3-action plans did
    assert w.peak_bytes == k.peak_bytes
    assert w.opt_offload_bytes == 0.0 and w.opt_transfer_s == 0.0


# ---------------------------------------------------------------------------
# greedy + solver: OFFLOAD_OPT selection
# ---------------------------------------------------------------------------

def test_greedy_parks_moments_when_remat_alone_cannot_fit():
    act = [10.0] * 4
    out = [1.0] * 4
    off = [9.0] * 4
    fl = [1e9] * 4
    opt = [30.0] * 4
    fixed, budget = 100.0, 95.0   # fixed alone exceeds the budget
    p = greedy_plan(act, budget, fixed, flops=fl, output_bytes=out,
                    offload_bytes=off, opt_bytes=opt,
                    pcie_bytes_per_s=PCIE, offload_overlap=0.5)
    assert p.n_opt >= 1
    sim = simulate(act, p.actions, fixed, out, fl, offload_bytes=off,
                   opt_bytes=opt, pcie_bytes_per_s=PCIE, overlap=0.5)
    assert sim.fits(budget)


def test_greedy_opt_bytes_is_a_pure_extension_under_slack():
    act, out, off, fl = [10.0] * 4, [1.0] * 4, [9.0] * 4, [1e9] * 4
    base = greedy_plan(act, 500.0, 50.0, flops=fl, output_bytes=out,
                       offload_bytes=off, pcie_bytes_per_s=PCIE)
    w = greedy_plan(act, 500.0, 50.0, flops=fl, output_bytes=out,
                    offload_bytes=off, opt_bytes=[5.0] * 4,
                    pcie_bytes_per_s=PCIE)
    # generous budget: nothing needs to move, and offering OFFLOAD_OPT
    # must not perturb the plan
    assert w.n_opt == 0
    assert w.as_actions() == base.as_actions()


def test_solver_exhaustive_finds_offload_opt_when_required():
    vec = dict(est_mem=[10.0, 10.0, 10.0], flops=[1e9] * 3,
               output_bytes=[1.0] * 3, offload_bytes=[9.0] * 3,
               opt_bytes=[60.0, 0.0, 0.0])
    res = solve(lambda k: vec, budget_bytes=95.0, fixed_bytes=100.0,
                method="exhaustive", pcie_bytes_per_s=PCIE)
    # only parking unit 0's moments can bring the fixed footprint under
    # budget; the exhaustive enumeration must find it
    assert res.feasible
    assert res.plan.n_opt >= 1
    assert res.plan.actions[0] is Action.OFFLOAD_OPT


# ---------------------------------------------------------------------------
# planner wiring: the pinned moment vector + knob validation
# ---------------------------------------------------------------------------

def test_planner_opt_offload_requires_offload(tiny):
    _, lm, _ = tiny
    with pytest.raises(ValueError, match="needs offload=True"):
        MimosePlanner(lm, HBM, opt_offload=True)


def test_planner_pins_opt_vector_once(tiny):
    _, lm, params = tiny
    pl = MimosePlanner(lm, HBM, quantum=64, warmup_samples=1,
                       offload=True, opt_offload=True)
    pl.plan(params, _batch(64))
    v = pl._opt_vector
    assert v is not None and np.all(v > 0)
    np.testing.assert_allclose(pl._opt_bytes_planning(), v)
    assert "opt_bytes" in pl._hybrid_kwargs(64)
    pl.plan(params, _batch(128))
    # moment bytes are pure parameter-shape math: pinned by the first
    # collection, never refit per input size
    assert pl._opt_vector is v


def test_opt_bytes_planning_gated_off_in_scan_mode(tiny, monkeypatch):
    _, lm, params = tiny
    pl = MimosePlanner(lm, HBM, quantum=64, warmup_samples=1,
                       offload=True, opt_offload=True)
    pl.plan(params, _batch(64))
    assert pl._opt_bytes_planning() is not None
    # scan-mode moments are stacked across a chunk in one leaf: parking
    # cannot free a slice, so the action must not be offered
    monkeypatch.setattr(pl, "lm", types.SimpleNamespace(
        cfg=types.SimpleNamespace(remat_mode="scan")))
    assert pl._opt_bytes_planning() is None


# ---------------------------------------------------------------------------
# host_offload_policy fallback + SPMD probe / degradation surfacing
# ---------------------------------------------------------------------------

def test_host_offload_policy_none_fallback(monkeypatch):
    monkeypatch.delattr(jax, "checkpoint_policies")
    assert host_offload_policy() is None
    assert spmd_offload_supported() is False


def test_configure_offload_degrades_and_warns_once(monkeypatch):
    monkeypatch.delattr(jax, "checkpoint_policies")
    monkeypatch.setattr(lm_mod, "_spmd_offload_warned", set())
    stub = types.SimpleNamespace(offload_exec=True)
    with pytest.warns(RuntimeWarning, match="host offload unavailable"):
        assert configure_offload(stub) is True
    assert stub.offload_exec is False
    # warn-once per mesh signature: the second call stays silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert configure_offload(stub) is True


def test_configure_offload_keeps_capable_runtimes_enabled():
    if host_offload_policy() is None:
        pytest.skip("jaxlib build has no offload policy")
    assert spmd_offload_supported() is True       # single device
    stub = types.SimpleNamespace(offload_exec=False)
    assert configure_offload(stub) is False
    assert stub.offload_exec is True


def test_trainer_counts_offload_degradation():
    cfg = get_config("bert_base_paper").reduced(
        num_layers=4, d_model=64, d_ff=128, vocab_size=256)
    lm = build_model(cfg)
    lm.offload_exec = False           # what configure_offload sets on
    params = lm.init(jax.random.PRNGKey(0))   # a degraded mesh/runtime
    tr = Trainer(lm, StubPlanner([Action.OFFLOAD, Action.KEEP,
                                  Action.KEEP, Action.KEEP]))
    opt_state = tr.optimizer.init(params)
    for _ in range(3):
        params, opt_state, _ = tr.step(params, opt_state, _batch(32))
    assert all(s.offload_degraded for s in tr.history)
    assert tr.planner.stats["offload_fallbacks"] == 1   # once per bucket
    s = tr.summary()
    assert s["offload_degraded_steps"] == 3
    assert s["offload_fallbacks"] == 1
    assert "offload degraded to remat" in engine_report(tr, tr.planner)


# ---------------------------------------------------------------------------
# TransferLane
# ---------------------------------------------------------------------------

def test_transfer_lane_round_trip_and_stats():
    lane = TransferLane()
    x = jnp.arange(1024, dtype=jnp.float32)
    y = lane.fetch(lane.offload(x))
    assert isinstance(y, jax.Array)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    st = lane.reset_stats()
    assert st["bytes_out"] == 4096 and st["bytes_in"] == 4096
    assert st["transfers"] >= 2 and st["exposed_s"] >= 0.0
    assert lane.stats["bytes_out"] == 0       # reset zeroes the counters
    lane.close()


def test_transfer_lane_host_value_skips_return_trip():
    lane = TransferLane()
    h = lane.offload(jnp.full((256,), 3.0, jnp.float32))
    hv = lane.host_value(h)
    on_host = isinstance(hv, np.ndarray) or (
        isinstance(hv, jax.Array)
        and hv.sharding.memory_kind == "pinned_host")
    assert on_host
    np.testing.assert_array_equal(np.asarray(hv), np.full((256,), 3.0))
    st = lane.reset_stats()
    assert st["bytes_out"] == 1024 and st["bytes_in"] == 0
    lane.close()


def test_transfer_lane_upload_mirrors_offload():
    lane = TransferLane()
    host = np.full((128,), 7.0, np.float32)
    y = lane.fetch(lane.upload(host))
    assert isinstance(y, jax.Array)
    np.testing.assert_array_equal(np.asarray(y), host)
    assert lane.reset_stats()["bytes_in"] == 512
    lane.close()


def test_transfer_lane_prefetch_lands_on_device():
    lane = TransferLane()
    x = jnp.arange(64, dtype=jnp.float32)
    h2 = lane.prefetch(lane.offload(x))
    y = lane.fetch(h2)
    assert isinstance(y, jax.Array)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    st = lane.reset_stats()
    assert st["bytes_out"] == 256 and st["bytes_in"] == 256
    lane.close()


def test_transfer_lane_depth_bounds_in_flight_and_charges_waits():
    lane = TransferLane(depth=2)
    orig = lane._copy_out

    def slow(x):
        time.sleep(0.05)
        return orig(x)

    lane._copy_out = slow
    for _ in range(3):
        lane.offload(jnp.ones((8,), jnp.float32))
    # the third enqueue found both buffers busy: the wait for the oldest
    # copy is exactly what the lane books as exposed time
    assert lane.stats["exposed_s"] > 0.0
    lane.drain()
    lane.close()


# ---------------------------------------------------------------------------
# trainer: OFFLOAD_OPT split-step execution
# ---------------------------------------------------------------------------

def test_trainer_opt_split_matches_fused_step_exactly():
    cfg = get_config("bert_base_paper").reduced(
        num_layers=4, d_model=64, d_ff=128, vocab_size=256)
    lm = build_model(cfg)
    losses = {}
    trainers = {}
    for name, acts in (("fused", [Action.KEEP] * 4),
                       ("split", [Action.KEEP, Action.KEEP,
                                  Action.OFFLOAD_OPT, Action.KEEP])):
        params = lm.init(jax.random.PRNGKey(0))
        tr = Trainer(lm, StubPlanner(acts))
        opt_state = tr.optimizer.init(params)
        ls = []
        for _ in range(4):
            params, opt_state, loss = tr.step(params, opt_state,
                                              _batch(32))
            ls.append(loss)
        losses[name] = ls
        trainers[name] = (tr, opt_state)
    # parking moments on the host must not change the math at all
    assert losses["split"] == losses["fused"]
    tr, opt_state = trainers["split"]
    st = tr.history[-1]
    assert st.opt_offload_units == 1
    assert tr._parked == {2}
    leaf = jax.tree_util.tree_leaves(tr._moment_get(opt_state.m, 2))[0]
    on_host = isinstance(leaf, np.ndarray) or (
        isinstance(leaf, jax.Array)
        and leaf.sharding.memory_kind == "pinned_host")
    assert on_host                    # moments live off-device between steps
    # telemetry: the lane measured real traffic and the simulator priced
    # the same bytes
    assert st.sim_transfer_s > 0.0 and st.exposed_transfer_s >= 0.0
    s = tr.summary()
    assert s["mean_opt_offload_units"] > 0
    assert s["sim_transfer_s"] > 0.0
    assert "offload: exposed transfer" in engine_report(tr, tr.planner)


# ---------------------------------------------------------------------------
# Pallas DMA copy kernel (interpret mode on CPU)
# ---------------------------------------------------------------------------

def test_dma_copy_identity_including_padding_tail():
    cases = [((128,), jnp.float32), ((33,), jnp.float32),
             ((7, 5), jnp.bfloat16), ((1,), jnp.int32)]
    for shape, dtype in cases:
        n = int(np.prod(shape))
        x = jnp.arange(n, dtype=jnp.float32).astype(dtype).reshape(shape)
        y = dma_copy(x, chunk_elems=16, interpret=True)
        assert y.shape == x.shape and y.dtype == x.dtype
        np.testing.assert_array_equal(
            np.asarray(y, np.float32), np.asarray(x, np.float32))


def test_residual_dma_copy_wrapper():
    x = jnp.linspace(0.0, 1.0, 1000, dtype=jnp.float32).reshape(10, 100)
    y = residual_dma_copy(x)
    assert y.shape == x.shape and y.dtype == x.dtype
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


# ---------------------------------------------------------------------------
# bandwidth calibration + snapshot restore under calibration drift
# ---------------------------------------------------------------------------

def test_calibrated_pcie_hierarchy(tmp_path, monkeypatch):
    path = tmp_path / "cal.json"
    monkeypatch.setenv(CALIBRATION_ENV, str(path))
    monkeypatch.delenv(PCIE_ENV, raising=False)
    assert calibrated_pcie_gbps(16.0) == 16.0     # nothing calibrated yet
    write_calibration({"pcie_gbps": 3.25})
    assert calibrated_pcie_gbps(16.0) == 3.25     # file beats default
    from repro.launch.roofline import calibrated_pcie_gbps as launch_cal
    assert launch_cal(12.0) == 3.25               # launch default delegates
    monkeypatch.setenv(PCIE_ENV, "7.5")
    assert calibrated_pcie_gbps(16.0) == 7.5      # env wins outright
    monkeypatch.delenv(PCIE_ENV)
    path.write_text("not json")
    assert calibrated_pcie_gbps(16.0) == 16.0     # corrupt file ignored


def test_measure_pcie_reports_round_trip_harmonic():
    cal = measure_pcie_gbps(size_mb=1, repeats=1)
    assert cal["pcie_gbps"] > 0
    assert cal["backend"] == jax.default_backend()
    hm = 2.0 / (1.0 / cal["device_to_host_gbps"]
                + 1.0 / cal["host_to_device_gbps"])
    assert cal["pcie_gbps"] == pytest.approx(hm, abs=0.01)


def test_bench_offload_bw_tool_writes_calibration(tmp_path, monkeypatch):
    spec = importlib.util.spec_from_file_location(
        "bench_offload_bw", ROOT / "tools" / "bench_offload_bw.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    out = tmp_path / "cal.json"
    assert mod.main(["--size-mb", "1", "--repeats", "1",
                     "--out", str(out)]) == 0
    cal = json.loads(out.read_text())
    assert cal["pcie_gbps"] > 0 and cal["size_mb"] == 1
    # the tool's output is exactly what the --pcie-gbps default reads
    monkeypatch.setenv(CALIBRATION_ENV, str(out))
    monkeypatch.delenv(PCIE_ENV, raising=False)
    assert calibrated_pcie_gbps(999.0) == cal["pcie_gbps"]


def test_restore_drops_plans_on_calibrated_bandwidth_change(
        tiny, tmp_path, monkeypatch):
    """A recalibration between snapshot and resume changes the planner's
    link pricing; plans solved at the old bandwidth must be dropped, not
    resurrected (satellite of the plan_key roofline-knob guarantee)."""
    _, lm, params = tiny
    src = MimosePlanner(lm, HBM, quantum=64, warmup_samples=1,
                        offload=True, pcie_gbps=16.0)
    src.plan(params, _batch(64))
    state = planner_state(src)
    assert state["plans"]
    monkeypatch.setenv(CALIBRATION_ENV, str(tmp_path / "cal.json"))
    monkeypatch.delenv(PCIE_ENV, raising=False)
    write_calibration({"pcie_gbps": 1.72})       # bench tool ran meanwhile
    gbps = calibrated_pcie_gbps(16.0)
    assert gbps == 1.72
    dst = MimosePlanner(lm, HBM, quantum=64, warmup_samples=1,
                        offload=True, pcie_gbps=gbps)
    summary = restore_planner_state(dst, state)
    assert summary["restored_plans"] == 0
    assert summary["dropped_plans"] == len(state["plans"])
    # the learned estimators still restore — only the stale plans drop
    assert dst.estimator.num_samples == src.estimator.num_samples
