"""Launch plumbing: dry-run entry point in a subprocess (it needs its own
jax process because of --xla_force_host_platform_device_count)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(*args):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600)


@pytest.mark.slow
def test_dryrun_single_pair_compiles():
    p = _run_dryrun("--arch", "mamba2-1.3b", "--shape", "decode_32k")
    assert p.returncode == 0, p.stdout + p.stderr
    rec = json.loads(p.stdout.strip().splitlines()[-1])
    assert rec["status"] == "ok"
    assert rec["step"] == "serve_step"
    assert rec["flops_per_dev"] > 0
    assert rec["mesh"] == "16x16"


@pytest.mark.slow
def test_dryrun_skips_long_decode_for_full_attention():
    p = _run_dryrun("--arch", "yi-9b", "--shape", "long_500k")
    rec = json.loads(p.stdout.strip().splitlines()[-1])
    assert rec["status"] == "skipped"
    assert "full-attention" in rec["reason"]


def test_mesh_requires_512_devices_message():
    # in THIS process there is one device; the mesh must refuse politely
    from repro.launch.mesh import make_production_mesh
    import jax
    if len(jax.devices()) < 256:
        with pytest.raises(RuntimeError, match="host_platform_device_count"):
            make_production_mesh()
