"""End-to-end behaviour tests for the Mimose system (paper claims)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DTRSimPlanner, MimosePlanner, NonePlanner,
                        ShuttlingCollector, SublinearPlanner, simulate)
from repro.core.planner import fixed_train_bytes
from repro.data.pipeline import DISTRIBUTIONS, make_batches
from repro.models.lm import build_model
from repro.models.registry import get_config
from repro.optim.adamw import AdamW
from repro.train import checkpoint as ckpt
from repro.train.serve import generate
from repro.train.trainer import Trainer


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("bert_base_paper").reduced(
        num_layers=4, d_model=128, d_ff=256, vocab_size=256)
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


def _budget(lm, params, frac):
    fixed = fixed_train_bytes(params)
    col = ShuttlingCollector(lm)
    tot = col.collect(params, {
        "tokens": jnp.ones((4, 160), jnp.int32)}).total_activation_bytes()
    return fixed + int(tot * frac)


def _train(lm, params, planner, n=12, seed=3):
    cfg = lm.cfg
    tr = Trainer(lm, planner, AdamW(lr=1e-3))
    batches = make_batches("swag", batch_size=4, vocab_size=cfg.vocab_size,
                           num_batches=n, quantum=32, seed=seed)
    p, _ = tr.run(jax.tree_util.tree_map(jnp.copy, params), batches)
    return tr, p


def test_training_converges_with_mimose(setup):
    cfg, lm, params = setup
    planner = MimosePlanner(lm, _budget(lm, params, 0.5),
                            warmup_samples=2, quantum=32)
    tr, _ = _train(lm, params, planner, n=20)
    losses = [s.loss for s in tr.history]
    # robust to batch-to-batch variance from dynamic sizes
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
    assert all(np.isfinite(l) for l in losses)


def test_mimose_loss_identical_to_baseline(setup):
    """Paper Fig. 15: remat changes memory, not math."""
    cfg, lm, params = setup
    mimose = MimosePlanner(lm, _budget(lm, params, 0.4),
                           warmup_samples=2, quantum=32)
    none = NonePlanner(lm)
    tr_m, _ = _train(lm, params, mimose, n=8, seed=11)
    tr_n, _ = _train(lm, params, none, n=8, seed=11)
    lm_losses = [s.loss for s in tr_m.history]
    ln_losses = [s.loss for s in tr_n.history]
    np.testing.assert_allclose(lm_losses, ln_losses, rtol=1e-4)
    assert any(s.remat_units for s in tr_m.history)   # mimose did remat


def test_plan_cache_bounds_replanning(setup):
    """Paper Table 2: the planner runs dozens of times per epoch, not
    once per iteration."""
    cfg, lm, params = setup
    planner = MimosePlanner(lm, _budget(lm, params, 0.5),
                            warmup_samples=2, quantum=64)
    tr, _ = _train(lm, params, planner, n=20)
    assert planner.stats["cache_hits"] > planner.stats["cache_misses"]
    warm = [s.plan_time_s for s in tr.history if s.plan_time_s < 0.05]
    assert warm and float(np.mean(warm)) < 5e-3


def test_plans_respect_budget_across_unseen_sizes(setup):
    cfg, lm, params = setup
    budget = _budget(lm, params, 0.55)
    fixed = fixed_train_bytes(params)
    planner = MimosePlanner(lm, budget, warmup_samples=3, quantum=16)
    col = ShuttlingCollector(lm)
    for S in (32, 64, 96):
        planner.plan(params, {"tokens": jnp.ones((4, S), jnp.int32)})
    for S in (48, 80, 128, 160):
        batch = {"tokens": jnp.ones((4, S), jnp.int32)}
        mask, _ = planner.plan(params, batch)
        truth = col.collect(params, batch).activation_vector()
        saved = sum(t for t, m in zip(truth, mask) if not m) + fixed
        assert saved <= budget * 1.02


def test_dtr_overhead_exceeds_mimose(setup):
    """Paper Fig. 5 / §6.2: DTR replans every iteration; Mimose caches."""
    cfg, lm, params = setup
    budget = _budget(lm, params, 0.4)
    dtr = DTRSimPlanner(lm, budget)
    mi = MimosePlanner(lm, budget, warmup_samples=2, quantum=64)
    batch = {"tokens": jnp.ones((4, 96), jnp.int32)}
    for _ in range(10):
        dtr.plan(params, batch)
        mi.plan(params, batch)
    assert dtr.stats["replans"] == 10
    assert mi.stats["cache_hits"] == 9


def test_encdec_and_vlm_train_with_planner():
    for arch in ("seamless_m4t_large_v2", "qwen2_vl_7b"):
        cfg = get_config(arch).reduced(dtype="float32")
        lm = build_model(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        planner = MimosePlanner(lm, budget_bytes=1e12, warmup_samples=1)
        extra = {}
        if cfg.family == "encdec":
            extra["frames"] = lambda B, S: np.zeros((B, S, cfg.d_model),
                                                    np.float32)
        if cfg.family == "vlm":
            extra["vision_embeds"] = lambda B, S: np.zeros(
                (B, cfg.vision_tokens, cfg.d_model), np.float32)
        tr = Trainer(lm, planner, AdamW(lr=1e-3))
        batches = make_batches("swag", batch_size=2,
                               vocab_size=cfg.vocab_size, num_batches=3,
                               quantum=64, seed=0, extra=extra)
        p, _ = tr.run(params, batches)
        assert np.isfinite(tr.history[-1].loss)


def test_checkpoint_roundtrip(setup, tmp_path):
    cfg, lm, params = setup
    planner = NonePlanner(lm)
    tr, p1 = _train(lm, params, planner, n=3)
    path = str(tmp_path / "state.msgpack")
    ckpt.save(path, p1)
    p2 = ckpt.load(path, p1)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_generation_runs(setup):
    cfg, lm, params = setup
    out = generate(lm, params, jnp.ones((2, 4), jnp.int32), 5)
    assert out.shape == (2, 5)
    assert bool(jnp.all(out >= 0)) and bool(jnp.all(out < cfg.vocab_size))


def test_input_size_distributions_match_paper_ranges():
    for name, (lo, hi) in {"swag": (35, 141), "squad": (153, 512),
                           "qqp": (30, 332)}.items():
        d = DISTRIBUTIONS[name]
        s = d.sample(np.random.default_rng(0), 2000)
        assert s.min() >= lo and s.max() <= hi
        assert len(np.unique(s)) > 10          # genuinely dynamic
