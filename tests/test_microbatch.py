"""Tests for adaptive microbatching (ISSUE 5): batch splitting +
token-weighted gradient accumulation numerics (attention and mamba2,
ragged included), the joint (k, action-plan) scheduler search and its
never-worse floor, simulator microbatch replay, planner threading and
cache keys, trainer execution + stats, the chunked prefill serve fix,
the engine-report microbatch column, and the summary zero-guard."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.actions import Action
from repro.core import (DTRSimPlanner, MimosePlanner, ShuttlingCollector,
                        SublinearPlanner, greedy_plan, greedy_plan_adaptive,
                        simulate, simulate_sharded)
from repro.core.planner import fixed_train_bytes
from repro.launch.report import engine_report
from repro.models.lm import build_model
from repro.models.registry import get_config
from repro.optim.adamw import AdamW
from repro.train.accumulate import accumulated_grads, split_batch
from repro.train.serve import generate, prefill_into_cache
from repro.train.trainer import Trainer

PCIE = 16e9


def _ragged_batch(B, S, vocab, seed=0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(S // 4, S + 1, B).astype(np.int32)
    lens[0] = S                              # keep the bucket honest
    tokens = rng.integers(1, vocab, (B, S)).astype(np.int32)
    w = (np.arange(S)[None, :] < lens[:, None]).astype(np.float32)
    tokens = tokens * w.astype(np.int32)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = 0
    return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels),
            "weights": jnp.asarray(w), "lengths": jnp.asarray(lens)}


@pytest.fixture(scope="module")
def attn_setup():
    cfg = get_config("bert_base_paper").reduced(
        num_layers=4, d_model=128, d_ff=256, vocab_size=512,
        dtype="float32")
    lm = build_model(cfg)
    return cfg, lm, lm.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def mamba_setup():
    cfg = get_config("mamba2_1p3b").reduced(
        num_layers=2, d_model=64, d_ff=128, vocab_size=256,
        dtype="float32")
    lm = build_model(cfg)
    return cfg, lm, lm.init(jax.random.PRNGKey(1))


# ---------------------------------------------------------------------------
# split_batch
# ---------------------------------------------------------------------------

def test_split_batch_shapes_and_lengths():
    b = _ragged_batch(6, 32, 100)
    mbs = split_batch(b, 3)
    assert mbs["tokens"].shape == (3, 2, 32)
    assert mbs["lengths"].shape == (3, 2)
    np.testing.assert_array_equal(
        np.asarray(mbs["lengths"]).reshape(-1), np.asarray(b["lengths"]))


def test_split_batch_pads_non_divisor_with_inert_rows():
    b = _ragged_batch(5, 16, 100)
    mbs = split_batch(b, 2)                  # 5 -> 6 rows, 2 x 3
    assert mbs["tokens"].shape == (2, 3, 16)
    flat_w = np.asarray(mbs["weights"]).reshape(6, 16)
    flat_l = np.asarray(mbs["lengths"]).reshape(6)
    assert flat_w[5].sum() == 0.0            # pad row carries no weight
    assert flat_l[5] == 0                    # ...and zero length


def test_split_batch_materialises_missing_weights():
    b = {"tokens": jnp.ones((3, 8), jnp.int32),
         "labels": jnp.ones((3, 8), jnp.int32)}
    mbs = split_batch(b, 2)                  # 3 -> 4 rows
    w = np.asarray(mbs["weights"]).reshape(4, 8)
    assert w[:3].sum() == 3 * 8 and w[3].sum() == 0.0


# ---------------------------------------------------------------------------
# accumulation numerics: k-microbatch scan == full-batch step (fp32)
# ---------------------------------------------------------------------------

def _assert_accumulation_matches(lm, params, batch, k):
    (l0, m0), g0 = jax.value_and_grad(
        lambda p: lm.loss(p, batch), has_aux=True)(params)
    l1, m1, g1 = accumulated_grads(lm, params, batch, k)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(m0["tokens"]), float(m1["tokens"]))
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6)


@pytest.mark.parametrize("k", [2, 4])
def test_accumulation_matches_full_batch_attention(attn_setup, k):
    _, lm, params = attn_setup
    batch = {"tokens": jnp.ones((4, 48), jnp.int32),
             "labels": jnp.ones((4, 48), jnp.int32)}
    _assert_accumulation_matches(lm, params, batch, k)


def test_accumulation_matches_full_batch_ragged_attention(attn_setup):
    """Ragged batch: lengths (and weights) split alongside tokens, and
    the token-weighted accumulation reproduces the global weighted mean
    even though the microbatch weights are unequal."""
    _, lm, params = attn_setup
    _assert_accumulation_matches(lm, params, _ragged_batch(4, 48, 512), 2)


@pytest.mark.parametrize("k", [2, 3])
def test_accumulation_matches_full_batch_mamba2(mamba_setup, k):
    _, lm, params = mamba_setup
    _assert_accumulation_matches(lm, params, _ragged_batch(6, 32, 256,
                                                           seed=3), k)


def test_accumulation_moe_all_pad_microbatch_inert():
    """MoE regression: an all-pad microbatch (batch-axis padding when
    k does not divide B) must contribute NOTHING — without the w_raw
    guard its load-balance aux would enter with clamped weight 1."""
    cfg = get_config("granite_moe_1b_a400m").reduced(
        num_layers=2, d_model=64, d_ff=128, vocab_size=128,
        dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(2))
    batch = {"tokens": jnp.ones((5, 16), jnp.int32),
             "labels": jnp.ones((5, 16), jnp.int32),
             "weights": jnp.ones((5, 16), jnp.float32)}
    # k=2: rows pad 5 -> 6, no all-pad microbatch — the reference
    l_ref, m_ref, _ = accumulated_grads(lm, params, batch, 2)
    # k=4: rows pad 5 -> 8, the last microbatch is 2 pad rows
    l4, m4, g4 = accumulated_grads(lm, params, batch, 4)
    assert np.isfinite(float(l4))
    assert float(m4["tokens"]) == float(m_ref["tokens"]) == 5 * 16
    for g in jax.tree_util.tree_leaves(g4):
        assert np.all(np.isfinite(np.asarray(g)))


def test_accumulation_with_action_plan(attn_setup):
    """REMAT/OFFLOAD actions change placement, never values — the
    accumulated step under a plan still matches the full-batch step."""
    _, lm, params = attn_setup
    batch = _ragged_batch(4, 48, 512, seed=5)
    plan = (Action.REMAT, Action.KEEP, Action.OFFLOAD, Action.REMAT)
    (l0, _), g0 = jax.value_and_grad(
        lambda p: lm.loss(p, batch), has_aux=True)(params)
    l1, _, g1 = accumulated_grads(lm, params, batch, 2, actions=plan)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# simulator microbatch replay
# ---------------------------------------------------------------------------

def test_simulate_microbatch_scales_totals_not_peak():
    act = [100.0, 100.0]
    s1 = simulate(act, [True, True], 10.0, flops=[1e9, 1e9])
    s2 = simulate(act, [True, True], 10.0, flops=[1e9, 1e9],
                  microbatch=2, accum_overhead_s=1e-3)
    assert s2.peak_bytes == s1.peak_bytes        # per-microbatch vectors
    assert s2.recompute_flops == 2 * s1.recompute_flops
    assert s2.microbatches == 2
    assert s2.accum_overhead_s == pytest.approx(1e-3)
    assert s2.step_overhead_s == pytest.approx(
        2 * s1.recompute_time_s + 1e-3)
    sh = simulate_sharded(act, [True, True], 10.0, 4, flops=[1e9, 1e9],
                          microbatch=3)
    assert sh.microbatches == 3


def test_simulate_default_is_k1_and_unchanged():
    act = [5.0, 7.0, 11.0]
    s = simulate(act, [True, False, True], 3.0)
    assert s.microbatches == 1 and s.accum_overhead_s == 0.0
    assert s.step_overhead_s == s.recompute_time_s


# ---------------------------------------------------------------------------
# joint (k, action-plan) scheduler search
# ---------------------------------------------------------------------------

def _vecs(act, out, off, fl):
    """vectors_of_k from exact 1/k scaling (batch-linear toy units)."""
    def f(k):
        return {"est_mem": act / k, "output_bytes": out / k,
                "offload_bytes": off / k, "flops": fl / k}
    return f


def test_adaptive_k1_identical_to_plain_greedy():
    rng = np.random.default_rng(0)
    act = rng.uniform(1e6, 1e8, 12)
    fl = rng.uniform(1e9, 1e12, 12)
    budget = act.sum() * 0.5
    a = greedy_plan_adaptive(_vecs(act, act * 0.1, act * 0.8, fl),
                             budget, 0.0, max_microbatches=1)
    b = greedy_plan(act, budget, 0.0, flops=fl, output_bytes=act * 0.1,
                    offload_bytes=act * 0.8)
    assert a.actions == b.actions and a.microbatch == 1


def test_adaptive_escalates_k_when_k1_infeasible():
    """A budget below the k=1 global-minimum footprint (exhaustive over
    every action plan) is reachable only by splitting: the search picks
    k > 1, not infeasibility."""
    import itertools
    act = np.full(6, 1e7)
    out = np.full(6, 1e5)
    off = act * 0.8                          # 20% residue stays on device
    fl = np.full(6, 1e10)
    # every k=1 plan keeps residues/checkpoints + the executing unit's
    # transient working set on device — the exhaustive minimum:
    k1_floor = min(simulate(act, plan, 0.0, out, fl,
                            offload_bytes=off).peak_bytes
                   for plan in itertools.product((0, 1, 2), repeat=6))
    budget = 0.8 * k1_floor
    plan = greedy_plan_adaptive(_vecs(act, out, off, fl), budget, 0.0,
                                max_microbatches=4)
    assert plan.microbatch > 1
    v = _vecs(act, out, off, fl)(plan.microbatch)
    sim = simulate(v["est_mem"], plan.actions, 0.0, v["output_bytes"],
                   v["flops"], offload_bytes=v["offload_bytes"],
                   microbatch=plan.microbatch)
    assert sim.fits(budget)


def test_adaptive_never_worse_than_k1_randomized():
    """The floor property: k=1 always competes, so at equal budget the
    adaptive choice never has higher simulated step overhead."""
    rng = np.random.default_rng(11)
    exercised = 0
    for trial in range(40):
        n = int(rng.integers(2, 16))
        act = rng.uniform(1e5, 1e7, n)
        out = act * rng.uniform(0.01, 0.3, n)
        off = act * rng.uniform(0.5, 1.0, n)
        fl = rng.uniform(1e8, 1e12, n)
        budget = float(rng.uniform(0.3, 1.2)) * act.sum() \
            + 2 * act.max() + out.max()
        vf = _vecs(act, out, off, fl)
        p1 = greedy_plan_adaptive(vf, budget, 0.0, max_microbatches=1)
        pk = greedy_plan_adaptive(vf, budget, 0.0, max_microbatches=4)

        def replay(p):
            v = vf(p.microbatch)
            return simulate(v["est_mem"], p.actions, 0.0,
                            v["output_bytes"], v["flops"],
                            offload_bytes=v["offload_bytes"],
                            microbatch=p.microbatch,
                            accum_overhead_s=5e-4)
        s1, sk = replay(p1), replay(pk)
        if s1.fits(budget):
            exercised += 1
            assert sk.fits(budget), trial
            assert sk.step_overhead_s <= s1.step_overhead_s + 1e-12, trial
    assert exercised >= 10


def test_adaptive_prefers_smaller_k_on_ties():
    act = np.full(4, 1e6)
    plan = greedy_plan_adaptive(_vecs(act, act * 0.1, act, act * 0.0 + 1e9),
                                1e18, 0.0, max_microbatches=4)
    assert plan.microbatch == 1              # ample budget: no split


def test_adaptive_charges_pad_overhead():
    """A candidate split that wastes compute on batch-axis pad rows
    loses to an equally feasible split without the waste."""
    act = np.full(4, 1e7)
    fl = np.full(4, 1e9)
    base = _vecs(act, act * 0.1, act * 0.9, fl)

    def vf(k):
        v = dict(base(k))
        v["pad_overhead_s"] = 1.0 if k == 2 else 0.0   # k=2 pads rows
        return v

    budget = 1.8e7          # below the k=1 floor; k=2 and k=3 both fit
    plan = greedy_plan_adaptive(vf, budget, 0.0, candidate_ks=[1, 2, 3])
    assert plan.microbatch == 3              # waste-free split wins
    # without the waste term the smaller split would win the tie-break
    plan = greedy_plan_adaptive(base, budget, 0.0, candidate_ks=[1, 2, 3])
    assert plan.microbatch == 2


def test_planner_pad_waste_priced_for_non_divisor_k(attn_setup):
    _, lm, _ = attn_setup
    planner = MimosePlanner(lm, 1e12, max_microbatches=3)
    batch = {"tokens": jnp.ones((8, 16), jnp.int32)}
    fl = np.full(4, 1e9)
    assert planner.pad_waste_s(batch, 2, fl) == 0.0       # 8 % 2 == 0
    w3 = planner.pad_waste_s(batch, 3, fl)                # 8 -> 9 rows
    assert w3 > 0.0
    assert planner.pad_waste_s(batch, 3, None) == 0.0     # byte-only


# ---------------------------------------------------------------------------
# planner threading
# ---------------------------------------------------------------------------

def test_mimose_picks_split_for_tight_budget(attn_setup):
    _, lm, params = attn_setup
    batch = {"tokens": jnp.ones((8, 64), jnp.int32),
             "labels": jnp.ones((8, 64), jnp.int32)}
    col = ShuttlingCollector(lm).collect(params, batch)
    act, out, off = (col.activation_vector(), col.output_vector(),
                     col.offloadable_vector())
    fixed = fixed_train_bytes(params)
    k1_floor = simulate(act, [2] * len(act), fixed, out,
                        offload_bytes=off).peak_bytes
    budget = 0.5 * (fixed + k1_floor)
    planner = MimosePlanner(lm, budget, quantum=32, warmup_samples=1,
                            offload=True, max_microbatches=4)
    mask, info = planner.plan(params, batch)
    assert info.plan.microbatch > 1
    # cache key embeds the knob: a second plan() is a pure hit
    _, info2 = planner.plan(params, batch)
    assert info2.cache_hit and info2.plan.microbatch == info.plan.microbatch


def test_plan_cache_key_includes_max_microbatches(attn_setup):
    _, lm, params = attn_setup
    batch = {"tokens": jnp.ones((4, 64), jnp.int32),
             "labels": jnp.ones((4, 64), jnp.int32)}
    p1 = MimosePlanner(lm, 1e12, quantum=32, warmup_samples=1)
    p2 = MimosePlanner(lm, 1e12, quantum=32, warmup_samples=1,
                       max_microbatches=4)
    assert p1.plan_key(batch) != p2.plan_key(batch)
    assert p1.plan_key(batch)[:2] == p2.plan_key(batch)[:2]


def test_candidate_ks_capped_at_batch_size(attn_setup):
    _, lm, _ = attn_setup
    planner = MimosePlanner(lm, 1e12, max_microbatches=8)
    batch = {"tokens": jnp.ones((3, 16), jnp.int32)}
    assert planner.candidate_microbatches(batch) == [1, 2, 3]


def test_sublinear_and_dtr_thread_max_microbatches(attn_setup):
    _, lm, params = attn_setup
    batch = {"tokens": jnp.ones((4, 64), jnp.int32),
             "labels": jnp.ones((4, 64), jnp.int32)}
    sub = SublinearPlanner(lm, 1e12, max_input_size=4 * 128,
                           warmup_samples=2, max_microbatches=2)
    _, info = sub.plan(params, batch)
    assert info.plan.microbatch in (1, 2)    # ample budget: 1 expected
    assert info.plan.microbatch == 1
    # DTR escalates only when evict-everything cannot fit
    col = ShuttlingCollector(lm).collect(params, batch)
    fixed = fixed_train_bytes(params)
    tight = fixed + 1.5 * float(col.activation_vector().max())
    dtr = DTRSimPlanner(lm, tight, max_microbatches=4)
    _, info = dtr.plan(params, batch)
    assert info.plan.microbatch > 1
    ample = DTRSimPlanner(lm, 1e15, max_microbatches=4)
    _, info = ample.plan(params, batch)
    assert info.plan.microbatch == 1


# ---------------------------------------------------------------------------
# trainer execution + stats + report column
# ---------------------------------------------------------------------------

def test_trainer_runs_accumulated_step_end_to_end(attn_setup):
    _, lm, params = attn_setup
    batch = _ragged_batch(8, 60, 512, seed=7)
    col = ShuttlingCollector(lm).collect(
        params, {"tokens": jnp.ones((8, 64), jnp.int32)})
    act, out, off = (col.activation_vector(), col.output_vector(),
                     col.offloadable_vector())
    fixed = fixed_train_bytes(params)
    k1_floor = simulate(act, [2] * len(act), fixed, out,
                        offload_bytes=off).peak_bytes
    budget = 0.5 * (fixed + k1_floor)
    planner = MimosePlanner(lm, budget, quantum=32, warmup_samples=1,
                            offload=True, max_microbatches=2)
    tr = Trainer(lm, planner, AdamW(lr=1e-3))
    p = jax.tree_util.tree_map(jnp.copy, params)
    opt_state = tr.optimizer.init(p)
    for _ in range(2):
        p, opt_state, loss = tr.step(p, opt_state, dict(batch))
        assert np.isfinite(loss)
    st = tr.history[-1]
    assert st.microbatches == 2
    s = tr.summary()
    assert s["mean_microbatches"] == 2.0
    # the report's per-bucket table shows where accumulation kicked in
    rep = engine_report(tr, planner)
    assert "| k |" in rep.splitlines()[0]
    bucket = tr.history[-1].bucket
    assert f"| {bucket} | 2 | 2 |" in rep


def test_trainer_jit_cache_keys_on_microbatch(attn_setup):
    """Same bucket + same actions but a different split must compile
    separately (the accumulated step is a different executable)."""
    _, lm, params = attn_setup
    planner = MimosePlanner(lm, 1e12, quantum=32, warmup_samples=1)
    tr = Trainer(lm, planner, AdamW(lr=1e-3))
    batch = tr._prepare({"tokens": np.ones((4, 32), np.int32),
                         "labels": np.ones((4, 32), np.int32)})
    mask = (False,) * lm.num_plan_units()
    assert tr._step_key(mask, batch, 1) != tr._step_key(mask, batch, 2)


def test_padded_tokens_count_batch_axis_padding(attn_setup):
    """A non-divisor split computes ceil(B/k)*k rows — the padding
    accounting must count what actually ran, not the unsplit shape."""
    from repro.core.planner import NonePlanner
    _, lm, params = attn_setup

    class ForcedSplit(NonePlanner):
        def plan(self, p, batch):
            mask, info = super().plan(p, batch)
            info.plan.microbatch = 3
            return mask, info

    tr = Trainer(lm, ForcedSplit(lm), AdamW(lr=1e-3), bucket_pad=False)
    p = jax.tree_util.tree_map(jnp.copy, params)
    opt_state = tr.optimizer.init(p)
    p, opt_state, _ = tr.step(p, opt_state, {
        "tokens": np.ones((8, 16), np.int32),
        "labels": np.ones((8, 16), np.int32)})
    st = tr.history[-1]
    assert st.microbatches == 3
    assert st.padded_tokens == 9 * 16        # 8 rows padded to 9


def test_summary_zeroed_throughput_without_warm_steps(attn_setup):
    """Satellite: a run where every step compiled has no warm-rate
    evidence — summary() returns zeroed throughput instead of a rate
    computed from compile-dominated steps (and never raises)."""
    _, lm, params = attn_setup
    planner = MimosePlanner(lm, 1e12, quantum=32, warmup_samples=1)
    tr = Trainer(lm, planner, AdamW(lr=1e-3))
    p = jax.tree_util.tree_map(jnp.copy, params)
    opt_state = tr.optimizer.init(p)
    p, opt_state, _ = tr.step(p, opt_state, {
        "tokens": np.ones((2, 32), np.int32),
        "labels": np.ones((2, 32), np.int32)})
    s = tr.summary()                          # single step == compile step
    assert s["steps"] == 1 and s["compiles"] == 1
    assert s["tokens_per_s"] == 0.0
    assert s["padded_tokens_per_s"] == 0.0
    assert s["mean_step_s"] == 0.0 and s["pad_fraction"] == 0.0
    assert np.isfinite(s["final_loss"])


# ---------------------------------------------------------------------------
# chunked prefill (serve satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["bert_base_paper", "mamba2_1p3b",
                                  "hymba_1p5b"])
def test_chunked_prefill_generation_unchanged(arch):
    cfg = get_config(arch).reduced(num_layers=2, d_model=64, d_ff=128,
                                   vocab_size=128, dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 13), 1, 128)
    l1, c1 = prefill_into_cache(lm, params, prompt,
                                lm.init_cache(2, 17), chunk=1)
    l2, c2 = prefill_into_cache(lm, params, prompt,
                                lm.init_cache(2, 17), chunk=5)
    # final-position logits match the token-by-token reference...
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               rtol=2e-5, atol=2e-5)
    # ...and so do the advanced caches
    for a, b in zip(jax.tree_util.tree_leaves(c1),
                    jax.tree_util.tree_leaves(c2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)
    # generation output is unchanged end to end
    g1 = generate(lm, params, prompt, 4, prefill_chunk=1)
    g2 = generate(lm, params, prompt, 4, prefill_chunk=5)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


def test_chunked_prefill_dispatch_count(attn_setup, monkeypatch):
    """The point of the fix: ceil(S/chunk) decode dispatches, not S."""
    import repro.train.serve as serve
    _, lm, params = attn_setup
    prompt = jnp.ones((1, 33), jnp.int32)
    cache = lm.init_cache(1, 33)
    calls = {"n": 0}
    real_jit = jax.jit

    def counting_jit(fn, **kw):
        jfn = real_jit(fn, **kw)

        def wrapped(*a, **k):
            calls["n"] += 1                   # one jitted step dispatch
            return jfn(*a, **k)
        return wrapped

    monkeypatch.setattr(serve.jax, "jit", counting_jit)
    serve.prefill_into_cache(lm, params, prompt, cache, chunk=8)
    assert calls["n"] == 5                    # ceil(33 / 8), was 33
