"""Tests for the continuous-batching serve engine (ISSUE 9): trace
equivalence vs sequential generation across LM families, input-aware
admission under an HBM budget (never exceed, defer-then-serve, reject
what can never fit), the batched cache-slot API, vector-index decode,
the cached serve step's compile accounting, the admission estimator's
accuracy on unsampled buckets, and trace-generator determinism.

All engine tests carry ``-m serve`` (own CI job; tier-1 excludes them).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.trace import TraceRequest, gen_trace
from repro.launch.report import serve_report
from repro.models.lm import build_model
from repro.models.registry import get_config
from repro.train.engine import ServeEngine, cache_leaf_bytes
from repro.train.serve import cached_serve_step, generate

pytestmark = pytest.mark.serve


def _setup(arch, seed=0, **kw):
    red = dict(num_layers=2, d_model=64, d_ff=128, vocab_size=256,
               dtype="float32")
    red.update(kw)
    cfg = get_config(arch).reduced(**red)
    lm = build_model(cfg)
    return cfg, lm, lm.init(jax.random.PRNGKey(seed))


@pytest.fixture(scope="module")
def dense_setup():
    return _setup("qwen3_1p7b")


@pytest.fixture(scope="module")
def ssm_setup():
    return _setup("mamba2_1p3b", seed=1)


def _mixed_trace(cfg, n=6, new=8, rate=0.0, seed=3):
    return gen_trace(num_requests=n, vocab_size=cfg.vocab_size,
                     rate_rps=rate, max_new_tokens=new, min_new_tokens=4,
                     prompt_scale=0.2, seed=seed)


# ---------------------------------------------------------------------------
# tentpole: engine output == sequential generate, per request


@pytest.mark.parametrize("family", ["dense", "ssm", "hybrid"])
def test_engine_matches_sequential_generate(family, dense_setup, ssm_setup):
    """Every request of a mixed-length greedy trace decodes
    token-for-token identically to a one-request ``generate`` at the
    engine's bucketed cache geometry — across attention, SSM, and
    hybrid cache families."""
    if family == "dense":
        cfg, lm, params = dense_setup
    elif family == "ssm":
        cfg, lm, params = ssm_setup
    else:
        cfg, lm, params = _setup("hymba_1p5b", seed=2)
    trace = _mixed_trace(cfg)
    eng = ServeEngine(lm, params, hbm_bytes=2e9, quantum=32, max_slots=4,
                      prefill_chunk=8, decode_steps=2)
    res = eng.run(trace)
    assert res.completed == len(trace)
    lens = {len(r.prompt) for r in trace}
    assert len(lens) > 1, "trace must mix prompt lengths"
    for r in trace:
        want = np.asarray(generate(lm, params, jnp.asarray(r.prompt[None]),
                                   r.max_new_tokens,
                                   cache_len=eng.bucket_of(r)))[0]
        got = np.asarray(res.outputs[r.rid])
        np.testing.assert_array_equal(got, want, err_msg=f"rid {r.rid}")


def test_vector_index_decode_matches_scalar(dense_setup):
    """``decode_step`` with a (B,) index vector of equal entries is the
    scalar-index step — the per-row scatter path is numerically the
    dynamic-slice path."""
    cfg, lm, params = dense_setup
    B, S = 2, 11
    tok = jax.random.randint(jax.random.PRNGKey(5), (B, 1), 1,
                             cfg.vocab_size)
    cache_s = lm.init_cache(B, 32)
    cache_v = jax.tree_util.tree_map(jnp.copy, cache_s)
    lg_s, cache_s = lm.decode_step(params, tok, cache_s, S)
    lg_v, cache_v = lm.decode_step(params, tok, cache_v,
                                   jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_v),
                               rtol=1e-6, atol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(cache_s),
                    jax.tree_util.tree_leaves(cache_v)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_cache_insert_extract_evict_roundtrip(dense_setup):
    """One-row staging caches survive a pool insert/extract round trip
    bit-exactly; evict zeroes exactly the evicted slot."""
    cfg, lm, params = dense_setup
    pool = lm.init_cache(3, 16)
    row = jax.tree_util.tree_map(
        lambda l: jnp.ones_like(l) * 0.5,
        lm.init_cache(1, 16))
    pool = lm.cache_insert(pool, row, 1)
    back = lm.cache_extract(pool, 1)
    for a, b in zip(jax.tree_util.tree_leaves(back),
                    jax.tree_util.tree_leaves(row)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    other = lm.cache_extract(pool, 0)       # neighbours untouched
    assert all(float(jnp.abs(l).max()) == 0.0
               for l in jax.tree_util.tree_leaves(other))
    pool = lm.cache_evict(pool, 1)
    gone = lm.cache_extract(pool, 1)
    assert all(float(jnp.abs(l).max()) == 0.0
               for l in jax.tree_util.tree_leaves(gone))


# ---------------------------------------------------------------------------
# admission under the HBM budget


def test_admission_never_exceeds_budget(dense_setup):
    """Under a budget tight enough to force deferrals, the predicted
    peak bounds the actual allocated peak, and both stay within the
    budget — admit-before-allocate means zero admission OOMs."""
    cfg, lm, params = dense_setup
    trace = _mixed_trace(cfg, n=8, seed=11)
    eng = ServeEngine(lm, params, hbm_bytes=1e9, quantum=32, max_slots=2,
                      prefill_chunk=8)
    # tightest budget that still fits params + one admitted request
    tight = (eng.param_bytes + eng.slot_bytes(64) * 3
             + eng.prefill_chunk * eng._token_ws * 2)
    eng2 = ServeEngine(lm, params, hbm_bytes=tight, quantum=32,
                       max_slots=2, prefill_chunk=8)
    res = eng2.run(trace)
    assert res.stats["deferrals"] > 0, "budget was not tight"
    assert res.completed == len(trace)
    assert (res.stats["peak_actual_bytes"]
            <= res.stats["peak_predicted_bytes"] <= tight)


def test_deferred_requests_eventually_served(dense_setup):
    """An over-subscribed burst (every request at t=0, budget fits ~1
    in flight) defers most of the queue but completes all of it."""
    cfg, lm, params = dense_setup
    trace = _mixed_trace(cfg, n=5, seed=13)
    probe = ServeEngine(lm, params, hbm_bytes=1e9, quantum=32)
    tight = (probe.param_bytes + probe.slot_bytes(64) * 3
             + probe.prefill_chunk * probe._token_ws * 2)
    eng = ServeEngine(lm, params, hbm_bytes=tight, quantum=32,
                      max_slots=4, prefill_chunk=8)
    res = eng.run(trace)
    assert res.stats["deferrals"] > 0
    assert res.rejected == 0
    assert res.completed == len(trace)
    assert sorted(res.outputs) == sorted(r.rid for r in trace)


def test_request_that_never_fits_is_rejected_not_crashed(dense_setup):
    """A request whose single slot exceeds the whole budget is REJECTED
    with the run completing normally — never an allocation failure."""
    cfg, lm, params = dense_setup
    probe = ServeEngine(lm, params, hbm_bytes=1e9, quantum=32)
    small = TraceRequest(rid=0, arrival_s=0.0,
                         prompt=np.arange(1, 9, dtype=np.int32),
                         max_new_tokens=4)
    huge = TraceRequest(rid=1, arrival_s=0.0,
                        prompt=np.ones(4096, np.int32),
                        max_new_tokens=64)
    tight = (probe.param_bytes + probe.slot_bytes(32) * 4
             + probe.prefill_chunk * probe._token_ws * 2)
    eng = ServeEngine(lm, params, hbm_bytes=tight, quantum=32,
                      max_slots=2, prefill_chunk=8)
    res = eng.run([small, huge])
    assert res.completed == 1 and 0 in res.outputs
    assert res.rejected == 1
    assert res.stats["peak_actual_bytes"] <= tight


def test_budget_below_params_raises(dense_setup):
    cfg, lm, params = dense_setup
    with pytest.raises(ValueError, match="parameter bytes"):
        ServeEngine(lm, params, hbm_bytes=1.0)


def test_encdec_family_rejected():
    cfg, lm, params = _setup("seamless_m4t_large_v2", seed=4,
                             encoder_layers=1, num_layers=1)
    assert lm.kind == "dec"
    with pytest.raises(ValueError, match="decoder-only"):
        ServeEngine(lm, params, hbm_bytes=1e9)


# ---------------------------------------------------------------------------
# estimator accuracy


def test_estimator_predicts_unseen_buckets(dense_setup, ssm_setup):
    """The admission estimator (PolyEstimator over per-leaf cache
    bytes) matches the eval_shape ground truth within 5% on buckets it
    never sampled — for both linear-in-S (KV) and constant (SSM state)
    cache families."""
    for cfg, lm, params in (dense_setup, ssm_setup):
        eng = ServeEngine(lm, params, hbm_bytes=1e9, quantum=32)
        for bucket in (64, 128, 320):       # warm-fit sampled 32/96/160
            truth = float(cache_leaf_bytes(lm, bucket).sum())
            assert abs(eng.slot_bytes(bucket) - truth) <= 0.05 * truth, \
                (lm.kind, bucket, eng.slot_bytes(bucket), truth)


# ---------------------------------------------------------------------------
# compile accounting


def test_cached_serve_step_is_shared_and_compiles_once(dense_setup):
    """Satellite 1: ``generate``/``prefill_into_cache`` share one jit
    per LM — repeated calls at the same geometry add zero compiles."""
    cfg, lm, params = dense_setup
    prompt = jax.random.randint(jax.random.PRNGKey(6), (1, 12), 1,
                                cfg.vocab_size)
    assert cached_serve_step(lm) is cached_serve_step(lm)
    generate(lm, params, prompt, 4, cache_len=32)
    before = cached_serve_step(lm)._cache_size()
    for _ in range(3):
        generate(lm, params, prompt, 4, cache_len=32)
    assert cached_serve_step(lm)._cache_size() == before


def test_engine_decode_compiles_bounded_by_buckets(dense_setup):
    """Decode geometries stay O(#buckets x #slot-tiers) and strictly
    below #requests, and a second engine over the same LM re-traces
    nothing (executables are cached on the model)."""
    cfg, lm, params = dense_setup
    trace = _mixed_trace(cfg, n=8, seed=17)
    eng = ServeEngine(lm, params, hbm_bytes=2e9, quantum=32, max_slots=4,
                      prefill_chunk=8)
    res = eng.run(trace)
    n_buckets = len({eng.bucket_of(r) for r in trace})
    decode_geoms = res.compile_counts["decode"]
    assert decode_geoms <= n_buckets * len(eng.tiers)
    assert decode_geoms < len(trace)
    before = eng._decode_jit._cache_size()
    eng2 = ServeEngine(lm, params, hbm_bytes=2e9, quantum=32,
                       max_slots=4, prefill_chunk=8)
    assert eng2._decode_jit is eng._decode_jit
    eng2.run(trace)
    assert eng2._decode_jit._cache_size() == before


def test_prefill_chunks_are_powers_of_two(dense_setup):
    """Prefill never traces an arbitrary remainder width: every chunk
    geometry is drawn from the fixed power-of-two candidate set, so
    compile count is O(log max_chunk) per bucket."""
    cfg, lm, params = dense_setup
    trace = _mixed_trace(cfg, n=6, seed=19)
    eng = ServeEngine(lm, params, hbm_bytes=2e9, quantum=32,
                      prefill_chunk=16)
    eng.run(trace)
    widths = {k[2] for k in eng.compile_keys if k[0] == "prefill"}
    assert widths <= {1, 2, 4, 8, 16}, widths


# ---------------------------------------------------------------------------
# trace generator + report


def test_gen_trace_deterministic_and_open_loop():
    a = gen_trace(num_requests=10, vocab_size=128, rate_rps=4.0,
                  max_new_tokens=8, seed=5)
    b = gen_trace(num_requests=10, vocab_size=128, rate_rps=4.0,
                  max_new_tokens=8, seed=5)
    c = gen_trace(num_requests=10, vocab_size=128, rate_rps=4.0,
                  max_new_tokens=8, seed=6)
    assert all(np.array_equal(x.prompt, y.prompt)
               and x.arrival_s == y.arrival_s for x, y in zip(a, b))
    assert any(not np.array_equal(x.prompt, y.prompt)
               for x, y in zip(a, c))
    arr = [r.arrival_s for r in a]
    assert arr == sorted(arr) and arr[-1] > 0.0
    burst = gen_trace(num_requests=4, vocab_size=128, rate_rps=0.0,
                      max_new_tokens=8, seed=5)
    assert all(r.arrival_s == 0.0 for r in burst)
    rt = [TraceRequest.from_json(r.to_json()) for r in a]
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, rt))


def test_serve_report_renders(dense_setup):
    cfg, lm, params = dense_setup
    trace = _mixed_trace(cfg, n=3, seed=23)
    eng = ServeEngine(lm, params, hbm_bytes=2e9, quantum=32)
    res = eng.run(trace)
    text = serve_report(eng, res)
    assert "| metric | value |" in text
    assert "admission" in text and "compiled geometries" in text
    assert f"{res.completed} /" in text
