"""Unit + property tests for the Mimose core (collector/estimator/
scheduler/planner/simulator)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DTRSimPlanner, MimosePlanner, NonePlanner,
                        PolyEstimator, DecisionTreeEstimator,
                        ShuttlingCollector, SublinearPlanner, build_buckets,
                        dtr_simulate, greedy_plan, peak_if_checkpointing_unit,
                        simulate)
from repro.core.planner import fixed_train_bytes
from repro.models.lm import build_model
from repro.models.registry import get_config


# ---------------------------------------------------------------------------
# scheduler (Algorithm 1) properties
# ---------------------------------------------------------------------------

mem_lists = st.lists(st.floats(min_value=1.0, max_value=1e9,
                               allow_nan=False, allow_infinity=False),
                     min_size=1, max_size=64)


@given(mem_lists, st.floats(min_value=0.0, max_value=1e10))
@settings(max_examples=200, deadline=None)
def test_greedy_plan_covers_excess_when_feasible(est, budget):
    plan = greedy_plan(est, budget)
    total = sum(est)
    excess = total - budget
    if excess <= 0:
        assert not any(plan.remat)            # under budget -> no remat
    else:
        covered = sum(e for e, r in zip(est, plan.remat) if r)
        # plan covers the excess whenever that is possible at all
        if excess <= total:
            assert covered >= min(excess, total) - 1e-6


@given(mem_lists)
@settings(max_examples=100, deadline=None)
def test_greedy_plan_budget_zero_remats_everything(est):
    plan = greedy_plan(est, 0.0)
    assert all(plan.remat)


@given(mem_lists, st.floats(min_value=0.0, max_value=1e10))
@settings(max_examples=200, deadline=None)
def test_greedy_plan_simulated_peak_within_budget(est, budget):
    """If the plan's covered bytes reach the excess, the liveness
    simulator's *end-of-forward* footprint respects the budget."""
    plan = greedy_plan(est, budget)
    saved = sum(e for e, r in zip(est, plan.remat) if not r)
    if plan.excess_bytes > 0 and plan.covered_bytes >= plan.excess_bytes:
        assert saved <= budget + 1e-6


def test_greedy_prefers_earlier_timestamps_in_bucket():
    est = [100.0, 100.0, 100.0, 100.0]
    plan = greedy_plan(est, budget_bytes=250.0)
    # excess 150 -> two units, the two EARLIEST (paper Fig. 11)
    assert plan.remat == [True, True, False, False]


def test_buckets_tolerance_grouping():
    est = [100, 95, 50, 11, 10]
    buckets = build_buckets(est, tol=0.10)
    assert buckets[0] == [0, 1]         # within 10%
    assert buckets[1] == [2]
    assert buckets[2] == [3, 4]


@given(mem_lists)
@settings(max_examples=100, deadline=None)
def test_buckets_partition_all_units(est):
    buckets = build_buckets(est)
    flat = sorted(i for b in buckets for i in b)
    assert flat == list(range(len(est)))


# ---------------------------------------------------------------------------
# estimator
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(min_value=8, max_value=4096), min_size=4,
                max_size=12, unique=True),
       st.floats(min_value=0.0, max_value=10.0),
       st.floats(min_value=0.0, max_value=1e3),
       st.floats(min_value=0.0, max_value=1e6))
@settings(max_examples=50, deadline=None)
def test_poly2_fits_quadratic_exactly(sizes, a, b, c):
    est = PolyEstimator(2, min_samples=3)
    for s in sizes:
        est.add_sample(s, [a * s * s + b * s + c])
    est.fit()
    for s in sizes:
        truth = a * s * s + b * s + c
        assert abs(est.predict(s)[0] - truth) <= max(1e-6 * truth, 1.0)


def test_poly2_beats_poly1_on_attention_curve():
    sizes = np.array([64, 128, 256, 384, 512, 768, 1024])
    truth = 2.0 * sizes ** 2 + 100.0 * sizes           # attention-like
    e1, e2 = PolyEstimator(1, 3), PolyEstimator(2, 3)
    for s, t in zip(sizes[:5], truth[:5]):
        e1.add_sample(s, [t]); e2.add_sample(s, [t])
    t1 = np.stack([[t] for t in truth[5:]])
    assert e2.mape(sizes[5:], t1) < e1.mape(sizes[5:], t1)


def test_tree_estimator_runs():
    t = DecisionTreeEstimator()
    for s in (32, 64, 128, 256):
        t.add_sample(s, [float(s * s)])
    assert t.predict_total(64) > 0


def test_estimator_latency_sub_millisecond():
    est = PolyEstimator(2, 3)
    for s in (64, 128, 256, 512, 1024):
        est.add_sample(s, np.full(24, float(s * s)))
    est.fit()
    import time
    t0 = time.perf_counter()
    for _ in range(100):
        est.predict(333)
    per_call = (time.perf_counter() - t0) / 100
    assert per_call < 1e-3             # paper: ~16 us


# ---------------------------------------------------------------------------
# simulator
# ---------------------------------------------------------------------------

@given(mem_lists)
@settings(max_examples=100, deadline=None)
def test_simulate_remat_all_never_worse_than_none(act):
    none = simulate(act, [False] * len(act))
    full = simulate(act, [True] * len(act))
    assert full.peak_bytes <= none.peak_bytes + 1e-6
    assert none.recompute_bytes == 0.0
    assert full.recompute_bytes == pytest.approx(sum(act))


def test_fig11_checkpointing_last_unit_is_worst():
    act = [100.0] * 12                  # 12 equal encoders (Bert-base)
    peaks = [peak_if_checkpointing_unit(act, i) for i in range(12)]
    assert max(peaks) == peaks[-1]
    assert all(p <= peaks[-1] for p in peaks)


@given(mem_lists, st.floats(min_value=10.0, max_value=1e10))
@settings(max_examples=100, deadline=None)
def test_dtr_sim_plan_ops_positive_when_evicting(act, budget):
    mask, ops = dtr_simulate(act, budget)
    if any(mask):
        assert ops > 0
    # DTR never evicts the most recent tensor
    assert not mask[-1] or len(act) == 1


# randomized (act, out, off, actions) instances for the invariant fuzz
_sim_instances = st.composite(lambda draw: {
    "act": (act := [1.0 + draw(st.floats(min_value=0.0, max_value=1e8,
                                         allow_nan=False,
                                         allow_infinity=False))
                    for _ in range(draw(st.integers(min_value=1,
                                                    max_value=24)))]),
    "out": [0.3 * a * draw(st.floats(min_value=0.0, max_value=1.0,
                                     allow_nan=False,
                                     allow_infinity=False))
            for a in act],
    "off": [1.2 * a * draw(st.floats(min_value=0.0, max_value=1.0,
                                     allow_nan=False,
                                     allow_infinity=False))
            for a in act],
    "fl": [draw(st.floats(min_value=0.0, max_value=1e12,
                          allow_nan=False, allow_infinity=False))
           for _ in act],
    "actions": [draw(st.integers(min_value=0, max_value=2)) for _ in act],
    "fixed": draw(st.floats(min_value=0.0, max_value=1e8,
                            allow_nan=False, allow_infinity=False)),
})()


@given(_sim_instances)
@settings(max_examples=100, deadline=None)
def test_simulate_peak_bounded_by_kept_plus_transient(inst):
    """The liveness peak never exceeds the bytes the plan actually
    keeps plus the bounded per-unit transients: KEEP holds ``act``,
    REMAT only ``out``, OFFLOAD ``act - off`` (the checkpoint streams
    to host); on top ride the forward transient (``act + out`` of one
    unit), the backward restore (``restore + act`` of one unit), and
    the remat-outputs a backward pass can resurrect at once."""
    act, out, off = inst["act"], inst["out"], inst["off"]
    acts = inst["actions"]
    sim = simulate(act, acts, inst["fixed"], out, inst["fl"],
                   offload_bytes=off)
    kept = sum(o if a == 1 else (x - min(f, x) if a == 2 else x)
               for x, o, f, a in zip(act, out, off, acts))
    remat_out = sum(o for o, a in zip(out, acts) if a == 1)
    fwd_transient = max(x + o for x, o in zip(act, out))
    restore = [x if a == 1 else (min(f, x) if a == 2 else 0.0)
               for x, f, a in zip(act, off, acts)]
    bwd_transient = max(r + x for r, x in zip(restore, act))
    bound = (inst["fixed"] + kept + remat_out
             + max(fwd_transient, bwd_transient))
    assert sim.peak_bytes <= bound + 1e-6


@given(_sim_instances)
@settings(max_examples=50, deadline=None)
def test_simulate_agrees_with_sharded_on_1x1_mesh(inst):
    """A 1-device "mesh" is no mesh at all: the per-device replay must
    reproduce the scalar simulator exactly."""
    from repro.core import simulate_sharded
    sim = simulate(inst["act"], inst["actions"], inst["fixed"],
                   inst["out"], inst["fl"], offload_bytes=inst["off"])
    shd = simulate_sharded(inst["act"], inst["actions"], inst["fixed"], 1,
                          inst["out"], inst["fl"],
                          offload_bytes=inst["off"])
    assert shd.peak_bytes_per_device == pytest.approx(sim.peak_bytes)
    assert shd.per_device.recompute_flops == \
        pytest.approx(sim.recompute_flops)
    assert shd.per_device.step_overhead_s == \
        pytest.approx(sim.step_overhead_s)


@given(_sim_instances, st.integers(min_value=2, max_value=6))
@settings(max_examples=50, deadline=None)
def test_simulate_overhead_additive_across_microbatch_k(inst, k):
    """A k-way accumulated step is k sequential microbatches plus the
    accumulation bookkeeping: overhead(k) = k * overhead(1) + (k-1) *
    accum, on the SAME per-microbatch vectors."""
    accum = 5e-4
    one = simulate(inst["act"], inst["actions"], inst["fixed"],
                   inst["out"], inst["fl"], offload_bytes=inst["off"],
                   microbatch=1, accum_overhead_s=0.0)
    many = simulate(inst["act"], inst["actions"], inst["fixed"],
                    inst["out"], inst["fl"], offload_bytes=inst["off"],
                    microbatch=k, accum_overhead_s=accum)
    assert many.step_overhead_s == pytest.approx(
        k * one.step_overhead_s + (k - 1) * accum)
    # splitting never changes the peak at fixed per-microbatch vectors
    assert many.peak_bytes == pytest.approx(one.peak_bytes)


@given(_sim_instances)
@settings(max_examples=50, deadline=None)
def test_simulate_many_matches_scalar_simulate(inst):
    """The batched evaluator the solver's exhaustive fallback leans on
    must agree with the scalar simulator row for row."""
    from repro.core import simulate_many
    rows = [inst["actions"], [0] * len(inst["act"]),
            [1] * len(inst["act"]), [2] * len(inst["act"])]
    bs = simulate_many(inst["act"], rows, inst["fixed"], inst["out"],
                       inst["fl"], offload_bytes=inst["off"],
                       microbatch=2, accum_overhead_s=5e-4)
    for i, row in enumerate(rows):
        sim = simulate(inst["act"], row, inst["fixed"], inst["out"],
                       inst["fl"], offload_bytes=inst["off"],
                       microbatch=2, accum_overhead_s=5e-4)
        assert bs.peak_bytes[i] == pytest.approx(sim.peak_bytes)
        assert bs.step_overhead_s[i] == pytest.approx(sim.step_overhead_s)
        assert bs.recompute_flops[i] == pytest.approx(sim.recompute_flops)
        assert bs.offload_bytes[i] == pytest.approx(sim.offload_bytes)


# ---------------------------------------------------------------------------
# collector + planner integration (small real model)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small():
    cfg = get_config("bert_base_paper").reduced(
        num_layers=4, d_model=128, d_ff=256, vocab_size=512)
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


def _batch(S, B=2, vocab=512):
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32)}


def test_collector_monotone_in_input_size(small):
    _, lm, params = small
    col = ShuttlingCollector(lm)
    totals = [col.collect(params, _batch(S)).total_activation_bytes()
              for S in (32, 64, 128)]
    assert totals[0] < totals[1] < totals[2]


def test_collector_superlinear_attention(small):
    """Doubling seqlen more than doubles activation bytes (quadratic term)."""
    _, lm, params = small
    col = ShuttlingCollector(lm)
    t64 = col.collect(params, _batch(64)).total_activation_bytes()
    t128 = col.collect(params, _batch(128)).total_activation_bytes()
    assert t128 > 2.0 * t64


def test_planner_cache_hit_and_estimator_accuracy(small):
    _, lm, params = small
    fixed = fixed_train_bytes(params)
    col = ShuttlingCollector(lm)
    total128 = col.collect(params, _batch(128)).total_activation_bytes()
    planner = MimosePlanner(lm, fixed + total128 // 2, warmup_samples=3,
                            quantum=32)
    for S in (32, 64, 96):
        planner.plan(params, _batch(S))
    assert planner.estimator.ready
    mask, info = planner.plan(params, _batch(128))
    assert not info.cache_hit and not info.collected   # predicted
    # estimator vs ground truth within 2%
    pred = planner.estimator.predict(2 * 128).sum()
    assert abs(pred - total128) / total128 < 0.02
    mask2, info2 = planner.plan(params, _batch(128))
    assert info2.cache_hit and mask2 == mask


def test_planner_no_remat_when_budget_ample(small):
    _, lm, params = small
    planner = MimosePlanner(lm, budget_bytes=1e12, warmup_samples=1)
    mask, _ = planner.plan(params, _batch(64))
    assert not any(mask)


def test_sublinear_conservative_vs_mimose(small):
    """Static plan at max size remats at least as much as Mimose does for
    a small input (the paper's Fig. 4 waste)."""
    _, lm, params = small
    fixed = fixed_train_bytes(params)
    col = ShuttlingCollector(lm)
    total = col.collect(params, _batch(256)).total_activation_bytes()
    budget = fixed + total // 3
    sub = SublinearPlanner(lm, budget, max_input_size=2 * 256,
                           warmup_samples=3)
    mi = MimosePlanner(lm, budget, warmup_samples=2, quantum=16)
    small_batch = _batch(32)
    m_sub, _ = sub.plan(params, small_batch)
    for S in (32, 64):
        mi.plan(params, _batch(S))
    m_mi, _ = mi.plan(params, small_batch)
    assert sum(m_sub) >= sum(m_mi)


def test_dtr_planner_replans_every_iteration(small):
    _, lm, params = small
    fixed = fixed_train_bytes(params)
    col = ShuttlingCollector(lm)
    total = col.collect(params, _batch(128)).total_activation_bytes()
    dtr = DTRSimPlanner(lm, fixed + total // 2)
    for _ in range(3):
        dtr.plan(params, _batch(128))
    assert dtr.stats["replans"] == 3          # no caching, unlike Mimose


def test_planner_audit_detects_and_fixes_drift(small):
    """Adaptive-estimator extension: a corrupted fit is caught by the
    drift audit and repaired from an exact abstract re-collection."""
    _, lm, params = small
    planner = MimosePlanner(lm, budget_bytes=1e12, warmup_samples=2,
                            quantum=8, audit_every=1)
    for S in (32, 48):
        planner.plan(params, _batch(S))
    assert planner.estimator.ready
    # corrupt the fitted coefficients to force drift
    planner.estimator.fit()
    planner.estimator._coeffs = planner.estimator._coeffs * 3.0
    planner.plan(params, _batch(96))
    assert planner.stats["audits"] >= 1
    assert planner.stats["refits"] >= 1
    # post-refit prediction is accurate again
    col = ShuttlingCollector(lm)
    truth = col.collect(params, _batch(128)).total_activation_bytes()
    pred = planner.estimator.predict(2 * 128).sum()
    assert abs(pred - truth) / truth < 0.05


def test_audit_refit_clears_stale_plan_cache(small):
    """The drift audit must not only refit the estimator — plans built
    from the drifted fit are stale and must leave the cache, and the
    audits/refits counters must advance exactly once for one drift."""
    _, lm, params = small
    planner = MimosePlanner(lm, budget_bytes=1e12, warmup_samples=3,
                            quantum=8, audit_every=1)
    for S in (32, 48, 56):
        planner.plan(params, _batch(S))
    planner.plan(params, _batch(64))        # post-warmup: a cached plan
    stale_keys = set(planner.cache.keys())
    assert stale_keys and planner.stats["refits"] == 0
    audits_before = planner.stats["audits"]
    # corrupt the fitted coefficients to force drift on the next miss
    planner.estimator.fit()
    planner.estimator._coeffs = planner.estimator._coeffs * 3.0
    planner.plan(params, _batch(96))
    assert planner.stats["audits"] == audits_before + 1
    assert planner.stats["refits"] == 1
    # every pre-drift plan was flushed; only the fresh bucket is cached
    assert stale_keys.isdisjoint(set(planner.cache.keys()))
    assert len(planner.cache) == 1


def test_fixed_train_bytes_accounts_adam(small):
    _, lm, params = small
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
    fb = fixed_train_bytes(params)
    assert fb == pytest.approx(n * 4 + n * 4 + 8 * n)   # f32 params


def test_plan_cache_key_includes_roofline_constants(small):
    """Regression: the plan-cache key must carry the roofline knobs
    (``pcie_gbps``, ``offload_overlap``) — a background-solved plan
    priced at one link speed must not be resurrected after a CLI knob
    change re-prices OFFLOAD actions."""
    _, lm, _ = small
    batch = _batch(64)
    base = MimosePlanner(lm, 1e12, quantum=32, warmup_samples=1)
    slow_link = MimosePlanner(lm, 1e12, quantum=32, warmup_samples=1,
                              pcie_gbps=4.0)
    no_overlap = MimosePlanner(lm, 1e12, quantum=32, warmup_samples=1,
                               offload_overlap=0.0)
    assert base.plan_key(batch) != slow_link.plan_key(batch)
    assert base.plan_key(batch) != no_overlap.plan_key(batch)
    # same knobs -> same key; bucket + mesh prefix stays shared
    same = MimosePlanner(lm, 1e12, quantum=32, warmup_samples=1)
    assert base.plan_key(batch) == same.plan_key(batch)
    assert base.plan_key(batch)[:2] == slow_link.plan_key(batch)[:2]
