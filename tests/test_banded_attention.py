"""Banded sliding-window attention vs the masked-full reference —
hypothesis property sweep over geometry."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.layers import (_build_mask, sdpa_banded_local,
                                 sdpa_reference)


@st.composite
def geometries(draw):
    W = draw(st.sampled_from([16, 32, 64]))
    nb = draw(st.integers(min_value=2, max_value=6))
    H = draw(st.sampled_from([1, 2, 4]))
    group = draw(st.sampled_from([1, 2]))
    hd = draw(st.sampled_from([8, 16]))
    B = draw(st.integers(min_value=1, max_value=2))
    return B, nb * W, H * group, H, hd, W


@given(geometries(), st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_banded_equals_masked_full(geom, seed):
    B, S, H, Hkv, hd, W = geom
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    mask = _build_mask(pos, pos, W, False)
    ref = sdpa_reference(q, k, v, mask)
    out = sdpa_banded_local(q, k, v, W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_banded_gradients_match():
    B, S, H, hd, W = 1, 128, 2, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    mask = _build_mask(pos, pos, W, False)
    g1 = jax.grad(lambda q_: (sdpa_banded_local(q_, k, v, W) ** 2).sum())(q)
    g2 = jax.grad(lambda q_: (sdpa_reference(q_, k, v, mask) ** 2).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-3, atol=1e-4)


def test_banded_score_tile_is_smaller():
    """The banded path's residuals scale with S*2W, not S^2."""
    def resid(S, fn, W=32):
        q = jax.ShapeDtypeStruct((1, S, 2, 16), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(S), (1, S))
        if fn == "banded":
            f = lambda a, b, c: sdpa_banded_local(a, b, c, W)
        else:
            mask = _build_mask(pos, pos, W, False)
            f = lambda a, b, c: sdpa_reference(a, b, c, mask)
        vjp = jax.eval_shape(lambda a, b, c: jax.vjp(f, a, b, c)[1], q, q, q)
        return sum(int(np.prod(l.shape)) * 4
                   for l in jax.tree_util.tree_leaves(vjp))
    # full path quadruples residuals when S doubles; banded only doubles
    full_ratio = resid(256, "full") / resid(128, "full")
    band_ratio = resid(256, "banded") / resid(128, "banded")
    assert band_ratio < 2.3 < full_ratio
