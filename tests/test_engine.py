"""Tests for the compile-once bucketed execution engine (ISSUE 1):
padded-bucket loss equivalence, plan-cache/jit-cache key alignment,
collector deduplication, the vectorised scheduler, and the estimator
guard."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MimosePlanner, NonePlanner, PolyEstimator
from repro.core.collector import ShuttlingCollector
from repro.core.planner import fixed_train_bytes
from repro.core.scheduler import greedy_plan, greedy_plan_reference
from repro.data.pipeline import (DISTRIBUTIONS, bucket_edges, bucket_length,
                                 make_batches, pad_batch, top_buckets)
from repro.models.lm import build_model
from repro.models.registry import get_config
from repro.optim.adamw import AdamW
from repro.train.trainer import Trainer


@pytest.fixture(scope="module")
def small():
    cfg = get_config("bert_base_paper").reduced(
        num_layers=4, d_model=128, d_ff=256, vocab_size=512)
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


def _ragged_batch(S, B=2, vocab=512, seed=0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(S // 2, S + 1, B)
    tokens = rng.integers(1, vocab, (B, S)).astype(np.int32)
    weights = (np.arange(S)[None, :] < lens[:, None]).astype(np.float32)
    tokens = tokens * weights.astype(np.int32)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = 0
    return {"tokens": tokens, "labels": labels, "weights": weights,
            "lengths": lens}


# ---------------------------------------------------------------------------
# bucketing (data layer)
# ---------------------------------------------------------------------------

def test_bucket_length_rounds_up_to_quantum():
    assert bucket_length(65, 64) == 128
    assert bucket_length(64, 64) == 64
    assert bucket_length(1, 64) == 64


def test_bucket_edges_bound_geometry():
    d = DISTRIBUTIONS["swag"]                     # lengths in [35, 141]
    edges = set(bucket_edges(d, 32))
    assert edges == {64, 96, 128, 160}
    for b in make_batches("swag", batch_size=8, vocab_size=64,
                          num_batches=40, quantum=32, seed=3):
        assert b["tokens"].shape[1] in edges


def test_top_buckets_are_quantum_multiples_and_ranked():
    tb = top_buckets("swag", batch_size=8, quantum=32, k=3, seed=0)
    assert 1 <= len(tb) <= 3
    freqs = [f for _, f in tb]
    assert freqs == sorted(freqs, reverse=True)
    for S, f in tb:
        assert S % 32 == 0 and 0 < f <= 1


def test_pad_batch_pads_and_rebuilds_weights():
    b = _ragged_batch(50)
    del b["weights"]
    p = pad_batch(b, 64)
    assert p["tokens"].shape[1] == 64
    assert p["weights"].shape == p["tokens"].shape
    # exact mask from the true lengths; padded tail fully zeroed
    assert (p["weights"].sum(1) == b["lengths"]).all()
    assert (p["tokens"][:, 50:] == 0).all()
    assert (p["weights"][:, 50:] == 0).all()


def test_pad_batch_synthesizes_mask_for_bare_batch():
    """Regression: a {tokens, labels} batch relies on lm.loss's implicit
    all-ones weights — padding must materialise that mask over the REAL
    positions so the padded tail cannot enter the loss."""
    b = _ragged_batch(50)
    del b["weights"], b["lengths"]
    p = pad_batch(b, 64)
    assert p["weights"].shape == (2, 64)
    assert (p["weights"][:, :50] == 1).all()
    assert (p["weights"][:, 50:] == 0).all()


def test_pad_batch_noop_when_aligned():
    b = _ragged_batch(64)
    p = pad_batch(b, 64)
    assert p["tokens"].shape == b["tokens"].shape
    np.testing.assert_array_equal(p["tokens"], b["tokens"])


def test_padded_bucket_loss_equals_unpadded(small):
    """Masked loss on the padded bucket == loss on the raw ragged batch
    (padding is causal-suffix + zero-weight, so it is invisible)."""
    _, lm, params = small
    raw = _ragged_batch(50)
    padded = pad_batch(raw, 64)
    l_raw, m_raw = lm.loss(params, {k: jnp.asarray(v) for k, v in raw.items()
                                    if k != "lengths"})
    l_pad, m_pad = lm.loss(params, {k: jnp.asarray(v)
                                    for k, v in padded.items()
                                    if k != "lengths"})
    assert float(m_raw["tokens"]) == float(m_pad["tokens"])
    np.testing.assert_allclose(float(l_raw), float(l_pad),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# unified plan-cache / jit-cache key
# ---------------------------------------------------------------------------

def test_repeat_bucket_means_zero_recompiles(small):
    """Raw batches of many distinct lengths inside one bucket share ONE
    compiled step and ONE plan: the caches are keyed identically."""
    _, lm, params = small
    planner = MimosePlanner(lm, budget_bytes=1e12, quantum=64,
                            warmup_samples=2)
    tr = Trainer(lm, planner, AdamW(lr=1e-3))
    p = jax.tree_util.tree_map(jnp.copy, params)   # steps donate buffers
    opt_state = tr.optimizer.init(p)
    for i, S in enumerate((30, 40, 50, 60, 33, 64)):
        p, opt_state, _ = tr.step(p, opt_state, _ragged_batch(S, seed=i))
    assert tr.cache_stats["compiles"] == 1
    assert tr.cache_stats["jit_hits"] == 5
    assert list(tr.cache_stats["bucket_steps"]) == [2 * 64]
    assert planner.stats["cache_misses"] == 1
    assert planner.stats["cache_hits"] == 5


def test_compiles_bounded_by_buckets_not_raw_shapes(small):
    _, lm, params = small
    planner = MimosePlanner(lm, budget_bytes=1e12, quantum=64,
                            warmup_samples=2)
    tr = Trainer(lm, planner, AdamW(lr=1e-3))
    p = jax.tree_util.tree_map(jnp.copy, params)   # steps donate buffers
    opt_state = tr.optimizer.init(p)
    sizes = (30, 60, 70, 120, 40, 100, 50, 110)    # 8 raw -> 2 buckets
    for i, S in enumerate(sizes):
        p, opt_state, _ = tr.step(p, opt_state, _ragged_batch(S, seed=i))
    assert tr.cache_stats["compiles"] == 2
    assert sorted(tr.cache_stats["bucket_steps"]) == [2 * 64, 2 * 128]


def test_prewarm_compiles_off_critical_path(small):
    _, lm, params = small
    planner = MimosePlanner(lm, budget_bytes=1e12, quantum=64,
                            warmup_samples=2)
    tr = Trainer(lm, planner, AdamW(lr=1e-3))
    p = jax.tree_util.tree_map(jnp.copy, params)   # steps donate buffers
    opt_state = tr.optimizer.init(p)
    n = tr.prewarm(p, opt_state, [64, 128], batch_size=2)
    assert n == 2 and tr.cache_stats["prewarm_compiles"] == 2
    p, opt_state, loss = tr.step(p, opt_state, _ragged_batch(50))
    assert np.isfinite(loss)
    assert tr.cache_stats["compiles"] == 0          # served by prewarm
    assert tr.cache_stats["jit_hits"] == 1


def test_prewarm_extra_keys_for_encoder_family():
    """Encoder batches carry ``frames``; prewarm takes builders for the
    extra keys instead of KeyErroring on its synthetic batch."""
    cfg = get_config("seamless_m4t_large_v2").reduced(
        num_layers=1, encoder_layers=1, d_model=64, d_ff=128,
        vocab_size=128, dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    tr = Trainer(lm, MimosePlanner(lm, budget_bytes=1e12, quantum=64,
                                   warmup_samples=2), AdamW(lr=1e-3))
    opt_state = tr.optimizer.init(params)
    extra = {"frames": lambda B, S: np.zeros((B, 16, cfg.d_model),
                                             np.float32)}
    with pytest.raises(KeyError):
        tr.prewarm(params, opt_state, [64], batch_size=2)
    n = tr.prewarm(params, opt_state, [64], batch_size=2, extra=extra)
    assert n == 1 and tr.cache_stats["prewarm_compiles"] == 1


def test_unbucketed_planner_still_trains(small):
    """NonePlanner has quantum 1: the engine degrades to the seed's
    per-shape behaviour without erroring."""
    _, lm, params = small
    tr = Trainer(lm, NonePlanner(lm), AdamW(lr=1e-3))
    p = jax.tree_util.tree_map(jnp.copy, params)   # steps donate buffers
    opt_state = tr.optimizer.init(p)
    p, _, loss = tr.step(p, opt_state, _ragged_batch(48))
    assert np.isfinite(loss)
    assert tr.cache_stats["compiles"] == 1


# ---------------------------------------------------------------------------
# deduplicated collector
# ---------------------------------------------------------------------------

def test_dedup_collector_matches_per_layer_byte_for_byte(small):
    _, lm, params = small
    batch = {"tokens": jnp.ones((2, 96), jnp.int32),
             "labels": jnp.ones((2, 96), jnp.int32)}
    base = ShuttlingCollector(lm, dedup=False).collect(params, batch)
    fast = ShuttlingCollector(lm, dedup=True).collect(params, batch)
    assert np.array_equal(base.activation_vector(), fast.activation_vector())
    for r0, r1 in zip(base.records, fast.records):
        assert (r0.name, r0.index, r0.activation_bytes, r0.output_bytes,
                r0.param_bytes) == (r1.name, r1.index, r1.activation_bytes,
                                    r1.output_bytes, r1.param_bytes)
    # 4 homogeneous blocks -> one abstract trace
    assert fast.traced_units == 1
    assert fast.dedup_hits == 3
    assert base.traced_units == 4 and base.dedup_hits == 0


def test_dedup_keyed_on_encoder_geometry():
    """Regression: decoder units close over the encoder output, so frame
    count must be part of the trace key — same token shape with a
    different F must NOT replay cached cross-attention residuals."""
    cfg = get_config("seamless_m4t_large_v2").reduced(
        num_layers=2, encoder_layers=2, d_model=96, d_ff=192,
        vocab_size=256, dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    col = ShuttlingCollector(lm, dedup=True)
    base = ShuttlingCollector(lm, dedup=False)
    for F in (16, 64):
        batch = {"tokens": jnp.ones((2, 32), jnp.int32),
                 "labels": jnp.ones((2, 32), jnp.int32),
                 "frames": jnp.zeros((2, F, cfg.d_model), jnp.float32)}
        fast = col.collect(params, batch)
        ref = base.collect(params, batch)
        np.testing.assert_array_equal(fast.activation_vector(),
                                      ref.activation_vector())


def test_measure_time_not_replayed_from_dedup_cache(small):
    """Timings are wall-clock, not shape-determined: every unit gets its
    own measurement even when its byte trace is a dedup hit."""
    _, lm, params = small
    col = ShuttlingCollector(lm, measure_time=True, dedup=True)
    batch = {"tokens": jnp.ones((1, 32), jnp.int32),
             "labels": jnp.ones((1, 32), jnp.int32)}
    res = col.collect(params, batch)
    assert res.dedup_hits > 0
    assert all(r.forward_time_s > 0 for r in res.records)


def test_dedup_trace_cache_persists_across_sizes(small):
    _, lm, params = small
    col = ShuttlingCollector(lm)
    for S in (64, 96, 64):
        col.collect(params, {"tokens": jnp.ones((2, S), jnp.int32),
                             "labels": jnp.ones((2, S), jnp.int32)})
    # one trace per distinct geometry, repeats fully served by the cache
    assert col.stats["traces"] == 2
    assert col.stats["dedup_hits"] == 3 * 4 - 2


# ---------------------------------------------------------------------------
# vectorised scheduler
# ---------------------------------------------------------------------------

def test_fast_scheduler_matches_reference():
    rng = np.random.default_rng(7)
    for trial in range(300):
        n = int(rng.integers(1, 64))
        kind = trial % 4
        if kind == 0:
            est = rng.uniform(1.0, 1e9, n)
        elif kind == 1:
            est = np.round(rng.uniform(1, 10, n)) * 100.0   # heavy ties
        elif kind == 2:
            est = np.full(n, 100.0)                         # one bucket
        else:
            est = np.concatenate([rng.uniform(1, 1e6, n // 2 + 1),
                                  np.zeros(n // 2)])[:n]    # zero units
        budget = float(rng.uniform(0, est.sum() * 1.2))
        fixed = float(rng.choice([0.0, est.sum() * 0.1]))
        a = greedy_plan(est, budget, fixed)
        b = greedy_plan_reference(est, budget, fixed)
        assert a.remat == b.remat
        assert a.excess_bytes == pytest.approx(b.excess_bytes)
        assert a.covered_bytes == pytest.approx(b.covered_bytes)


def test_fast_scheduler_empty_input():
    p = greedy_plan([], 100.0)
    assert p.remat == [] and p.covered_bytes == 0.0


# ---------------------------------------------------------------------------
# estimator guard
# ---------------------------------------------------------------------------

def test_estimator_predict_before_samples_raises_clearly():
    est = PolyEstimator(2)
    with pytest.raises(RuntimeError, match="no samples"):
        est.predict(128)
    with pytest.raises(RuntimeError, match="no samples"):
        est.fit()
    est.add_sample(64, [1.0])
    assert est.predict(64)[0] >= 0.0      # usable after the first sample
