"""Sharding spec rules + launch plumbing (single-device mesh on CPU;
the 512-device production meshes are exercised by launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.config import INPUT_SHAPES
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import input_specs, shape_applicable
from repro.models.lm import build_model
from repro.models.registry import ARCH_IDS, get_config
from repro.optim.adamw import AdamW
from repro.sharding import specs as SP

ASSIGNED = [a for a in ARCH_IDS if a != "bert_base_paper"]


@pytest.fixture(scope="module")
def mesh():
    return make_debug_mesh(1, 1)


def test_param_specs_cover_all_leaves(mesh):
    for arch in ("qwen3_1p7b", "granite_moe_1b_a400m", "mamba2_1p3b",
                 "hymba_1p5b"):
        cfg = get_config(arch).reduced()
        lm = build_model(cfg)
        struct = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
        sh = SP.params_shardings(struct, mesh,
                                 scanned=cfg.remat_mode == "scan")
        n_leaves = len(jax.tree_util.tree_leaves(struct))
        n_sh = len(jax.tree_util.tree_leaves(
            sh, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding)))
        assert n_leaves == n_sh


def test_column_row_rules():
    cfg = get_config("qwen3_1p7b")                 # full size, divisible
    lm = build_model(cfg)
    struct = jax.eval_shape(lm.init, jax.random.PRNGKey(0))

    class FakeMesh:
        shape = {"model": 16, "data": 16}
        axis_names = ("data", "model")

    spec = SP.param_spec(
        (jax.tree_util.DictKey("blocks"), jax.tree_util.DictKey("attn"),
         jax.tree_util.DictKey("wq")),
        jax.ShapeDtypeStruct((8, 2048, 2048), jnp.bfloat16),
        scanned=True, mesh=FakeMesh(), model_dim=16)
    assert spec == P(None, None, "model")
    spec = SP.param_spec(
        (jax.tree_util.DictKey("blocks"), jax.tree_util.DictKey("attn"),
         jax.tree_util.DictKey("wo")),
        jax.ShapeDtypeStruct((8, 2048, 2048), jnp.bfloat16),
        scanned=True, mesh=FakeMesh(), model_dim=16)
    assert spec == P(None, "model", None)
    # expert weights: expert-parallel on the leading E axis
    spec = SP.param_spec(
        (jax.tree_util.DictKey("blocks"), jax.tree_util.DictKey("moe"),
         jax.tree_util.DictKey("wi")),
        jax.ShapeDtypeStruct((32, 1024, 512), jnp.bfloat16),
        scanned=False, mesh=FakeMesh(), model_dim=16)
    assert spec == P("model", None, None)
    # non-divisible dims stay replicated
    spec = SP.param_spec(
        (jax.tree_util.DictKey("embed"),),
        jax.ShapeDtypeStruct((50277, 512), jnp.float32),
        scanned=False, mesh=FakeMesh(), model_dim=16)
    assert spec == P(None, None)


def test_input_specs_all_pairs_build():
    """Every (arch x shape) either yields well-formed specs or is a
    documented skip."""
    n_ok = n_skip = 0
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            if not ok:
                assert "full-attention" in why
                n_skip += 1
                continue
            batch = input_specs(cfg, shape)
            assert "tokens" in batch
            B = shape.global_batch
            assert batch["tokens"].shape[0] == B
            if shape.kind == "decode":
                assert batch["tokens"].shape[1] == 1
            elif cfg.family == "vlm":
                assert (batch["tokens"].shape[1] + cfg.vision_tokens
                        == shape.seq_len)
            else:
                assert batch["tokens"].shape[1] == shape.seq_len
            n_ok += 1
    assert n_ok + n_skip == 40
    assert n_skip == 7        # 7 pure-full-attention archs skip long_500k


def test_jit_with_shardings_single_device(mesh):
    """The sharded train step actually runs on a 1x1 mesh."""
    cfg = get_config("qwen3_1p7b").reduced(dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    p_sh = SP.params_shardings(params, mesh, scanned=False)
    batch = {"tokens": jnp.ones((2, 32), jnp.int32),
             "labels": jnp.ones((2, 32), jnp.int32)}
    b_sh = SP.batch_shardings(batch, mesh)
    with mesh:
        fn = jax.jit(lambda p, b: lm.loss(p, b)[0],
                     in_shardings=(p_sh, b_sh))
        loss = fn(jax.device_put(params, p_sh), batch)
    assert np.isfinite(float(loss))


def test_cache_specs(mesh):
    cfg = get_config("hymba_1p5b").reduced()
    lm = build_model(cfg)
    cache = jax.eval_shape(lambda: lm.init_cache(4, 64))
    sh = SP.cache_shardings(cache, mesh, stacked=False)
    assert len(jax.tree_util.tree_leaves(sh,
               is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding))) \
        == len(jax.tree_util.tree_leaves(cache))


def test_make_debug_mesh():
    m = make_debug_mesh(1, 1)
    assert m.shape == {"data": 1, "model": 1}
