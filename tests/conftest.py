"""Test harness glue.

The property tests are written against ``hypothesis``; this container
does not ship it and nothing may be pip-installed, so when the real
library is missing we register a small deterministic stand-in that
implements exactly the strategy surface the suite uses (``floats``,
``integers``, ``lists``, ``sampled_from``, ``composite``) plus the
``given``/``settings`` decorators.  Draws come from a seeded PRNG so
runs are reproducible; the real hypothesis always wins when installed.
"""
from __future__ import annotations

import functools
import os
import random
import sys
import types


def _install_hypothesis_shim() -> None:
    try:
        import hypothesis  # noqa: F401  (real library present)
        return
    except ImportError:
        pass

    class Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def example(self, rng: random.Random):
            return self._draw(rng)

    def floats(min_value=None, max_value=None, allow_nan=True,
               allow_infinity=True, **_):
        lo = 0.0 if min_value is None else float(min_value)
        hi = (lo + 1e6) if max_value is None else float(max_value)
        return Strategy(lambda rng: rng.uniform(lo, hi))

    def integers(min_value=0, max_value=1 << 30, **_):
        return Strategy(lambda rng: rng.randint(int(min_value), int(max_value)))

    def sampled_from(seq):
        seq = list(seq)
        return Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def lists(elements, min_size=0, max_size=None, unique=False, **_):
        hi = (min_size + 8) if max_size is None else max_size

        def draw(rng: random.Random):
            n = rng.randint(min_size, hi)
            out, seen, tries = [], set(), 0
            while len(out) < n and tries < 200 * (n + 1):
                v = elements.example(rng)
                tries += 1
                if unique:
                    if v in seen:
                        continue
                    seen.add(v)
                out.append(v)
            return out
        return Strategy(draw)

    def composite(fn):
        @functools.wraps(fn)
        def make(*args, **kwargs):
            def draw_from(rng: random.Random):
                return fn(lambda strat: strat.example(rng), *args, **kwargs)
            return Strategy(draw_from)
        return make

    _DEFAULT_EXAMPLES = int(os.environ.get("HYPOTHESIS_SHIM_EXAMPLES", "15"))

    def given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = (getattr(wrapper, "_shim_max_examples", None)
                     or getattr(fn, "_shim_max_examples", None)
                     or _DEFAULT_EXAMPLES)
                n = min(int(n), _DEFAULT_EXAMPLES)
                for i in range(n):
                    rng = random.Random(f"{fn.__module__}.{fn.__qualname__}:{i}")
                    drawn = [s.example(rng) for s in strategies]
                    fn(*args, *drawn, **kwargs)
            # copy identity but NOT __wrapped__: pytest must see the
            # (*args, **kwargs) signature, not the drawn parameters
            for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
                setattr(wrapper, attr, getattr(fn, attr))
            wrapper.is_hypothesis_test = True
            return wrapper
        return deco

    def settings(max_examples=None, deadline=None, **_):
        def deco(fn):
            if max_examples is not None:
                fn._shim_max_examples = max_examples
            return fn
        return deco

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    strat_mod = types.ModuleType("hypothesis.strategies")
    strat_mod.floats = floats
    strat_mod.integers = integers
    strat_mod.lists = lists
    strat_mod.sampled_from = sampled_from
    strat_mod.composite = composite
    hyp.strategies = strat_mod
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat_mod


_install_hypothesis_shim()
