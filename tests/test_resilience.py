"""Elastic-resilience tests: strict checkpoint validation, atomic
snapshots with corruption fallback, kill-and-resume (including onto a
reshaped mesh), and the OOM watchdog's DTR-style escalation ladder
under deterministic fault injection."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MeshBudget, MimosePlanner
from repro.data.pipeline import make_batches
from repro.models.lm import build_model
from repro.models.registry import get_config
from repro.optim.adamw import AdamW
from repro.train import checkpoint
from repro.train.checkpoint import CheckpointError
from repro.train.resilience import (FaultInjector, OOMWatchdog, Restored,
                                    SimulatedOOM, SnapshotManager,
                                    planner_state, restore_planner_state)
from repro.train.trainer import Trainer

pytestmark = pytest.mark.resilience

HBM = float(1 << 30)          # roomy per-device budget: plans stay no-op


@pytest.fixture(scope="module")
def small():
    cfg = get_config("bert_base_paper").reduced(
        num_layers=2, d_model=64, d_ff=128, vocab_size=256)
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


def _batch(S, B=2):
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32)}


def _copy(tree):
    """Private copy of a param/opt pytree: the jit train step donates
    its inputs, so a shared fixture tree must never be stepped on."""
    return jax.tree_util.tree_map(lambda x: jnp.array(x), tree)


def _batches(cfg, n, B=2, seed=0):
    return list(make_batches("swag", batch_size=B,
                             vocab_size=cfg.vocab_size, num_batches=n,
                             quantum=64, seed=seed))


# ---------------------------------------------------------------------------
# checkpoint.load validation
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": jnp.ones((3,), jnp.float32)}
    p = str(tmp_path / "t.ckpt")
    checkpoint.save(p, tree)
    back = checkpoint.load(p, jax.tree_util.tree_map(jnp.zeros_like, tree))
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))


def test_checkpoint_loaded_leaves_are_writable_copies(tmp_path):
    # np.frombuffer over the msgpack payload is read-only; load must
    # copy so downstream numpy consumers can mutate without tripping
    p = str(tmp_path / "t.ckpt")
    checkpoint.save(p, {"w": jnp.ones((4,), jnp.float32)})
    back = checkpoint.load(p, {"w": jnp.zeros((4,), jnp.float32)})
    host = np.asarray(back["w"])
    buf = np.frombuffer(b"\x00" * 16, dtype=np.float32)
    assert not buf.flags.writeable          # the failure mode guarded against
    assert host.copy().flags.writeable


def test_checkpoint_dtype_mismatch_names_leaf(tmp_path):
    p = str(tmp_path / "t.ckpt")
    checkpoint.save(p, {"emb": jnp.ones((2, 2), jnp.float32)})
    with pytest.raises(CheckpointError, match="dtype mismatch.*emb"):
        checkpoint.load(p, {"emb": jnp.ones((2, 2), jnp.int32)})


def test_checkpoint_shape_mismatch_names_leaf(tmp_path):
    p = str(tmp_path / "t.ckpt")
    checkpoint.save(p, {"w": jnp.ones((2, 2), jnp.float32)})
    with pytest.raises(CheckpointError, match="w"):
        checkpoint.load(p, {"w": jnp.ones((3, 2), jnp.float32)})


def test_checkpoint_treedef_mismatch(tmp_path):
    p = str(tmp_path / "t.ckpt")
    checkpoint.save(p, {"a": jnp.ones((2,), jnp.float32)})
    with pytest.raises(CheckpointError, match="treedef mismatch"):
        checkpoint.load(p, {"b": jnp.ones((2,), jnp.float32)})


def test_checkpoint_truncated_file(tmp_path):
    p = str(tmp_path / "t.ckpt")
    checkpoint.save(p, {"w": jnp.ones((64,), jnp.float32)})
    raw = open(p, "rb").read()
    with open(p, "wb") as f:
        f.write(raw[: len(raw) // 2])
    with pytest.raises(CheckpointError):
        checkpoint.load(p, {"w": jnp.ones((64,), jnp.float32)})


# ---------------------------------------------------------------------------
# fault injector
# ---------------------------------------------------------------------------

def test_injector_first_n():
    inj = FaultInjector("2")
    hits = [inj.should_fail(step=i, bucket=0) for i in range(4)]
    assert hits == [True, True, False, False]
    assert inj.injected == 2


def test_injector_by_bucket_and_step():
    inj = FaultInjector({"bucket": {128: 1}, "step": {5: 1}})
    assert not inj.should_fail(step=0, bucket=64)
    assert inj.should_fail(step=1, bucket=128)       # bucket quota
    assert not inj.should_fail(step=2, bucket=128)   # quota spent
    assert inj.should_fail(step=5, bucket=64)        # step quota
    assert not inj.should_fail(step=5, bucket=64)


def test_injector_from_env(monkeypatch):
    monkeypatch.setenv(FaultInjector.ENV, '{"step": {"0": 1}}')
    inj = FaultInjector.from_env()
    assert inj is not None and inj.armed
    assert inj.should_fail(step=0, bucket=0)
    monkeypatch.delenv(FaultInjector.ENV)
    assert FaultInjector.from_env() is None


def test_injector_rejects_garbage():
    with pytest.raises(ValueError):
        FaultInjector("not json {")


def test_watchdog_classifies_oom():
    assert OOMWatchdog.is_oom(SimulatedOOM(0, 128))
    assert "RESOURCE_EXHAUSTED" in str(SimulatedOOM(0, 128))
    assert not OOMWatchdog.is_oom(ValueError("shape mismatch"))


# ---------------------------------------------------------------------------
# snapshots: atomicity, retention, corruption fallback
# ---------------------------------------------------------------------------

def _tiny_state():
    params = {"w": jnp.arange(4, dtype=jnp.float32)}
    opt = {"m": jnp.zeros((4,), jnp.float32)}
    return params, opt


def test_snapshot_roundtrip_and_manifest(tmp_path):
    params, opt = _tiny_state()
    sm = SnapshotManager(str(tmp_path), every_steps=5, keep=3)
    path = sm.save(step=5, params=params, opt_state=opt, data_cursor=5)
    man = json.load(open(os.path.join(path, sm.MANIFEST)))
    assert set(man["files"]) >= {"params.ckpt", "opt.ckpt", "meta.json"}
    r = sm.restore_latest(params_like=jax.tree_util.tree_map(
        jnp.zeros_like, params), opt_like=opt)
    assert isinstance(r, Restored)
    assert r.step == 5 and r.data_cursor == 5
    np.testing.assert_array_equal(np.asarray(r.params["w"]),
                                  np.asarray(params["w"]))


def test_snapshot_due_cadence(tmp_path):
    sm = SnapshotManager(str(tmp_path), every_steps=4)
    assert [s for s in range(1, 9) if sm.due(s)] == [4, 8]
    sm2 = SnapshotManager(str(tmp_path), every_steps=0, every_secs=0.0)
    assert not any(sm2.due(s) for s in range(1, 9))
    sm3 = SnapshotManager(str(tmp_path), every_secs=1e-9)
    assert sm3.due(1)        # wall-clock trigger fires immediately


def test_snapshot_retention(tmp_path):
    params, opt = _tiny_state()
    sm = SnapshotManager(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        sm.save(step=step, params=params, opt_state=opt)
    snaps = sm.snapshots()
    assert len(snaps) == 2
    assert snaps[-1].endswith("snap-00000004")
    assert sm.written == 4


def test_restore_skips_corrupt_snapshot(tmp_path):
    params, opt = _tiny_state()
    sm = SnapshotManager(str(tmp_path), keep=3)
    sm.save(step=1, params=params, opt_state=opt, data_cursor=1)
    good = np.asarray(params["w"]).copy()
    newest = sm.save(step=2, params={"w": params["w"] * 7.0},
                     opt_state=opt, data_cursor=2)
    # bit-flip the newest snapshot's params: manifest hash must catch it
    target = os.path.join(newest, "params.ckpt")
    raw = bytearray(open(target, "rb").read())
    raw[-1] ^= 0xFF
    with open(target, "wb") as f:
        f.write(bytes(raw))
    r = sm.restore_latest(params_like=params, opt_like=opt)
    assert r.step == 1        # fell back past the corrupt snap-2
    np.testing.assert_array_equal(np.asarray(r.params["w"]), good)


def test_restore_ignores_partial_tmp_dir(tmp_path):
    params, opt = _tiny_state()
    sm = SnapshotManager(str(tmp_path))
    sm.save(step=1, params=params, opt_state=opt)
    os.makedirs(str(tmp_path / ".tmp-snap-00000009"))  # simulated crash
    assert len(sm.snapshots()) == 1
    assert sm.restore_latest(params_like=params, opt_like=opt).step == 1


def test_restore_empty_dir_raises(tmp_path):
    sm = SnapshotManager(str(tmp_path))
    with pytest.raises(Exception, match="no restorable snapshot"):
        sm.restore_latest(params_like={}, opt_like={})


# ---------------------------------------------------------------------------
# planner state: serialize / restore, same mesh and reshaped mesh
# ---------------------------------------------------------------------------

def test_planner_state_same_mesh_roundtrip(small):
    cfg, lm, params = small
    src = MimosePlanner(lm, HBM, quantum=64, warmup_samples=1)
    src.plan(params, _batch(64))
    src.plan(params, _batch(128))
    state = planner_state(src)
    assert state["sample_log"] and state["plans"]

    dst = MimosePlanner(lm, HBM, quantum=64, warmup_samples=1)
    summary = restore_planner_state(dst, state)
    assert not summary["mesh_changed"]
    assert summary["restored_plans"] == len(state["plans"])
    assert dst.estimator.num_samples == src.estimator.num_samples
    np.testing.assert_allclose(dst.estimator.predict(96),
                               src.estimator.predict(96))
    # a seen bucket is a pure cache hit on the restored planner
    dst.plan(params, _batch(64))
    assert dst.stats["cache_hits"] == 1
    assert dst.stats["collections"] == 0


def test_planner_state_mesh_reshape_replays_samples(small):
    cfg, lm, params = small
    mb_a = MeshBudget.from_shape([1, 2], HBM)
    mb_b = MeshBudget.from_shape([2, 1], HBM)
    src = MimosePlanner(lm, None, quantum=64, warmup_samples=1,
                        mesh_budget=mb_a)
    src.plan(params, _batch(64))
    state = planner_state(src)

    dst = MimosePlanner(lm, None, quantum=64, warmup_samples=1,
                        mesh_budget=mb_b)
    summary = restore_planner_state(dst, state, params=params)
    assert summary["mesh_changed"]
    assert summary["restored_samples"] == len(state["sample_log"])
    # plans keyed to the old mesh signature must not survive the reshape
    assert summary["restored_plans"] == 0
    assert summary["dropped_plans"] == len(state["plans"])
    assert dst.estimator.ready
    assert dst.stats["dropped_plans"] == len(state["plans"])
    # the replayed fit is the NEW mesh's per-device bytes, not the old's
    fresh = MimosePlanner(lm, None, quantum=64, warmup_samples=1,
                          mesh_budget=mb_b)
    fresh.plan(params, _batch(64))
    np.testing.assert_allclose(dst.estimator.predict(128),
                               fresh.estimator.predict(128), rtol=1e-6)


def test_planner_state_drops_roofline_mismatched_plans(small):
    """A plan priced at one PCIe link / overlap must not be restored
    into a planner with different roofline knobs — the solved (or
    greedy-hybrid) cost model behind it no longer holds."""
    cfg, lm, params = small
    src = MimosePlanner(lm, HBM, quantum=64, warmup_samples=1,
                        pcie_gbps=16.0)
    src.plan(params, _batch(64))
    state = planner_state(src)
    assert state["plans"] and state["plans"][0]["pcie_gbps"] == 16.0
    assert "source" in state["plans"][0]["plan"]

    dst = MimosePlanner(lm, HBM, quantum=64, warmup_samples=1,
                        pcie_gbps=4.0)
    summary = restore_planner_state(dst, state)
    assert summary["restored_plans"] == 0
    assert summary["dropped_plans"] == len(state["plans"])
    # matching knobs restore verbatim, provenance included
    same = MimosePlanner(lm, HBM, quantum=64, warmup_samples=1,
                         pcie_gbps=16.0)
    summary = restore_planner_state(same, state)
    assert summary["restored_plans"] == len(state["plans"])
    key = same.plan_key(_batch(64))
    assert same.cache[key].source == "greedy"
    # pre-PR-7 snapshots lack the fields: default to the live knobs
    for rec in state["plans"]:
        del rec["pcie_gbps"], rec["offload_overlap"]
        del rec["plan"]["source"]
    legacy = MimosePlanner(lm, HBM, quantum=64, warmup_samples=1,
                           pcie_gbps=4.0)
    summary = restore_planner_state(legacy, state)
    assert summary["restored_plans"] == len(state["plans"])


def test_planner_state_mesh_reshape_requires_params(small):
    cfg, lm, params = small
    src = MimosePlanner(lm, None, quantum=64, warmup_samples=1,
                        mesh_budget=MeshBudget.from_shape([1, 2], HBM))
    src.plan(params, _batch(64))
    dst = MimosePlanner(lm, None, quantum=64, warmup_samples=1,
                        mesh_budget=MeshBudget.from_shape([2, 1], HBM))
    with pytest.raises(ValueError, match="needs params"):
        restore_planner_state(dst, planner_state(src))


# ---------------------------------------------------------------------------
# kill-and-resume: trainer-level, across a mesh reshape
# ---------------------------------------------------------------------------

def test_kill_and_resume_across_mesh_reshape(small, tmp_path):
    cfg, lm, params0 = small
    batches = _batches(cfg, 8)
    opt = AdamW(lr=1e-3)

    def fresh(mesh_shape):
        planner = MimosePlanner(lm, None, quantum=64, warmup_samples=1,
                                mesh_budget=MeshBudget.from_shape(
                                    mesh_shape, HBM))
        return Trainer(lm, planner, opt)

    # reference: 8 uninterrupted steps under mesh (1, 2)
    tr_a = fresh([1, 2])
    params = _copy(params0)
    opt_state = opt.init(params)
    ref_losses = []
    for b in batches:
        params, opt_state, loss = tr_a.step(params, opt_state, b)
        ref_losses.append(loss)

    # preempted run: 4 steps, snapshot, "kill"
    tr_b = fresh([1, 2])
    tr_b.snapshots = SnapshotManager(str(tmp_path), keep=2)
    params = _copy(params0)
    opt_state = opt.init(params)
    for b in batches[:4]:
        params, opt_state, _ = tr_b.step(params, opt_state, b)
    tr_b.snapshots.save(step=tr_b.global_step, params=params,
                        opt_state=opt_state, planner=tr_b.planner,
                        data_cursor=tr_b.data_cursor)

    # resume onto the RESHAPED mesh (2, 1): new process, new planner
    tr_c = fresh([2, 1])
    r = tr_c.snapshots_restored = SnapshotManager(str(tmp_path)) \
        .restore_latest(params_like=params0,
                        opt_like=opt.init(params0),
                        planner=tr_c.planner)
    assert r.step == 4 and r.data_cursor == 4
    assert r.planner_summary["mesh_changed"]
    assert r.planner_summary["restored_samples"] >= 1
    params, opt_state = r.params, r.opt_state
    tr_c.global_step, tr_c.data_cursor = r.step, r.data_cursor
    tr_c.restores = 1
    res_losses = []
    for b in batches[r.data_cursor:]:
        params, opt_state, loss = tr_c.step(params, opt_state, b)
        res_losses.append(loss)

    # loss trajectory matches the uninterrupted run (same numerics
    # modulo remat re-association; generous rtol documents the bound)
    np.testing.assert_allclose(res_losses, ref_losses[4:], rtol=1e-4)
    # zero planner re-warmup for seen buckets: the replayed sample log
    # made the estimator ready, so no collection and no refit ran
    assert tr_c.planner.stats["collections"] == 0
    assert tr_c.planner.stats["refits"] == 0
    # recompiles bounded by the resumed run's own bucket set (a new
    # process always compiles each bucket once — never more)
    n_buckets = len({tr_c.planner.bucket_key(tr_c._prepare(b))
                     for b in batches[4:]})
    assert tr_c.cache_stats["compiles"] <= n_buckets
    assert tr_c.summary()["restores"] == 1


# ---------------------------------------------------------------------------
# OOM watchdog: escalation ladder, bounded retries, cache poisoning
# ---------------------------------------------------------------------------

def test_watchdog_escalation_ladder_and_recovery(small):
    cfg, lm, params = small
    planner = MimosePlanner(lm, HBM, quantum=64, warmup_samples=1)
    tr = Trainer(lm, planner, AdamW())
    params = _copy(params)
    opt_state = tr.optimizer.init(params)
    batch = _batch(128, B=4)
    bucket = planner.bucket_key(tr._prepare(batch))
    key0 = planner.plan_key(tr._prepare(batch))
    wd = OOMWatchdog(max_retries=3,
                     injector=FaultInjector({"bucket": {bucket: 3}}))
    tr.watchdog = wd

    params2, opt_state, loss = tr.step(params, opt_state, batch)
    assert np.isfinite(loss)
    # the ladder ran all three rungs: remat replan, action upgrade,
    # then a doubled gradient-accumulation split
    assert wd.stats["oom_events"] == 3
    assert wd.stats["escalations"] == 3
    assert wd.stats["retry_successes"] == 1
    assert wd.stats["retry_failures"] == 0
    assert wd.stats["oom_by_bucket"] == {bucket: 3}
    assert planner.stats["oom_events"] == 3
    assert planner.stats["escalations"] == 3
    assert planner._escalation[key0] == 3
    assert planner.cache.get(key0).microbatch == 2   # rung 3 doubled k
    # the quota is spent: the next step of the bucket sails through
    params3, opt_state, loss2 = tr.step(params2, opt_state, batch)
    assert wd.stats["oom_events"] == 3
    assert tr.summary()["oom_events"] == 3
    assert tr.summary()["escalations_by_bucket"] == {bucket: 3}


def test_watchdog_poisons_plan_and_step_cache(small):
    cfg, lm, params = small
    planner = MimosePlanner(lm, HBM, quantum=64, warmup_samples=1)
    tr = Trainer(lm, planner, AdamW())
    params = _copy(params)
    opt_state = tr.optimizer.init(params)
    batch = _batch(64, B=4)
    bucket = planner.bucket_key(tr._prepare(batch))
    tr.watchdog = OOMWatchdog(max_retries=2,
                              injector=FaultInjector({"bucket": {bucket: 1}}))
    tr.step(params, opt_state, batch)
    # the failed attempt's plan was replaced under the same key and its
    # compiled step evicted — exactly one poisoning each
    assert planner.stats["poisoned_plans"] == 1
    assert tr.cache_stats["compiles"] == 2   # failed plan + escalated plan


def test_watchdog_bounded_retries_reraises(small):
    cfg, lm, params = small
    planner = MimosePlanner(lm, HBM, quantum=64, warmup_samples=1)
    wd = OOMWatchdog(max_retries=1, injector=FaultInjector("10"))
    tr = Trainer(lm, planner, AdamW(), watchdog=wd)
    opt_state = tr.optimizer.init(params)
    with pytest.raises(SimulatedOOM):
        tr.step(params, opt_state, _batch(64, B=4))
    assert wd.stats["retry_failures"] == 1
    assert wd.stats["retry_successes"] == 0
    assert wd.stats["oom_events"] == 2       # initial try + 1 retry


def test_watchdog_ignores_non_oom_errors(small):
    cfg, lm, params = small
    planner = MimosePlanner(lm, HBM, quantum=64, warmup_samples=1)
    wd = OOMWatchdog(max_retries=3)
    tr = Trainer(lm, planner, AdamW(), watchdog=wd)
    opt_state = tr.optimizer.init(params)
    bad = {"tokens": jnp.ones((2, 64), jnp.int32)}    # no labels: real bug
    with pytest.raises(Exception):
        tr.step(params, opt_state, bad)
    assert wd.stats["oom_events"] == 0        # not booked as an OOM


def test_engine_report_shows_resilience_counters(small):
    from repro.launch.report import engine_report
    cfg, lm, params = small
    planner = MimosePlanner(lm, HBM, quantum=64, warmup_samples=1)
    tr = Trainer(lm, planner, AdamW())
    params = _copy(params)
    opt_state = tr.optimizer.init(params)
    batch = _batch(64, B=4)
    bucket = planner.bucket_key(tr._prepare(batch))
    tr.watchdog = OOMWatchdog(max_retries=3,
                              injector=FaultInjector({"bucket": {bucket: 1}}))
    tr.step(params, opt_state, batch)
    rep = engine_report(tr, planner)
    assert "resilience:" in rep
    assert "1 OOM event(s)" in rep
    assert "escalations by bucket" in rep


# ---------------------------------------------------------------------------
# bench gate degrades gracefully
# ---------------------------------------------------------------------------

def _gate(args):
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "bench_gate", os.path.join(os.path.dirname(__file__), "..",
                                   "tools", "bench_gate.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod.main(args)


def test_bench_gate_skips_when_fresh_missing(tmp_path):
    assert _gate(["--fresh", str(tmp_path / "nope.json")]) == 0


def test_bench_gate_skips_when_baseline_missing(tmp_path):
    fresh = tmp_path / "fresh.json"
    fresh.write_text(json.dumps({"acceptance": {"g": True}}))
    assert _gate(["--fresh", str(fresh),
                  "--committed", str(tmp_path / "missing.json")]) == 0


def test_bench_gate_skips_when_acceptance_key_absent(tmp_path):
    fresh = tmp_path / "fresh.json"
    fresh.write_text(json.dumps({"acceptance": {"g": True}}))
    base = tmp_path / "base.json"
    base.write_text(json.dumps({"scheduler": {}}))
    assert _gate(["--fresh", str(fresh), "--committed", str(base)]) == 0


def test_bench_gate_fails_on_corrupt_json(tmp_path):
    fresh = tmp_path / "fresh.json"
    fresh.write_text("{not json")
    assert _gate(["--fresh", str(fresh)]) == 1


def test_bench_gate_still_gates_when_armed(tmp_path):
    fresh = tmp_path / "fresh.json"
    fresh.write_text(json.dumps({"acceptance": {"g": False}}))
    base = tmp_path / "base.json"
    base.write_text(json.dumps({"acceptance": {"g": True}}))
    assert _gate(["--fresh", str(fresh), "--committed", str(base)]) == 1
