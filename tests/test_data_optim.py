"""Data pipeline + optimizer unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import DISTRIBUTIONS, epoch_sizes, make_batches
from repro.optim.adamw import AdamW, cosine_schedule


def test_batches_are_padded_to_quantum():
    for b in make_batches("swag", batch_size=4, vocab_size=100,
                          num_batches=10, quantum=32, seed=0):
        assert b["tokens"].shape[1] % 32 == 0
        assert b["tokens"].shape == b["labels"].shape == b["weights"].shape


def test_padding_is_masked():
    for b in make_batches("qqp", batch_size=4, vocab_size=100,
                          num_batches=5, quantum=32, seed=0):
        pad = b["weights"] == 0
        assert (b["tokens"][pad] == 0).all()
        lens = b["lengths"]
        assert (b["weights"].sum(1) == lens).all()


def test_sizes_vary_across_batches():
    sizes = epoch_sizes("swag", 8, 50, quantum=32)
    assert len(np.unique(sizes)) >= 2


@given(st.sampled_from(["swag", "squad", "qqp"]),
       st.integers(min_value=1, max_value=2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_distribution_bounds(name, seed):
    d = DISTRIBUTIONS[name]
    s = d.sample(np.random.default_rng(seed), 500)
    assert s.min() >= d.lo and s.max() <= d.hi


def test_adamw_minimises_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clipping_bounds_update():
    opt = AdamW(lr=1.0, clip_norm=1e-3, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    grads = {"w": jnp.full(4, 1e6)}
    new, _ = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(new["w"]))) < 1.1   # bounded despite 1e6 grad


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.array(0))) == 0.0
    assert float(lr(jnp.array(10))) <= 1e-3 + 1e-9
    assert float(lr(jnp.array(100))) < 1e-4
