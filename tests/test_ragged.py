"""Ragged execution + cost-aware remat selection (ISSUE 3).

Equivalence: the length-aware kernels on a bucket-padded batch must
reproduce the reference kernels run on the unpadded lengths — bitwise
against the same Pallas kernel at the unpadded shape (same blocking),
allclose against the naive ``ref.py`` oracles (causal / window / GQA /
bidirectional variants, interpret mode).

Scheduler property: at equal budget a cost-aware plan never exceeds the
byte-only plan's simulated recompute time, and stays feasible.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scheduler import greedy_plan
from repro.core.simulator import simulate
from repro.data.pipeline import pad_batch
from repro.kernels import flash_attention as fa
from repro.kernels import ops
from repro.kernels import ssd_scan as ssd_mod
from repro.kernels.ref import flash_attention_reference, ssd_reference
from repro.launch.roofline import plan_unit_flops, unit_fwd_flops
from repro.models.lm import build_model
from repro.models.registry import get_config

KEY = jax.random.PRNGKey(3)


def _qkv(B, S, H, Hkv, hd, dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# flash attention: masked padded bucket == reference at the true lengths
# ---------------------------------------------------------------------------

RAGGED_FLASH_CASES = [
    # (B, S, H, Hkv, hd, causal, window)
    (2, 96, 4, 2, 32, True, 0),        # GQA causal
    (2, 96, 4, 4, 32, True, 32),       # sliding window
    (2, 128, 8, 1, 16, True, 0),       # extreme GQA
    (2, 96, 2, 2, 32, False, 0),       # bidirectional (encoder-style)
]


@pytest.mark.parametrize("case", RAGGED_FLASH_CASES)
def test_flash_ragged_matches_reference_at_true_lengths(case):
    B, S, H, Hkv, hd, causal, window = case
    q, k, v = _qkv(B, S, H, Hkv, hd)
    rng = np.random.default_rng(0)
    lens = jnp.asarray(rng.integers(S // 3, S + 1, B), jnp.int32)
    out = ops.flash_attention(q, k, v, lens, causal=causal, window=window)
    for b in range(B):
        L = int(lens[b])
        ref = flash_attention_reference(
            q[b:b + 1, :L].transpose(0, 2, 1, 3),
            k[b:b + 1, :L].transpose(0, 2, 1, 3),
            v[b:b + 1, :L].transpose(0, 2, 1, 3),
            causal=causal, window=window).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out[b:b + 1, :L]),
                                   np.asarray(ref), rtol=2e-4, atol=2e-5,
                                   err_msg=f"case={case} b={b} L={L}")


def test_flash_ragged_bitwise_matches_unpadded_kernel():
    """Same kernel, same blocking: the masked run over the padded bucket
    must produce bit-identical outputs to the kernel run at the true
    (block-aligned) length — masking changes nothing but trip counts."""
    B, S, H, hd, blk = 2, 128, 2, 32, 32
    L = 64                                  # block-aligned true length
    q, k, v = _qkv(B, S, H, H, hd)
    lens = jnp.full((B,), L, jnp.int32)
    padded = fa.flash_attention_fwd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), lens, causal=True,
        block_q=blk, block_k=blk, interpret=True)
    exact = fa.flash_attention_fwd(
        q[:, :L].transpose(0, 2, 1, 3), k[:, :L].transpose(0, 2, 1, 3),
        v[:, :L].transpose(0, 2, 1, 3), None, causal=True,
        block_q=blk, block_k=blk, interpret=True)
    np.testing.assert_array_equal(np.asarray(padded[:, :, :L]),
                                  np.asarray(exact))


def test_flash_ragged_backward_matches_reference():
    """Grads through the masked kernel == grads of the length-masked
    reference; dk/dv vanish at padded positions."""
    B, S, H, Hkv, hd = 2, 96, 4, 2, 32
    q, k, v = _qkv(B, S, H, Hkv, hd)
    lens = jnp.array([50, 77], jnp.int32)
    wm = (jnp.arange(S)[None, :] < lens[:, None]).astype(jnp.float32)

    def f_kernel(q, k, v):
        o = ops.flash_attention(q, k, v, lens, causal=True)
        return ((o * wm[:, :, None, None]) ** 2).sum()

    def f_ref(q, k, v):
        o = flash_attention_reference(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True,
            kv_len=lens).transpose(0, 2, 1, 3)
        return ((o * wm[:, :, None, None]) ** 2).sum()

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4,
                                   err_msg=f"d{name}")
    # keys/values past the true length receive exactly zero gradient
    assert float(np.abs(np.asarray(gk[1])[0, 50:]).max()) == 0.0
    assert float(np.abs(np.asarray(gk[2])[1, 77:]).max()) == 0.0


# ---------------------------------------------------------------------------
# SSD scan: state contributions stop at the true length
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunks_per_block", [1, 2])
def test_ssd_ragged_matches_reference_at_true_lengths(chunks_per_block):
    B, S, H, P, N, chunk = 2, 96, 2, 16, 8, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    lens = jnp.array([40, 77], jnp.int32)
    y = ops.ssd_scan(x, dt, A, Bm, Cm, lens, chunk=chunk,
                     chunks_per_block=chunks_per_block)
    for b in range(B):
        L = int(lens[b])
        yr, _ = ssd_reference(x[b:b + 1, :L], dt[b:b + 1, :L], A,
                              Bm[b:b + 1, :L], Cm[b:b + 1, :L])
        np.testing.assert_allclose(np.asarray(y[b:b + 1, :L]),
                                   np.asarray(yr), rtol=1e-3, atol=1e-3)


def test_ssd_ragged_bitwise_matches_unpadded_kernel():
    B, S, L, H, P, N, chunk = 1, 96, 32, 2, 16, 8, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    lens = jnp.full((B,), L, jnp.int32)
    padded = ssd_mod.ssd_scan(x, dt, A, Bm, Cm, kv_len=lens, chunk=chunk,
                              interpret=True)
    exact = ssd_mod.ssd_scan(x[:, :L], dt[:, :L], A, Bm[:, :L], Cm[:, :L],
                             chunk=chunk, interpret=True)
    np.testing.assert_array_equal(np.asarray(padded[:, :L]),
                                  np.asarray(exact))


# ---------------------------------------------------------------------------
# model-level: padded-with-lengths loss == unpadded loss
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["bert_base_paper", "mamba2_1p3b"])
def test_padded_loss_with_lengths_equals_unpadded(arch):
    cfg = get_config(arch).reduced(num_layers=2, d_model=64, d_ff=128,
                                   vocab_size=128, dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    S = 48
    lens = rng.integers(S // 2, S + 1, 2)
    tokens = rng.integers(1, 128, (2, S)).astype(np.int32)
    weights = (np.arange(S)[None, :] < lens[:, None]).astype(np.float32)
    tokens = tokens * weights.astype(np.int32)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = 0
    raw = {"tokens": tokens, "labels": labels, "weights": weights,
           "lengths": lens}
    padded = pad_batch(raw, 64)
    l_raw, m_raw = lm.loss(params, {k: jnp.asarray(v) for k, v in raw.items()
                                    if k != "lengths"})
    l_pad, m_pad = lm.loss(params, {k: jnp.asarray(v)
                                    for k, v in padded.items()})
    assert float(m_raw["tokens"]) == float(m_pad["tokens"])
    np.testing.assert_allclose(float(l_raw), float(l_pad),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# cost-aware scheduler vs byte-only oracle
# ---------------------------------------------------------------------------

def test_cost_aware_never_slower_than_byte_only():
    """Property: at equal budget, the cost-aware plan's simulated
    recompute time never exceeds the byte-only plan's, and its coverage
    is no worse (feasible whenever the byte-only plan is)."""
    rng = np.random.default_rng(11)
    for trial in range(300):
        n = int(rng.integers(1, 64))
        if trial % 3 == 0:
            est = rng.uniform(1.0, 1e9, n)
            fl = rng.uniform(1e9, 1e13, n)
        elif trial % 3 == 1:
            # equal bytes, heterogeneous flops — the flash-unit regime
            est = np.full(n, 1e8)
            fl = rng.choice([1e10, 4e10], n)
        else:
            # correlated bytes/flops with noise
            fl = rng.uniform(1e9, 1e12, n)
            est = fl * rng.uniform(0.5, 2.0, n) * 1e-3
        budget = float(rng.uniform(0, est.sum() * 1.2))
        fixed = float(rng.choice([0.0, est.sum() * 0.1]))
        byte = greedy_plan(est, budget, fixed, flops=fl, byte_only=True)
        cost = greedy_plan(est, budget, fixed, flops=fl)
        sim_b = simulate(est, byte.remat, fixed, flops=fl)
        sim_c = simulate(est, cost.remat, fixed, flops=fl)
        assert sim_c.recompute_time_s <= sim_b.recompute_time_s * (1 + 1e-12)
        assert cost.recompute_flops == pytest.approx(sim_c.recompute_flops)
        excess = est.sum() + fixed - budget
        if excess > 0:
            assert cost.covered_bytes >= min(excess, byte.covered_bytes) - 1e-6


def test_cost_aware_prefers_cheap_units_at_equal_bytes():
    """Flash-unit regime: equal bytes, 4x flops on every other unit —
    cost-aware must remat only the cheap ones when they suffice."""
    est = np.full(8, 100.0)
    fl = np.array([1., 4., 1., 4., 1., 4., 1., 4.]) * 1e9
    # excess of 400 => 4 units
    plan = greedy_plan(est, 400.0, 0.0, flops=fl)
    assert plan.remat == [True, False, True, False, True, False, True, False]
    byte = greedy_plan(est, 400.0, 0.0, flops=fl, byte_only=True)
    assert byte.recompute_flops > plan.recompute_flops


def test_plan_unit_flops_matches_unit_meta():
    """The analytic per-unit vector prices local (windowed) layers below
    global layers and scales with sequence length."""
    cfg = get_config("gemma3_12b").reduced(
        num_layers=4, d_model=64, d_ff=128, vocab_size=128,
        dtype="float32", sliding_window=32, global_interval=2)
    lm = build_model(cfg)
    small = {"tokens": np.zeros((2, 128), np.int32)}
    big = {"tokens": np.zeros((2, 256), np.int32)}
    fl_s = plan_unit_flops(lm, small)
    fl_b = plan_unit_flops(lm, big)
    assert fl_s.shape == (4,)
    # layers 0, 2 local; layers 1, 3 global (global_interval=2)
    assert fl_s[0] < fl_s[1] and fl_s[2] < fl_s[3]
    assert (fl_b > fl_s).all()
    # the meta-driven vector agrees with direct cost-model calls
    direct = unit_fwd_flops(cfg, "dense", batch=2, seq=128, is_global=False)
    assert fl_s[0] == pytest.approx(direct)
