"""Micro-benchmark: measure real device<->host offload bandwidth.

The planner prices OFFLOAD / OFFLOAD_OPT actions at ``--pcie-gbps``,
defaulting to the 16 GB/s roofline constant — a fine number for a TPU
host and a fantasy for most dev boxes.  This tool times actual transfers
through the same copy path the execution-side ``TransferLane`` uses
(pinned ``device_put`` where the build supports it, ``device_get``
otherwise) and writes the measured figure to the calibration file that
``repro.launch.roofline.calibrated_pcie_gbps`` — and therefore the
``--pcie-gbps`` default of ``repro.launch.train`` — reads.

    PYTHONPATH=src python tools/bench_offload_bw.py [--size-mb 64]
        [--repeats 3] [--out .mimose_calibration.json] [--no-write]

Override hierarchy at plan time: ``$MIMOSE_PCIE_GBPS`` > calibration
file (``$MIMOSE_CALIBRATION`` relocates it) > 16 GB/s default.
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="measure device<->host bandwidth and calibrate the "
                    "planner's PCIe pricing")
    ap.add_argument("--size-mb", type=int, default=64,
                    help="payload per timed transfer (float32 MB)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed repeats; best-of is reported (bandwidth "
                         "is a capability, not an average)")
    ap.add_argument("--out", default=None,
                    help="calibration JSON path (default: "
                         "$MIMOSE_CALIBRATION or ./.mimose_calibration.json)")
    ap.add_argument("--no-write", action="store_true",
                    help="measure and print only; leave the calibration "
                         "file untouched")
    args = ap.parse_args(argv)

    from repro.train.transfer import (calibration_path, measure_pcie_gbps,
                                      write_calibration)

    cal = measure_pcie_gbps(size_mb=args.size_mb, repeats=args.repeats)
    print(json.dumps(cal, indent=2, sort_keys=True))
    print(f"\nround-trip link: {cal['pcie_gbps']} GB/s "
          f"(D2H {cal['device_to_host_gbps']} / "
          f"H2D {cal['host_to_device_gbps']}, "
          f"pinned_host={'yes' if cal['pinned_host'] else 'no'}, "
          f"backend={cal['backend']})")
    if args.no_write:
        return 0
    path = write_calibration(cal, args.out)
    print(f"wrote {path} — repro.launch.train now prices OFFLOAD at "
          f"{cal['pcie_gbps']} GB/s unless --pcie-gbps/$MIMOSE_PCIE_GBPS "
          f"override it")
    assert path == (args.out or calibration_path())
    return 0


if __name__ == "__main__":
    sys.exit(main())
