#!/usr/bin/env python
"""Benchmark acceptance gate: diff a fresh ``bench_engine`` run against
the committed ``BENCH_engine.json`` baseline.

Three checks, stdlib-only (runs in the CI smoke job right after
``benchmarks/bench_engine.py --smoke``):

1. **Gate coverage** — every acceptance gate present in the committed
   baseline must exist in the fresh run.  A refactor that silently
   drops a gate cannot pass CI by simply not measuring it.
2. **Gate truth** — every acceptance gate in the fresh run must be
   True.  (``bench_engine`` exits non-zero on its own failures too;
   this re-checks from the artifact so the gate also works on a run
   produced elsewhere.)
3. **Metric drift** — scale-free ratio metrics (speedups, recovered
   fractions, time reductions) are compared within ``--rtol``.  The
   committed baseline is a full run while CI runs ``--smoke`` on noisy
   shared runners, so drift is reported as a WARNING by default;
   ``--strict-drift`` turns violations into failures for runs on
   comparable hardware.

Usage:
    python tools/bench_gate.py --fresh BENCH_engine.smoke.json \
        [--committed BENCH_engine.json] [--rtol 0.5] [--strict-drift]

Exit code 0 when the gates hold, 1 otherwise.
"""
from __future__ import annotations

import argparse
import json
import sys

# (path into the report, larger-is-better) — only scale-free ratios:
# absolute latencies/throughputs differ too much between the committed
# full run and a CI smoke run to gate on
DRIFT_METRICS = [
    (("scheduler", "units_96", "speedup"), True),
    (("collector", "speedup"), True),
    (("ragged", "sweep", "pad_50pct", "flash", "modeled_recovered"), True),
    (("ragged", "sweep", "pad_50pct", "ssd", "modeled_recovered"), True),
    # greedy -> solved overhead improvement at the tight heterogeneous
    # point (deterministic simulator math, identical in smoke and full)
    (("solver", "sweep", "m0.09_pcie4.0_ov0.75", "improvement_pct"), True),
    # measured offload-vs-remat step-time ratio at the transfer-bound
    # point (wall-clock, so warn-only drift absorbs runner variance)
    (("offload_exec", "speedup"), True),
    # continuous-batching vs sequential serving throughput ratio at
    # equal HBM budget (wall-clock; warn-only drift absorbs runners)
    (("serve", "speedup_vs_sequential"), True),
    # full-telemetry step-time overhead ratio (events + spans + sinks
    # vs disabled) — smaller is better; the hard <=2% bound is an
    # acceptance gate, this drift check catches creep below it
    (("telemetry", "overhead_ratio"), False),
]


def dig(report: dict, path: tuple):
    node = report
    for key in path:
        if not isinstance(node, dict) or key not in node:
            return None
        node = node[key]
    return node


def check(fresh: dict, committed: dict, rtol: float,
          strict_drift: bool) -> list:
    errors = []
    warnings = []
    base_gates = committed.get("acceptance", {})
    fresh_gates = fresh.get("acceptance", {})
    for gate in base_gates:
        if gate not in fresh_gates:
            errors.append(f"gate missing from fresh run: {gate}")
    for gate, value in fresh_gates.items():
        if value is not True:
            errors.append(f"gate failed: {gate} = {value}")
    for path, larger_better in DRIFT_METRICS:
        base = dig(committed, path)
        now = dig(fresh, path)
        name = ".".join(path)
        if base is None:
            continue                      # metric not in the baseline yet
        if now is None:
            errors.append(f"metric missing from fresh run: {name}")
            continue
        floor = base * (1.0 - rtol)
        drifted = (now < floor) if larger_better else (now > base * (1 + rtol))
        if drifted:
            msg = (f"drift: {name} = {now} vs committed {base} "
                   f"(tolerance {rtol:.0%})")
            (errors if strict_drift else warnings).append(msg)
    for w in warnings:
        print(f"WARNING {w}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh", required=True,
                    help="JSON from the bench_engine run under test")
    ap.add_argument("--committed", default="BENCH_engine.json",
                    help="committed baseline (default: BENCH_engine.json)")
    ap.add_argument("--rtol", type=float, default=0.5,
                    help="relative tolerance for ratio-metric drift")
    ap.add_argument("--strict-drift", action="store_true",
                    help="fail (not warn) on metric drift — for runs on "
                         "hardware comparable to the committed baseline")
    args = ap.parse_args(argv)

    # graceful degradation, not a crash: a branch that predates the
    # baseline (or a fresh clone that skipped the smoke run) should see
    # a clear SKIP, while a *corrupt* artifact still fails loudly
    try:
        with open(args.fresh) as f:
            fresh = json.load(f)
    except FileNotFoundError:
        print(f"bench gate: SKIP — fresh run artifact not found "
              f"({args.fresh}); run benchmarks/bench_engine.py first")
        return 0
    except json.JSONDecodeError as e:
        print(f"bench gate: FAIL — {args.fresh} is not valid JSON ({e})")
        return 1
    try:
        with open(args.committed) as f:
            committed = json.load(f)
    except FileNotFoundError:
        print(f"bench gate: SKIP — no committed baseline at "
              f"{args.committed}; nothing to gate against (commit one "
              "from a full bench_engine run to arm the gate)")
        return 0
    except json.JSONDecodeError as e:
        print(f"bench gate: FAIL — {args.committed} is not valid JSON "
              f"({e})")
        return 1
    if "acceptance" not in committed:
        print(f"bench gate: SKIP — committed baseline {args.committed} "
              "has no 'acceptance' key; gate coverage cannot be checked "
              "(re-generate the baseline with a current bench_engine)")
        return 0

    errors = check(fresh, committed, args.rtol, args.strict_drift)
    n_gates = len(fresh.get("acceptance", {}))
    if errors:
        for e in errors:
            print(f"FAIL {e}")
        print(f"bench gate: FAIL ({len(errors)} violation(s))")
        return 1
    print(f"bench gate: PASS ({n_gates} acceptance gates, "
          f"{len(DRIFT_METRICS)} drift metrics checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
