#!/usr/bin/env python
"""Summarize telemetry artifacts from a training or serving run.

Works on both outputs of the unified telemetry layer (``repro.obs``):

* a Chrome ``trace_event`` JSON written by ``--trace-out`` — span
  rollup: per (track, name) count / total / mean / max wall time,
  sorted by where the time actually went;
* a JSONL event log written by ``--events-out`` — plan-swap timeline
  (plan / solver_swap / escalation / refit / drift decisions in time
  order) and the serve admission ledger (admit / defer / reject per
  bucket with queue-wait stats).

Usage:

    python tools/trace_view.py trace.json                # span rollup
    python tools/trace_view.py events.jsonl              # everything
    python tools/trace_view.py events.jsonl --mode plans
    python tools/trace_view.py events.jsonl --mode admission
    python tools/trace_view.py trace.json --top 5

Stdlib only — safe to run anywhere the artifacts land.
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict

PLAN_KINDS = ("plan", "solver_swap", "escalation", "refit", "drift",
              "plan_poisoned", "plan_evicted")
ADMIT_KINDS = ("admit", "defer", "reject")


def _load(path: str):
    """Return ("trace", events) or ("jsonl", records) by sniffing."""
    with open(path) as f:
        text = f.read()
    try:
        doc = json.loads(text)
        if isinstance(doc, dict) and "traceEvents" in doc:
            return "trace", doc["traceEvents"]
    except json.JSONDecodeError:
        pass                        # multi-line JSONL falls through
    recs = []
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            recs.append(json.loads(line))
        except json.JSONDecodeError:
            continue
    return "jsonl", recs


# -- trace_event span rollup ------------------------------------------------
def span_rollup(events: list, top: int) -> None:
    tracks = {}
    for e in events:
        if e.get("ph") == "M" and e.get("name") == "thread_name":
            tracks[e.get("tid")] = e.get("args", {}).get("name", "?")
    agg = defaultdict(lambda: [0, 0.0, 0.0])     # (track,name) -> n,sum,max
    for e in events:
        if e.get("ph") != "X":
            continue
        key = (tracks.get(e.get("tid"), str(e.get("tid"))), e["name"])
        dur_ms = float(e.get("dur", 0)) / 1e3
        cell = agg[key]
        cell[0] += 1
        cell[1] += dur_ms
        cell[2] = max(cell[2], dur_ms)
    rows = sorted(agg.items(), key=lambda kv: -kv[1][1])[:top]
    print(f"{'track':<12} {'span':<16} {'count':>6} {'total ms':>10} "
          f"{'mean ms':>9} {'max ms':>9}")
    for (track, name), (n, tot, mx) in rows:
        print(f"{track:<12} {name:<16} {n:>6} {tot:>10.2f} "
              f"{tot / n:>9.3f} {mx:>9.2f}")


# -- event-log views --------------------------------------------------------
def plan_timeline(recs: list) -> None:
    rows = [r for r in recs if r.get("kind") in PLAN_KINDS]
    if not rows:
        print("no plan events")
        return
    t0 = rows[0].get("ts", 0.0)
    print("plan timeline (t=0 at first plan event):")
    for r in rows:
        t = r.get("ts", 0.0) - t0
        kind = r["kind"]
        if kind == "plan":
            detail = (f"bucket={r.get('bucket')} source={r.get('source')} "
                      f"k={r.get('k')} remat={r.get('n_remat')} "
                      f"offload={r.get('n_offload')}")
        elif kind == "solver_swap":
            detail = (f"bucket={r.get('bucket')} "
                      f"{r.get('greedy_s', 0):.6f}s -> "
                      f"{r.get('solved_s', 0):.6f}s "
                      f"({r.get('improvement_pct', 0):+.2f}%)")
        elif kind == "escalation":
            detail = (f"bucket={r.get('bucket')} level={r.get('level')} "
                      f"k={r.get('k')}")
        elif kind == "drift":
            detail = (f"bucket={r.get('bucket')} "
                      f"pred={r.get('predicted_bytes', 0) / 1e6:.2f}MB "
                      f"act={r.get('actual_bytes', 0) / 1e6:.2f}MB "
                      f"rel_err={r.get('rel_err', 0):.4f}"
                      + (" REFIT" if r.get("refit") else ""))
        else:
            detail = " ".join(f"{k}={v}" for k, v in r.items()
                              if k not in ("v", "ts", "kind"))
        print(f"  +{t:9.3f}s {kind:<14} {detail}")


def admission_view(recs: list) -> None:
    rows = [r for r in recs if r.get("kind") in ADMIT_KINDS]
    if not rows:
        print("no admission events")
        return
    per = defaultdict(lambda: defaultdict(int))
    waits = []
    for r in rows:
        per[r.get("bucket")][r["kind"]] += 1
        if r["kind"] == "admit":
            waits.append(float(r.get("wait_s", 0.0)))
    print("admission outcomes:")
    print(f"  {'bucket':>8} {'admit':>6} {'defer':>6} {'reject':>7}")
    for b in sorted(per, key=lambda x: (x is None, x)):
        c = per[b]
        print(f"  {str(b):>8} {c['admit']:>6} {c['defer']:>6} "
              f"{c['reject']:>7}")
    if waits:
        waits.sort()
        mid = waits[len(waits) // 2]
        print(f"  queue wait: mean {sum(waits) / len(waits):.4f}s "
              f"p50 {mid:.4f}s max {waits[-1]:.4f}s")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", help="trace_event JSON or events JSONL")
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "spans", "plans", "admission"],
                    help="view to render (auto = all that apply)")
    ap.add_argument("--top", type=int, default=20,
                    help="span rollup rows (default 20)")
    args = ap.parse_args(argv)
    kind, recs = _load(args.path)
    if kind == "trace":
        if args.mode in ("auto", "spans"):
            span_rollup(recs, args.top)
        else:
            ap.error(f"--mode {args.mode} needs an events JSONL, "
                     "got a trace_event JSON")
        return
    if args.mode in ("auto", "plans"):
        plan_timeline(recs)
    if args.mode in ("auto", "admission"):
        admission_view(recs)


if __name__ == "__main__":
    main()
