#!/usr/bin/env python
"""Docs CI gate: markdown links resolve + every module has a docstring.

Two checks, both zero-dependency (stdlib only):

1. Every relative (intra-repo) markdown link in README.md and docs/**.md
   points at a file or directory that exists.  External links (http/
   https/mailto) and pure #anchors are skipped; a link with an anchor
   (``path#section``) is checked on its path part only.
2. Every module under src/repro opens with a module docstring
   (``ast.get_docstring`` — a leading comment does not count).

Exit code 0 when clean, 1 with a per-violation report otherwise.

Usage:
    python tools/check_docs.py [repo_root]
"""
from __future__ import annotations

import ast
import pathlib
import re
import sys

# matches [text](target) while ignoring images' leading ! (still a link)
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def check_markdown_links(root: pathlib.Path) -> list:
    errors = []
    pages = [root / "README.md"]
    pages += sorted((root / "docs").glob("**/*.md"))
    for page in pages:
        if not page.exists():
            continue
        text = page.read_text()
        # strip fenced code blocks: shell snippets legitimately contain
        # bracket-paren sequences that are not links
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for m in _LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            path_part = target.split("#", 1)[0]
            if not path_part:
                continue
            resolved = (page.parent / path_part).resolve()
            if not resolved.exists():
                errors.append(f"{page.relative_to(root)}: broken link "
                              f"-> {target}")
    return errors


def check_module_docstrings(root: pathlib.Path) -> list:
    errors = []
    for mod in sorted((root / "src" / "repro").glob("**/*.py")):
        tree = ast.parse(mod.read_text())
        if not ast.get_docstring(tree):
            errors.append(f"{mod.relative_to(root)}: missing module "
                          "docstring")
    return errors


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = pathlib.Path(argv[0] if argv else ".").resolve()
    errors = check_markdown_links(root) + check_module_docstrings(root)
    for e in errors:
        print(f"FAIL {e}")
    if errors:
        print(f"\n{len(errors)} docs violation(s)")
        return 1
    print("docs OK: links resolve, all src/repro modules documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
