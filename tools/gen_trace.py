#!/usr/bin/env python
"""Write a deterministic open-loop serve trace as JSON.

Thin CLI over ``repro.data.trace.gen_trace`` so the serve bench, the
engine tests, and ad-hoc runs of ``repro.launch.serve`` all consume
byte-identical traces from one seed:

    PYTHONPATH=src python tools/gen_trace.py --num-requests 32 \
        --vocab-size 512 --rate-rps 8 --seed 0 -o trace.json

The JSON is a list of ``{rid, arrival_s, prompt, max_new_tokens}``
records (``TraceRequest.to_json``); load with
``[TraceRequest.from_json(r) for r in json.load(f)]``.
"""
import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.data.trace import gen_trace  # noqa: E402


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--num-requests", type=int, default=32)
    ap.add_argument("--vocab-size", type=int, default=512)
    ap.add_argument("--dataset", default="swag")
    ap.add_argument("--rate-rps", type=float, default=8.0,
                    help="Poisson arrival rate; <=0 = burst at t=0")
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--min-new-tokens", type=int, default=0,
                    help="when set, decode lengths are uniform in "
                         "[min, max] instead of exactly max")
    ap.add_argument("--prompt-scale", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("-o", "--out", default="-",
                    help="output path (default: stdout)")
    args = ap.parse_args()

    trace = gen_trace(num_requests=args.num_requests,
                      vocab_size=args.vocab_size, dataset=args.dataset,
                      rate_rps=args.rate_rps,
                      max_new_tokens=args.max_new_tokens,
                      min_new_tokens=args.min_new_tokens,
                      prompt_scale=args.prompt_scale, seed=args.seed)
    recs = [r.to_json() for r in trace]
    if args.out == "-":
        json.dump(recs, sys.stdout, indent=None)
        print()
    else:
        Path(args.out).write_text(json.dumps(recs))
        lens = [len(r.prompt) for r in trace]
        print(f"wrote {len(recs)} requests to {args.out} "
              f"(prompt lens {min(lens)}..{max(lens)}, "
              f"last arrival {trace[-1].arrival_s:.2f}s)")


if __name__ == "__main__":
    main()
