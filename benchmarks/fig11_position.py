"""Paper Fig. 11: peak memory vs WHICH encoder is checkpointed.

12 equal encoders (Bert-base): checkpointing a later encoder yields a
higher peak because its recompute happens while earlier activations are
still resident."""
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core import ShuttlingCollector, peak_if_checkpointing_unit
from repro.core.planner import fixed_train_bytes
from repro.models.lm import build_model
from repro.models.registry import get_config


def main(out) -> None:
    cfg = get_config("bert_base_paper").reduced(
        num_layers=12, d_model=128, d_ff=256, vocab_size=512)
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    col = ShuttlingCollector(lm)
    act = col.collect(params, {
        "tokens": jnp.ones((4, 128), jnp.int32)}).activation_vector()
    fixed = fixed_train_bytes(params)
    peaks = [peak_if_checkpointing_unit(act, i, fixed) for i in range(12)]
    for i, p in enumerate(peaks):
        out(csv_row(f"fig11.encoder{i}", 0.0,
                    f"peak_mb={p / 2**20:.2f}"))
    out(csv_row("fig11.summary", 0.0,
                f"last_is_worst={peaks[-1] == max(peaks)} "
                f"earliest_best={peaks[0] == min(peaks)}"))
