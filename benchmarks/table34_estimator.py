"""Paper Tables 3-4: regression model comparison for the memory estimator
(training time, prediction latency, error) with 10 samples."""
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import TASKS, build_task, csv_row
from repro.core import ESTIMATORS, ShuttlingCollector


def main(out) -> None:
    for task in TASKS:
        cfg, lm, params = build_task(task)
        col = ShuttlingCollector(lm)
        sizes = np.linspace(32, 352, 14).astype(int)
        data = {}
        for S in sizes:
            res = col.collect(params, {
                "tokens": jnp.ones((task.batch_size, int(S)), jnp.int32)})
            data[res.input_size] = res.activation_vector()
        train_sz = list(data)[:10]
        test_sz = list(data)[10:]
        truth = np.stack([data[s] for s in test_sz])
        for name, make in ESTIMATORS.items():
            est = make()
            for s in train_sz:
                est.add_sample(s, data[s])
            t0 = time.perf_counter()
            est.fit()
            fit_ms = 1e3 * (time.perf_counter() - t0)
            t0 = time.perf_counter()
            for _ in range(50):
                est.predict_total(test_sz[0])
            pred_us = (time.perf_counter() - t0) / 50 * 1e6
            err = est.mape(test_sz, truth)
            out(csv_row(f"table34.{task.name}.{name}", pred_us,
                        f"train_ms={fit_ms:.2f} error={100 * err:.2f}% "
                        f"samples=10"))
