"""Shared benchmark harness: the paper's four NLP tasks at CPU scale.

The paper evaluates on (task, dataset, model, batch):
    MC-Roberta (SWAG, Roberta-B, 16), QA-XLNet (SQuAD, XLNet, 16),
    QA-Bert (SQuAD, Bert-B, 12), TC-Bert (GLUE-QQP, Bert-B, 32).

We reproduce the same task *structure* — the dynamic-length distributions
are the paper's (Fig. 3) — at a reduced model scale so that a full
epoch-equivalent runs on this CPU container in seconds.  All relative
claims (Mimose vs Sublinear vs DTR throughput, overhead fractions,
estimator accuracy) are scale-free.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DTRSimPlanner, MimosePlanner, NonePlanner,
                        ShuttlingCollector, SublinearPlanner)
from repro.core.planner import fixed_train_bytes
from repro.data.pipeline import DISTRIBUTIONS, make_batches
from repro.models.lm import build_model
from repro.models.registry import get_config
from repro.optim.adamw import AdamW
from repro.train.trainer import Trainer


@dataclasses.dataclass
class Task:
    name: str
    dataset: str
    arch: str
    batch_size: int
    layers: int = 6
    d_model: int = 192
    d_ff: int = 384


TASKS = [
    Task("MC-Roberta", "swag", "bert_base_paper", 8),
    Task("QA-XLNet", "squad", "qwen3_1p7b", 4),
    Task("QA-Bert", "squad", "bert_base_paper", 4),
    Task("TC-Bert", "qqp", "bert_base_paper", 8),
]


def build_task(task: Task, seed: int = 0):
    cfg = get_config(task.arch).reduced(
        num_layers=task.layers, d_model=task.d_model, d_ff=task.d_ff,
        vocab_size=512, dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(seed))
    return cfg, lm, params


def max_input_size(task: Task, quantum: int = 32) -> int:
    d = DISTRIBUTIONS[task.dataset]
    return task.batch_size * ((d.hi + quantum - 1) // quantum) * quantum


def activation_budget(lm, params, task: Task, frac: float,
                      quantum: int = 32) -> float:
    """fixed + frac * (activation bytes at the max input size)."""
    col = ShuttlingCollector(lm)
    S = max_input_size(task, quantum) // task.batch_size
    tot = col.collect(params, {
        "tokens": jnp.ones((task.batch_size, S), jnp.int32)
    }).total_activation_bytes()
    return fixed_train_bytes(params) + frac * tot


def make_planner(kind: str, lm, params, task: Task, budget: float,
                 quantum: int = 32):
    if kind == "none":
        return NonePlanner(lm)
    if kind == "mimose":
        return MimosePlanner(lm, budget, warmup_samples=3, quantum=quantum)
    if kind == "sublinear":
        return SublinearPlanner(lm, budget,
                                max_input_size=max_input_size(task, quantum),
                                warmup_samples=3)
    if kind == "dtr":
        return DTRSimPlanner(lm, budget)
    raise KeyError(kind)


def run_epoch(lm, params, planner, task: Task, num_batches: int = 20,
              seed: int = 1, lr: float = 1e-3, warmup: bool = True) -> Dict:
    """One timed epoch.  With ``warmup=True`` the same batch sequence runs
    once first so every (shape, plan) pair is already jit-compiled — the
    timed epoch then measures steady-state step time, which is what the
    paper's Fig. 13 compares (compile cost amortises over a real epoch's
    thousands of iterations)."""
    tr = Trainer(lm, planner, AdamW(lr=lr))
    batch_list = list(make_batches(task.dataset, batch_size=task.batch_size,
                                   vocab_size=lm.cfg.vocab_size,
                                   num_batches=num_batches, quantum=32,
                                   seed=seed))
    if warmup:
        tr.run(jax.tree_util.tree_map(jnp.copy, params), batch_list)
        tr.history.clear()
    dtr_plan_before = (planner.stats["plan_time_s"]
                       if isinstance(planner, DTRSimPlanner) else 0.0)
    t0 = time.perf_counter()
    tr.run(jax.tree_util.tree_map(jnp.copy, params), batch_list)
    wall = time.perf_counter() - t0
    s = tr.summary()
    # DTR pays its (simulated) per-iteration planning cost on the critical
    # path; Mimose/Sublinear pay measured planning time (already in wall).
    extra = 0.0
    if isinstance(planner, DTRSimPlanner):
        extra = planner.stats["plan_time_s"] - dtr_plan_before
    compute = float(np.sum([st.step_time_s for st in tr.history]))
    return {
        "wall_s": wall + extra,
        "compute_s": compute + extra,
        "steps": s["steps"],
        "compiles": s["compiles"],
        "mean_remat_units": s["mean_remat_units"],
        "tokens_per_s": s["tokens_per_s"],
        "final_loss": s["final_loss"],
        "losses": [st.loss for st in tr.history],
        "plan_s": s["total_plan_s"] + extra,
    }


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.2f},{derived}"
