"""Engine benchmark: the compile-once bucketed execution path.

Measures the quantities the engine issues' acceptance criteria name and
writes everything to ``BENCH_engine.json``:

  1. scheduler  — ``greedy_plan`` (flat-array) vs the seed's python-list
     ``greedy_plan_reference`` on 24/96-unit inputs.
  2. collector  — deduplicated sheltered collection vs per-layer
     collection on an >= 8-layer homogeneous model.
  3. engine     — train steps over the SWAG-like length distributions for
     mimose / none / sublinear: XLA compile counts vs #buckets vs
     #distinct raw shapes, plan latency, cache hit rates, steps/s.
     Throughput is reported as *effective* (unpadded) tokens/s, with the
     raw padded rate as a secondary field, so padded and ragged runs are
     comparable.
  4. sharded    — the mesh-budget scenario sweep (1-device, (4, 2),
     (16, 16)): the same per-device HBM budget is infeasible on one
     device (the fixed param/grad/optimizer bytes alone exceed it) but
     the sharding-aware planner fits it on the meshes, validated by the
     per-device liveness simulator.  MeshBudget is pure axis-size math,
     so the 256-chip scenario plans on this single-CPU container.
  5. ragged     — the pad-fraction sweep: length-aware flash-attention /
     SSD kernels on a bucket-padded batch at 10/30/50% padding vs the
     unmasked kernels and the no-padding ideal; reports effective
     tokens/s and the fraction of the padding-induced throughput loss
     the masked kernels recover.
  6. remat_cost — cost-aware (bytes per recompute-FLOP) vs byte-only
     greedy selection on a heterogeneous (gemma3-style local/global)
     model under a per-device mesh budget: simulated recompute time at
     equal budget, feasibility per device.
  7. hybrid     — typed action plans (KEEP/REMAT/OFFLOAD-to-host) vs
     remat-only: a budget below the all-remat floor (fixed + boundary
     checkpoints) that only OFFLOAD can fit, an equal-budget sweep
     where the hybrid plan's simulated step overhead (recompute +
     non-overlapped PCIe transfer) never exceeds remat-only's, and a
     fully-overlapped-transfer point where hybrid is strictly faster.
  8. microbatch — adaptive microbatching (gradient accumulation as a
     planner knob): a budget below the bucket's global-minimum k=1
     footprint (exhaustive over ALL 3^n action plans) that a k=2 split
     fits, and an equal-budget sweep where the adaptive planner's
     simulated step overhead never exceeds the k=1 planner's (k=1
     always competes in the candidate search).
  9. serve      — continuous-batching serve engine vs sequential
     generation at equal HBM budget on one deterministic open-loop
     trace (warm pass both ways): throughput, token-for-token output
     equality, admission ledger (predicted peak bounds actual peak
     bounds budget), estimator accuracy on unsampled buckets, decode
     compile geometries vs the O(#buckets x #tiers) bound.
 10. offload_exec — MEASURED wall-clock of real double-buffered offload
     (repro.train.transfer.TransferLane) vs rematerialisation on a
     transfer-bound synthetic matmul chain: offload must beat remat at
     the point where recompute dwarfs the (hidden) transfer, and the
     lane's measured exposed transfer time must stay within the
     simulator's zero-overlap bound at the bandwidth the step actually
     achieved — the lane's own measured copy wall time — with a
     x1.5 + 5 ms tolerance band.

Usage:
    PYTHONPATH=src python benchmarks/bench_engine.py [--smoke] \
        [--out BENCH_engine.json]

``--smoke`` shrinks every axis so the whole file runs in under a minute
on CI while still exercising each measurement.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (MeshBudget, MimosePlanner, NonePlanner,
                        SublinearPlanner, greedy_plan_adaptive, simulate,
                        simulate_sharded, solve)
from repro.core.collector import ShuttlingCollector
from repro.core.planner import fixed_train_bytes
from repro.core.scheduler import greedy_plan, greedy_plan_reference
from repro.data.pipeline import DISTRIBUTIONS, bucket_edges, make_batches
from repro.kernels import flash_attention as fa
from repro.kernels import ssd_scan as ssd
from repro.models.lm import build_model
from repro.models.registry import get_config
from repro.obs import Telemetry, build_telemetry, flush_telemetry
from repro.optim.adamw import AdamW
from repro.sharding.budget import fixed_train_bytes_per_device
from repro.train.trainer import Trainer


def bench_scheduler(smoke: bool) -> dict:
    """(c) greedy_plan latency: flat-array vs seed implementation."""
    rng = np.random.default_rng(0)
    reps = 30 if smoke else 300
    out = {}
    for n in (24, 96):
        est = rng.uniform(1e6, 1e9, n)
        budget = est.sum() * 0.4          # ~60% of units rematerialised
        rows = {}
        for fn, name in ((greedy_plan, "fast"),
                         (greedy_plan_reference, "reference")):
            fn(est, budget)               # warm any lazy imports
            t0 = time.perf_counter()
            for _ in range(reps):
                fn(est, budget)
            rows[name] = (time.perf_counter() - t0) / reps * 1e6
        agree = (greedy_plan(est, budget).remat
                 == greedy_plan_reference(est, budget).remat)
        out[f"units_{n}"] = {
            "fast_us": round(rows["fast"], 1),
            "reference_us": round(rows["reference"], 1),
            "speedup": round(rows["reference"] / rows["fast"], 2),
            "plans_identical": bool(agree),
        }
    return out


def bench_collector(smoke: bool) -> dict:
    """(b) sheltered collection: deduplicated vs per-layer traces."""
    layers = 8
    cfg = get_config("bert_base_paper").reduced(
        num_layers=layers, d_model=96 if smoke else 128,
        d_ff=192 if smoke else 256, vocab_size=512, dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))

    def one(dedup: bool, S: int) -> float:
        col = ShuttlingCollector(lm, dedup=dedup)
        batch = {"tokens": jnp.ones((2, S), jnp.int32),
                 "labels": jnp.ones((2, S), jnp.int32)}
        t0 = time.perf_counter()
        res = col.collect(params, batch)
        return time.perf_counter() - t0, res

    reps = 2 if smoke else 3
    t_base = min(one(False, 128)[0] for _ in range(reps))
    t_dedup, res = min(((t, r) for t, r in (one(True, 128)
                                            for _ in range(reps))),
                       key=lambda p: p[0])
    base_res = one(False, 128)[1]
    return {
        "layers": layers,
        "per_layer_s": round(t_base, 4),
        "dedup_s": round(t_dedup, 4),
        "speedup": round(t_base / t_dedup, 2),
        "traced_units": res.traced_units,
        "dedup_hits": res.dedup_hits,
        "byte_identical": bool(np.array_equal(res.activation_vector(),
                                              base_res.activation_vector())),
    }


def bench_engine(smoke: bool) -> dict:
    """(a) compile counts bounded by #buckets + throughput comparison.

    The pipeline emits batches at a fine quantum (many distinct raw
    shapes); the mimose planner buckets at a coarser quantum, so the
    engine's compile count collapses onto the bucket set while the
    unbucketed baseline compiles once per raw shape.
    """
    cfg = get_config("bert_base_paper").reduced(
        num_layers=2 if smoke else 4, d_model=128, d_ff=256,
        vocab_size=512, dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))

    dataset = "swag"
    batch_size = 4
    steps = 10 if smoke else 30
    raw_quantum = 8                  # fine-grained -> many raw shapes
    engine_quantum = 64              # planner bucket granularity

    col = ShuttlingCollector(lm)
    S_hi = DISTRIBUTIONS[dataset].hi
    tot = col.collect(params, {
        "tokens": jnp.ones((batch_size, S_hi), jnp.int32)
    }).total_activation_bytes()
    budget = fixed_train_bytes(params) + 0.5 * tot

    batches = list(make_batches(dataset, batch_size=batch_size,
                                vocab_size=cfg.vocab_size,
                                num_batches=steps, quantum=raw_quantum,
                                seed=1))
    raw_shapes = {b["tokens"].shape for b in batches}
    n_buckets_possible = len(bucket_edges(DISTRIBUTIONS[dataset],
                                          engine_quantum))

    results = {}
    for kind in ("mimose", "none", "sublinear"):
        if kind == "mimose":
            planner = MimosePlanner(lm, budget, quantum=engine_quantum,
                                    warmup_samples=3)
        elif kind == "sublinear":
            planner = SublinearPlanner(
                lm, budget,
                max_input_size=batch_size * S_hi, warmup_samples=3)
        else:
            planner = NonePlanner(lm)
        tr = Trainer(lm, planner, AdamW(lr=1e-3))
        p = jax.tree_util.tree_map(jnp.copy, params)
        opt_state = tr.optimizer.init(p)
        t0 = time.perf_counter()
        for b in batches:
            p, opt_state, _ = tr.step(p, opt_state, b)
        wall = time.perf_counter() - t0
        s = tr.summary()
        results[kind] = {
            "steps": steps,
            "compiles": s["compiles"],
            "buckets_seen": s["buckets"],
            "jit_hits": s["jit_hits"],
            "steps_per_s": round(steps / wall, 3),
            # effective (unpadded) tokens/s — the comparable number;
            # the raw padded rate rides along as a diagnostic
            "tokens_per_s": round(s["tokens_per_s"], 1),
            "padded_tokens_per_s": round(s["padded_tokens_per_s"], 1),
            "pad_fraction": round(s["pad_fraction"], 4),
            "mean_plan_ms": round(s["total_plan_s"] / steps * 1e3, 3),
            "mean_remat_units": s["mean_remat_units"],
        }
        if kind == "mimose":
            results[kind]["plan_cache"] = {
                "hits": planner.stats["cache_hits"],
                "misses": planner.stats["cache_misses"],
                "collections": planner.stats["collections"],
            }
    results["distinct_raw_shapes"] = len(raw_shapes)
    results["bucket_set_size"] = n_buckets_possible
    results["engine_quantum"] = engine_quantum
    return results


def bench_sharded(smoke: bool) -> dict:
    """(d) mesh-budget scenario sweep: 1-device vs (4, 2) vs (16, 16).

    One per-device HBM budget (75% of the single-device fixed bytes, so
    a lone device cannot even hold the param/grad/optimizer state) is
    planned on each mesh shape; the per-device liveness simulation then
    checks the plan's peak against the budget.
    """
    cfg = get_config("bert_base_paper").reduced(
        num_layers=2 if smoke else 4, d_model=128, d_ff=256,
        vocab_size=512, dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    S = 32 if smoke else 64
    batch = {"tokens": jnp.ones((16, S), jnp.int32),
             "labels": jnp.ones((16, S), jnp.int32)}

    fixed_global = fixed_train_bytes(params)
    hbm = 0.75 * fixed_global
    out = {"hbm_per_device_bytes": int(hbm),
           "single_device_fixed_bytes": int(fixed_global),
           "scenarios": {}}
    for shape in ((1,), (4, 2), (16, 16)):
        budget = MeshBudget.from_shape(shape, hbm, zero1=True)
        # the scheduler models peak as fixed + saved residuals; the
        # liveness replay additionally charges the executing unit's
        # recomputed residuals + gradient working set (up to 2x the
        # largest unit), so plan with that much headroom
        col = ShuttlingCollector(lm, mesh_budget=budget).collect(
            params, batch)
        margin = 2 * float(col.device_activation_vector().max(initial=0.0))
        planner = MimosePlanner(lm, max(hbm - margin, 0.0),
                                mesh_budget=budget,
                                warmup_samples=1, quantum=32)
        t0 = time.perf_counter()
        mask, _info = planner.plan(params, batch)
        t_plan = time.perf_counter() - t0
        sim = simulate_sharded(col.device_activation_vector(), mask,
                               planner.resolve_fixed_bytes(params), budget.n_devices)
        name = "x".join(str(s) for s in shape)
        out["scenarios"][name] = {
            "n_devices": budget.n_devices,
            "fixed_bytes_per_device": int(planner.resolve_fixed_bytes(params)),
            "peak_bytes_per_device": int(sim.peak_bytes_per_device),
            "budget_bytes_per_device": int(hbm),
            "fits": bool(sim.fits(hbm)),
            "n_remat": int(sum(mask)),
            "plan_ms": round(t_plan * 1e3, 3),
        }
    sc = out["scenarios"]
    out["single_device_infeasible"] = not sc["1"]["fits"]
    out["sharded_fit_per_device"] = sc["4x2"]["fits"] and sc["16x16"]["fits"]
    return out


def _time_best(fn, args, reps: int) -> float:
    """Best-of-``reps`` wall time of an already-jitted callable."""
    jax.block_until_ready(fn(*args))          # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _flash_executed_flops(B, H, hd, S, L, bq, bk) -> float:
    """MXU FLOPs the causal flash kernel executes at bucket S with true
    length L — mirrors the kernel's trip-count clamps exactly: per query
    block, upper = min(causal bound, cdiv(L, bk)), zero once the block
    is fully inside the padding; 2 matmuls (qk^T, p@v) per trip."""
    nqb = -(-S // bq)
    nkb = -(-S // bk)
    trips = 0
    for qi in range(nqb):
        if qi * bq >= L:
            continue
        trips += min(-(-((qi + 1) * bq) // bk), nkb, -(-L // bk))
    return float(B * H * trips) * 4.0 * bq * bk * hd


def _ssd_executed_flops(B, H, P, N, S, L, chunk) -> float:
    """MXU FLOPs the SSD kernel executes at bucket S with true length L
    — the dynamic chunk loop runs cdiv(L, chunk) of the S/chunk chunks;
    per chunk: CB^T (Q,Q,N), w@x (Q,Q,P), two (Q,P,N) state terms."""
    Q = chunk
    chunks = -(-L // Q)
    per_chunk = 2.0 * Q * Q * N + 2.0 * Q * Q * P + 4.0 * Q * P * N
    return float(B * H * chunks) * per_chunk


def bench_ragged(smoke: bool) -> dict:
    """(e) pad-fraction sweep: masked (length-aware) kernels on a padded
    bucket vs unmasked kernels vs the no-padding ideal.

    For each pad fraction p the bucket sequence length S carries
    L = S*(1-p) real tokens.  Three variants per kernel:

      * ideal    — kernel at shape L (what a shape-per-length engine
                   would pay per step, ignoring its recompiles);
      * unmasked — kernel at shape S with no length operand (computes
                   over padding: the PR-1 engine's behaviour);
      * masked   — kernel at shape S with ``kv_len = L`` (same compiled
                   executable for every L — compile-once preserved).

    Two views of effective (real tokens only) throughput:

      * modeled  — executed kernel FLOPs (exact trip counts of the
                   length-aware clamps, above) at the TPU roofline
                   (``PEAK_FLOPS``) — deterministic, the number the
                   acceptance gate reads, in the same hardware-free
                   methodology as the dry-run/roofline benchmarks;
      * measured — interpret-mode wall time on this host (secondary
                   evidence that the dynamic trip counts really shrink
                   at runtime; CPU emulation overhead per grid cell
                   makes it an undercount of the TPU win).

    ``recovered`` = (masked - unmasked) / (ideal - unmasked): the
    fraction of the padding-induced throughput loss the masked kernel
    wins back.
    """
    from repro.launch.roofline import PEAK_FLOPS
    key = jax.random.PRNGKey(0)
    reps = 3 if smoke else 8

    B, H, hd = 1, 1, 32
    S = 2048 if smoke else 4096
    bq, bk = 128, 32
    flash_padded = jax.jit(lambda q, k, v, kvl: fa.flash_attention_fwd(
        q, k, v, kvl, causal=True, block_q=bq, block_k=bk, interpret=True))

    def make_qkv(s):
        ks = jax.random.split(key, 3)
        return tuple(jax.random.normal(k_, (B, H, s, hd), jnp.float32)
                     for k_ in ks)

    P, N, chunk, K = 64, 64, 64, 4
    Hs = 2

    def ssd_fn():
        return jax.jit(lambda x, dt, A, Bm, Cm, kvl: ssd.ssd_scan(
            x, dt, A, Bm, Cm, kv_len=kvl, chunk=chunk, chunks_per_block=K,
            interpret=True))

    Ss = 2048

    def make_ssd(s):
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (B, s, Hs, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, s, Hs)))
        A = -jnp.exp(jax.random.normal(ks[2], (Hs,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, s, N))
        Cm = jax.random.normal(ks[4], (B, s, N))
        return x, dt, A, Bm, Cm

    ssd_padded = ssd_fn()
    qkv_S = make_qkv(S)
    ssd_S = make_ssd(Ss)
    out = {"flash_bucket_seq": S, "ssd_bucket_seq": Ss, "batch": B,
           "method": "modeled = executed kernel FLOPs / PEAK_FLOPS "
                     "(deterministic); measured = interpret-mode wall "
                     "time on this host",
           "sweep": {}}
    for pf in (0.1, 0.3, 0.5):
        row = {}
        for name, bucket, span in (("flash", S, bq), ("ssd", Ss, chunk * K)):
            # real length kept span-aligned so the ideal shape exists
            L = max(span, int(round(bucket * (1.0 - pf) / span)) * span)
            kvl = jnp.full((B,), L, jnp.int32)
            full = jnp.full((B,), bucket, jnp.int32)
            if name == "flash":
                w_id = _flash_executed_flops(B, H, hd, L, L, bq, bk)
                w_un = _flash_executed_flops(B, H, hd, bucket, bucket, bq, bk)
                w_mk = _flash_executed_flops(B, H, hd, bucket, L, bq, bk)
                args_S = qkv_S
                args_L = tuple(a[:, :, :L] for a in qkv_S)  # seq axis 2
                fn_p = flash_padded
                fn_i = jax.jit(lambda q, k, v, kvl: fa.flash_attention_fwd(
                    q, k, v, kvl, causal=True, block_q=bq, block_k=bk,
                    interpret=True))
            else:
                w_id = _ssd_executed_flops(B, Hs, P, N, L, L, chunk)
                w_un = _ssd_executed_flops(B, Hs, P, N, Ss, Ss, chunk)
                w_mk = _ssd_executed_flops(B, Hs, P, N, Ss, L, chunk)
                args_S = ssd_S
                x_, dt_, A_, Bm_, Cm_ = ssd_S                # seq axis 1
                args_L = (x_[:, :L], dt_[:, :L], A_, Bm_[:, :L], Cm_[:, :L])
                fn_p, fn_i = ssd_padded, ssd_fn()
            tok = B * L
            m_id, m_un, m_mk = (tok / (w / PEAK_FLOPS)
                                for w in (w_id, w_un, w_mk))
            # tether the executed-work model to the executable: the
            # masked run over the padded bucket must reproduce the
            # ideal (unpadded-shape) run at the valid positions, or the
            # modeled numbers describe a kernel that doesn't exist
            got = np.asarray(fn_p(*(args_S + (kvl,))))
            want = np.asarray(fn_i(*(args_L + (kvl,))))
            got = got[:, :, :L] if name == "flash" else got[:, :L]
            want = want[:, :, :L] if name == "flash" else want[:, :L]
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
            t_id = _time_best(fn_i, args_L + (kvl,), reps)
            t_un = _time_best(fn_p, args_S + (full,), reps)
            t_mk = _time_best(fn_p, args_S + (kvl,), reps)
            r_id, r_un, r_mk = tok / t_id, tok / t_un, tok / t_mk
            row[name] = {
                "real_len": L,
                "modeled_eff_tokens_per_s": {
                    "ideal": round(m_id, 1), "unmasked": round(m_un, 1),
                    "masked": round(m_mk, 1)},
                "modeled_recovered": round((m_mk - m_un) / (m_id - m_un), 3)
                                     if m_id > m_un else 1.0,
                "measured_eff_tokens_per_s": {
                    "ideal": round(r_id, 1), "unmasked": round(r_un, 1),
                    "masked": round(r_mk, 1)},
                "measured_recovered": round((r_mk - r_un) / (r_id - r_un), 3)
                                      if r_id > r_un else 1.0,
            }
        out["sweep"][f"pad_{int(pf * 100)}pct"] = row
    return out


def bench_remat_cost(smoke: bool) -> dict:
    """(f) cost-aware vs byte-only remat selection at equal budget.

    A gemma3-style reduced model (sliding-window local layers with a
    global layer every 2nd) under the flash-attention kernels is the
    motivating heterogeneous case: every unit's O(S) flash residuals
    free the SAME bytes, but a global full-attention layer costs far
    more FLOPs to recompute than a windowed local layer.  Byte-only
    selection cannot tell them apart (one bucket, timestamp order);
    cost-aware selection remats the cheap local layers first.  Both
    selectors plan the same per-device mesh budget sweep; the per-device
    liveness simulator reports recompute time and validates feasibility.
    """
    cfg = get_config("gemma3_12b").reduced(
        num_layers=4 if smoke else 8, d_model=128, d_ff=256,
        vocab_size=512, dtype="float32", sliding_window=64,
        global_interval=2)
    lm = build_model(cfg, attn_impl="flash")
    params = lm.init(jax.random.PRNGKey(0))
    B, S = 4, 512
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}

    mesh_shape = (4, 2)
    budget_probe = MeshBudget.from_shape(mesh_shape, 1e18, zero1=True)
    col = ShuttlingCollector(lm, mesh_budget=budget_probe).collect(
        params, batch)
    act = col.device_activation_vector()
    fl = col.flops_vector()                       # cost model rides along
    fl_dev = fl / budget_probe.n_devices          # SPMD: per-device share
    fixed = fixed_train_bytes_per_device(params, budget_probe)
    # liveness replay charges the executing unit's working set on top of
    # fixed + saved residuals; plan with that much headroom (cf. sharded)
    margin = 2 * float(act.max(initial=0.0))

    out = {"arch": cfg.name, "units": lm.num_plan_units(),
           "mesh": "x".join(map(str, mesh_shape)), "budgets": {}}
    for cover in (0.3, 0.5, 0.7):
        budget = fixed + (1.0 - cover) * float(act.sum()) + margin
        row = {}
        for name, byte_only in (("byte_only", True), ("cost_aware", False)):
            plan = greedy_plan(act, budget - margin, fixed, flops=fl_dev,
                               byte_only=byte_only)
            sim = simulate_sharded(act, plan.remat, fixed,
                                   budget_probe.n_devices, flops=fl_dev)
            row[name] = {
                "n_remat": plan.n_remat,
                "recompute_gflops_per_dev": round(
                    sim.per_device.recompute_flops / 1e9, 3),
                "recompute_time_us": round(sim.recompute_time_s * 1e6, 3),
                "peak_bytes_per_device": int(sim.peak_bytes_per_device),
                "fits_budget": bool(sim.fits(budget)),
            }
        b, c = row["byte_only"], row["cost_aware"]
        row["time_reduction"] = round(
            1.0 - c["recompute_time_us"] / b["recompute_time_us"], 4) \
            if b["recompute_time_us"] else 0.0
        out["budgets"][f"cover_{int(cover * 100)}pct"] = row
    return out


def bench_hybrid(smoke: bool) -> dict:
    """(g) hybrid remat+offload action plans vs remat-only.

    Three claims, all validated by the liveness simulator on collected
    (exact, abstract) byte vectors:

      * feasibility gap — REMAT must keep every unit's boundary tensor
        on device as its recompute checkpoint (and KEEP keeps all of
        it), so every boolean plan has a peak floor; OFFLOAD streams
        the checkpoint to host too.  A budget between the exhaustive
        best-boolean-plan peak and the all-offload peak is infeasible
        for every remat mask but feasible hybrid.
      * floor property — at equal (feasible-for-both) budgets the hybrid
        plan's simulated step overhead (recompute + non-overlapped PCIe
        transfer) never exceeds the remat-only plan's: the remat-only
        plan always competes in the scheduler's candidate set.
      * overlapped win — with the transfer fully hidden under compute
        (``offload_overlap=1``) OFFLOAD is strictly cheaper than any
        recompute, so the hybrid plan eliminates recompute time at a
        budget where remat-only pays it.
    """
    cfg = get_config("bert_base_paper").reduced(
        num_layers=4 if smoke else 8, d_model=128, d_ff=256,
        vocab_size=512, dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    B, S = 4, 128 if smoke else 256
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    col = ShuttlingCollector(lm).collect(params, batch)
    act = col.activation_vector()
    out = col.output_vector()
    off = col.offloadable_vector()
    fl = col.flops_vector()
    fixed = fixed_train_bytes(params)
    pcie = 16e9
    # liveness headroom: fwd charges act+out over the saved set, bwd
    # resurrects an offloaded/rematted unit's residuals under its own
    # gradient working set (up to 2x the largest unit)
    margin = 2 * float(act.max()) + float(out.max())

    def replay(plan, overlap=0.5):
        return simulate(act, plan.actions, fixed, out, fl,
                        offload_bytes=off, pcie_bytes_per_s=pcie,
                        overlap=overlap)

    res = {"arch": cfg.name, "units": lm.num_plan_units(),
           "pcie_gbps": pcie / 1e9,
           "remat_floor_bytes": int(fixed + out.sum()),
           "hybrid_floor_bytes": int(fixed + (act - off).sum())}

    # -- feasibility gap: a budget NO boolean remat mask can fit --------
    # exhaustive over all 2^n masks (n <= 8 here): the true remat-only
    # floor, not just the all-remat plan
    import itertools
    bool_floor = min(
        simulate(act, mask, fixed, out, fl).peak_bytes
        for mask in itertools.product([False, True], repeat=len(act)))
    all_off_peak = simulate(act, [2] * len(act), fixed, out, fl,
                            offload_bytes=off,
                            pcie_bytes_per_s=pcie).peak_bytes
    gap_budget = 0.5 * (all_off_peak + bool_floor)
    hyb = greedy_plan(act, gap_budget, fixed, flops=fl, output_bytes=out,
                      offload_bytes=off, pcie_bytes_per_s=pcie)
    sim_h = replay(hyb)
    res["below_remat_floor"] = {
        "budget_bytes": int(gap_budget),
        "best_bool_plan_peak_bytes": int(bool_floor),
        "any_bool_plan_fits": bool(bool_floor <= gap_budget),
        "hybrid_peak_bytes": int(sim_h.peak_bytes),
        "hybrid_fits": bool(sim_h.fits(gap_budget)),
        "n_offload": hyb.n_offload,
        "offload_time_us": round(sim_h.offload_time_s * 1e6, 3),
    }

    # -- equal-budget sweep: hybrid never worse than remat-only ---------
    # scheduling-vs-simulation headroom (cf. the sharded sweep): plans
    # are built against budget - margin, validated against budget
    res["equal_budget"] = {}
    for cover in (0.3, 0.5, 0.7):
        budget = fixed + (1.0 - cover) * float(act.sum()) \
            + float(out.sum()) + margin
        # the legacy remat-only greedy needs the margin convention; the
        # hybrid planner replays liveness internally, so it takes the
        # true budget and handles transients itself
        ro = greedy_plan(act, budget - margin, fixed, flops=fl)
        hy = greedy_plan(act, budget, fixed, flops=fl,
                         output_bytes=out, offload_bytes=off,
                         pcie_bytes_per_s=pcie)
        sim_r, sim_y = replay(ro), replay(hy)
        res["equal_budget"][f"cover_{int(cover * 100)}pct"] = {
            "budget_bytes": int(budget),
            "remat_only": {
                "n_remat": ro.n_remat,
                "overhead_us": round(sim_r.step_overhead_s * 1e6, 3),
                "fits": bool(sim_r.fits(budget))},
            "hybrid": {
                "n_remat": hy.n_remat, "n_offload": hy.n_offload,
                "overhead_us": round(sim_y.step_overhead_s * 1e6, 3),
                "fits": bool(sim_y.fits(budget))},
        }

    # -- fully-overlapped transfer: offload strictly beats recompute ----
    budget = fixed + 0.5 * float(act.sum()) + float(out.sum()) + margin
    ro = greedy_plan(act, budget - margin, fixed, flops=fl)
    hy = greedy_plan(act, budget, fixed, flops=fl,
                     output_bytes=out, offload_bytes=off,
                     pcie_bytes_per_s=pcie, offload_overlap=1.0)
    sim_r, sim_y = replay(ro, 1.0), replay(hy, 1.0)
    res["overlapped_transfer"] = {
        "budget_bytes": int(budget),
        "remat_only_overhead_us": round(sim_r.step_overhead_s * 1e6, 3),
        "hybrid_overhead_us": round(sim_y.step_overhead_s * 1e6, 3),
        "hybrid_n_offload": hy.n_offload,
        "both_fit": bool(sim_r.fits(budget) and sim_y.fits(budget)),
    }
    return res


def bench_microbatch(smoke: bool) -> dict:
    """(h) adaptive microbatching vs the k=1 planner.

    Two claims, both on collected (exact, abstract) per-microbatch byte
    vectors and validated by the liveness simulator:

      * feasibility gap — every k=1 plan has a peak floor: even
        all-OFFLOAD keeps the non-offloadable residues plus the
        executing unit's transient working set on device, so there is
        a global-minimum footprint for the bucket (exhaustive over ALL
        3^n action plans).  Splitting the batch shrinks the per-unit
        activation terms themselves, so a budget between the k=2 and
        k=1 exhaustive floors is infeasible for every k=1 action plan
        yet feasible at k=2 — the scenario the pre-microbatching
        system flatly could not run.
      * never-worse floor — the adaptive candidate search always
        includes k=1, so at every equal (k=1-feasible) budget the
        chosen (k, action-plan) pair's simulated step overhead
        (recompute + exposed transfer + accumulation) never exceeds
        the k=1 planner's.
    """
    import itertools

    cfg = get_config("bert_base_paper").reduced(
        num_layers=4 if smoke else 6, d_model=128, d_ff=256,
        vocab_size=512, dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    B, S = 8, 128 if smoke else 256
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    fixed = fixed_train_bytes(params)
    pcie = 16e9
    candidate_ks = (1, 2, 4)

    # exact per-microbatch vectors per split: one abstract collection
    # on each split geometry (what the planner's estimator predicts
    # once warm — collections keep the benchmark deterministic)
    vecs = {}
    for k in candidate_ks:
        Bk = -(-B // k)
        probe = {key: v[:Bk] for key, v in batch.items()}
        col = ShuttlingCollector(lm).collect(params, probe)
        vecs[k] = {"est_mem": col.activation_vector(),
                   "output_bytes": col.output_vector(),
                   "offload_bytes": col.offloadable_vector(),
                   "flops": col.flops_vector()}

    def vectors_of_k(k):
        return vecs[k]

    def exhaustive_floor(k: int) -> float:
        """Minimum simulated peak over EVERY action plan at split k —
        the true global-minimum footprint of the bucket (small n)."""
        v = vecs[k]
        n = len(v["est_mem"])
        return min(
            simulate(v["est_mem"], plan, fixed, v["output_bytes"],
                     v["flops"], offload_bytes=v["offload_bytes"],
                     pcie_bytes_per_s=pcie, microbatch=k).peak_bytes
            for plan in itertools.product((0, 1, 2), repeat=n))

    def replay(plan):
        v = vecs[plan.microbatch]
        return simulate(v["est_mem"], plan.actions, fixed,
                        v["output_bytes"], v["flops"],
                        offload_bytes=v["offload_bytes"],
                        pcie_bytes_per_s=pcie,
                        microbatch=plan.microbatch,
                        accum_overhead_s=5e-4)

    res = {"arch": cfg.name, "units": lm.num_plan_units(),
           "batch": B, "seq": S, "candidate_ks": list(candidate_ks)}

    # -- feasibility gap: below the k=1 global-minimum footprint --------
    k1_floor = exhaustive_floor(1)
    k2_floor = exhaustive_floor(2)
    gap_budget = 0.5 * (k1_floor + k2_floor)
    plan = greedy_plan_adaptive(vectors_of_k, gap_budget, fixed,
                                candidate_ks=[1, 2],
                                pcie_bytes_per_s=pcie,
                                accum_overhead_s=5e-4)
    sim = replay(plan)
    res["below_k1_floor"] = {
        "budget_bytes": int(gap_budget),
        "k1_global_min_peak_bytes": int(k1_floor),
        "k2_global_min_peak_bytes": int(k2_floor),
        "any_k1_plan_fits": bool(k1_floor <= gap_budget),
        "chosen_microbatch": plan.microbatch,
        "adaptive_peak_bytes": int(sim.peak_bytes),
        "adaptive_fits": bool(sim.fits(gap_budget)),
    }

    # -- equal-budget sweep: adaptive never worse than the k=1 planner --
    act1 = vecs[1]["est_mem"]
    margin = 2 * float(act1.max()) + float(vecs[1]["output_bytes"].max())
    res["equal_budget"] = {}
    for cover in (0.3, 0.5, 0.7):
        budget = fixed + (1.0 - cover) * float(act1.sum()) \
            + float(vecs[1]["output_bytes"].sum()) + margin
        p1 = greedy_plan_adaptive(vectors_of_k, budget, fixed,
                                  candidate_ks=[1],
                                  pcie_bytes_per_s=pcie,
                                  accum_overhead_s=5e-4)
        pk = greedy_plan_adaptive(vectors_of_k, budget, fixed,
                                  candidate_ks=list(candidate_ks),
                                  pcie_bytes_per_s=pcie,
                                  accum_overhead_s=5e-4)
        s1, sk = replay(p1), replay(pk)
        res["equal_budget"][f"cover_{int(cover * 100)}pct"] = {
            "budget_bytes": int(budget),
            "k1": {"n_remat": p1.n_remat,
                   "overhead_us": round(s1.step_overhead_s * 1e6, 3),
                   "fits": bool(s1.fits(budget))},
            "adaptive": {"microbatch": pk.microbatch,
                         "n_remat": pk.n_remat,
                         "overhead_us": round(sk.step_overhead_s * 1e6, 3),
                         "fits": bool(sk.fits(budget))},
        }
    return res


def bench_solver(smoke: bool) -> dict:
    """(i) the optimal-plan tier vs the greedy density heuristic.

    The PR-5 hybrid point is the motivating case: a gemma3-style
    heterogeneous model (cheap sliding-window layers, expensive global
    layers every 2nd) with remat+offload+microbatch all in play.  The
    greedy scores one (unit, action) density at a time, so at budgets
    where the optimum mixes actions across the local/global cost gap it
    over-pays; ``solve()`` (exhaustive here — n <= 8 — i.e. the same
    ground truth as ``tests/oracle.py``) finds the true optimum.  The
    sweep replays both plans through the same scalar simulator:

      * never worse — at every (budget, PCIe, overlap) point where the
        greedy plan fits, the solved plan fits at overhead <= greedy's
        (greedy competes as a candidate, so this holds by construction
        — the bench validates the construction);
      * strictly better — at the tight-budget points the solved plan's
        simulated step overhead beats greedy's outright;
      * dp == exhaustive — the chain DP reproduces the brute-force
        optimum at every point (the oracle property, on real collected
        vectors rather than randomized ones).
    """
    cfg = get_config("gemma3_12b").reduced(
        num_layers=6, d_model=128, d_ff=256, vocab_size=512,
        dtype="float32", sliding_window=64, global_interval=2)
    lm = build_model(cfg, attn_impl="flash")
    params = lm.init(jax.random.PRNGKey(0))
    B, S = 4, 512
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    fixed = fixed_train_bytes(params)
    candidate_ks = (1, 2, 4)
    accum = 5e-4

    vecs = {}
    for k in candidate_ks:
        Bk = -(-B // k)
        probe = {key: v[:Bk] for key, v in batch.items()}
        col = ShuttlingCollector(lm).collect(params, probe)
        vecs[k] = {"est_mem": col.activation_vector(),
                   "output_bytes": col.output_vector(),
                   "offload_bytes": col.offloadable_vector(),
                   "flops": col.flops_vector()}

    def vectors_of_k(k):
        return vecs[k]

    act1 = vecs[1]["est_mem"]

    def replay(plan, pcie, overlap):
        v = vecs[plan.microbatch]
        return simulate(v["est_mem"], plan.actions, fixed,
                        v["output_bytes"], v["flops"],
                        offload_bytes=v["offload_bytes"],
                        pcie_bytes_per_s=pcie, overlap=overlap,
                        microbatch=plan.microbatch,
                        accum_overhead_s=accum)

    # (budget multiplier on act.sum(), pcie GB/s, overlap) — the tight
    # points are where the greedy's one-action-at-a-time densities
    # misprice the local/global recompute gap
    points = [(0.09, 4.0, 0.75), (0.35, 28.0, 0.95)]
    if not smoke:
        points += [(0.09, 24.0, 0.75), (0.60, 16.0, 0.5),
                   (0.90, 16.0, 0.5)]
    res = {"arch": cfg.name, "units": lm.num_plan_units(),
           "candidate_ks": list(candidate_ks), "sweep": {}}
    for m, pcie_g, ov in points:
        pcie = pcie_g * 1e9
        budget = fixed + m * float(act1.sum())
        g = greedy_plan_adaptive(vectors_of_k, budget, fixed,
                                 candidate_ks=list(candidate_ks),
                                 pcie_bytes_per_s=pcie,
                                 offload_overlap=ov,
                                 accum_overhead_s=accum)
        gs = replay(g, pcie, ov)
        r_ex = solve(vectors_of_k, budget, fixed,
                     candidate_ks=list(candidate_ks),
                     pcie_bytes_per_s=pcie, offload_overlap=ov,
                     accum_overhead_s=accum, method="exhaustive")
        r_dp = solve(vectors_of_k, budget, fixed,
                     candidate_ks=list(candidate_ks),
                     pcie_bytes_per_s=pcie, offload_overlap=ov,
                     accum_overhead_s=accum, method="dp",
                     include_greedy=False)
        greedy_fits = bool(gs.peak_bytes <= budget + 1e-6)
        row = {
            "budget_mult": m, "pcie_gbps": pcie_g, "overlap": ov,
            "greedy": {"overhead_us": round(gs.step_overhead_s * 1e6, 3),
                       "microbatch": g.microbatch, "fits": greedy_fits},
            "solved": {"overhead_us": round(r_ex.overhead_s * 1e6, 3),
                       "microbatch": r_ex.plan.microbatch
                       if r_ex.plan else 0,
                       "feasible": r_ex.feasible,
                       "solve_ms": round(r_ex.solve_s * 1e3, 3)},
            "dp_overhead_us": round(r_dp.overhead_s * 1e6, 3),
            "dp_matches_exhaustive":
                bool(r_dp.feasible == r_ex.feasible
                     and abs(r_dp.score - r_ex.score)
                     <= 1e-9 * max(abs(r_ex.score), 1e-12)),
            "never_worse": bool((not greedy_fits)
                                or (r_ex.feasible and r_ex.overhead_s
                                    <= gs.step_overhead_s + 1e-12)),
            "strict_win": bool(greedy_fits and r_ex.feasible
                               and r_ex.overhead_s
                               < gs.step_overhead_s * (1.0 - 1e-9)),
        }
        if row["strict_win"]:
            row["improvement_pct"] = round(
                100.0 * (1.0 - r_ex.overhead_s / gs.step_overhead_s), 2)
        res["sweep"][f"m{m}_pcie{pcie_g}_ov{ov}"] = row
    return res


def bench_offload_exec(smoke: bool) -> dict:
    """(j) real overlapped offload, MEASURED — not simulated.

    A synthetic n-unit matmul chain where each unit's backward needs a
    d x d residual the forward produced.  Two executions of the SAME
    math (final gradients compared bitwise-close):

      * offload — the residual streams to host on the TransferLane
        right after the forward dispatches the next unit, and streams
        back (prefetched one unit ahead) behind the backward's compute:
        the double-buffered path the trainer's OFFLOAD_OPT choreography
        uses.
      * remat  — the residual is discarded and the backward re-runs the
        unit's forward chain to regenerate it (keeping only the unit's
        boundary input, exactly what a REMAT action keeps on device).

    The point is transfer-bound by construction: the recompute chain
    costs r heavy matmuls per unit while the residual is ~1 d^2 buffer,
    so hidden transfer must beat recompute on wall-clock.  The second
    gate holds the lane's measured exposed time to the simulator's
    zero-overlap exposure evaluated at the bandwidth the step actually
    achieved — i.e. the lane's own measured copy wall time (``copy_s``,
    == bytes / realised GB/s) — with a x1.5 + 5 ms tolerance band
    (documented in docs/ARCHITECTURE.md "Real overlapped offload").  A
    caller can wait each copy out at most once, so exposure above the
    band means the accounting broke (double-charged waits), not just a
    slow link; below it is overlap doing its job.  The idle-link
    calibration (``measure_pcie_gbps``) is reported alongside as a
    ``contention_factor`` — ~1 on hosts with a real DMA engine, large
    on this CPU container where copies and compute share cores.
    """
    from repro.train.transfer import TransferLane, measure_pcie_gbps

    d = 256 if smoke else 384        # residual is one d x d f32 buffer
    r = 4                            # matmuls per unit chain (recompute)
    n = 4 if smoke else 6            # units
    reps = 2 if smoke else 3
    scale = np.float32(1.0 / np.sqrt(d))
    W = jax.random.normal(jax.random.PRNGKey(0), (d, d), jnp.float32) * scale
    h0 = jax.random.normal(jax.random.PRNGKey(1), (d, d), jnp.float32)

    @jax.jit
    def chain(h):                     # the unit's heavy forward
        z = h
        for _ in range(r):
            z = jnp.tanh(z @ W)
        return z

    @jax.jit
    def boundary(z):                  # unit output handed to unit i+1
        return jnp.tanh(z @ W)

    @jax.jit
    def unit_bwd(z, g):               # backward consumes the residual
        for _ in range(r):
            g = jnp.tanh(g @ W.T) + z * np.float32(1e-3)
        return g

    def run_offload(lane):
        handles = []
        h = h0
        for _ in range(n):
            z = chain(h)
            h = boundary(z)           # next unit dispatches async...
            handles.append(lane.offload(z))   # ...the copy rides behind it
        g = jnp.ones_like(h)
        pre = list(handles)
        pre[n - 1] = lane.prefetch(handles[n - 1])
        for i in reversed(range(n)):
            if i > 0:                 # start the next return copy early
                pre[i - 1] = lane.prefetch(handles[i - 1])
            z = lane.fetch(pre[i])
            g = unit_bwd(z, g)
        jax.block_until_ready(g)
        lane.drain()
        return g

    def run_remat():
        ins = []                      # REMAT keeps only boundary inputs
        h = h0
        for _ in range(n):
            ins.append(h)
            z = chain(h)
            h = boundary(z)
        g = jnp.ones_like(h)
        for i in reversed(range(n)):
            z = chain(ins[i])         # regenerate the residual: recompute
            g = unit_bwd(z, g)
        jax.block_until_ready(g)
        return g

    # warm-up: compile both paths + first-touch the lane's worker thread
    warm_lane = TransferLane()
    g_off = run_offload(warm_lane)
    warm_lane.close()
    g_rm = run_remat()
    results_match = bool(np.allclose(np.asarray(g_off), np.asarray(g_rm),
                                     rtol=1e-5, atol=1e-5))

    best_off, best_exposed, best_copy, moved = float("inf"), 0.0, 0.0, 0.0
    for _ in range(reps):
        lane = TransferLane()
        t0 = time.perf_counter()
        run_offload(lane)
        dt = time.perf_counter() - t0
        st = lane.reset_stats()
        lane.close()
        if dt < best_off:
            best_off = dt
            best_exposed = float(st["exposed_s"])
            best_copy = float(st["copy_s"])
            moved = float(st["bytes_out"] + st["bytes_in"])
    best_rm = _time_best(run_remat, (), reps)

    # simulator-side bound at the bandwidth the step ACTUALLY achieved:
    # at zero overlap every copy's wall time is exposed, and a caller
    # can wait each copy out at most once, so measured exposure must sit
    # inside [0, 1.5 x copy_s + 5 ms] — above the band the exposure
    # accounting double-charged waits.  The idle-link calibration is
    # reported as a contention factor, not gated on: without a DMA
    # engine (CPU containers) contended copies run far below idle
    # bandwidth, while on real accelerators copy_s ~= bytes/pcie and
    # this band collapses onto the bandwidth model.
    tol_s = 1.5 * best_copy + 5e-3
    cal = measure_pcie_gbps(size_mb=4 if smoke else 16, repeats=2)
    idle_round_trip_s = moved / (cal["pcie_gbps"] * 1e9)
    return {
        "units": n, "chain_matmuls": r, "residual_bytes": d * d * 4,
        "results_match": results_match,
        "offload_step_s": round(best_off, 6),
        "remat_step_s": round(best_rm, 6),
        "speedup": round(best_rm / max(best_off, 1e-12), 4),
        "bytes_moved": int(moved),
        "measured_exposed_s": round(best_exposed, 6),
        "measured_copy_s": round(best_copy, 6),
        "tolerance_s": round(tol_s, 6),
        "exposed_within_tolerance": bool(0.0 <= best_exposed <= tol_s),
        "overlap_measured": round(
            max(0.0, 1.0 - best_exposed / max(best_copy, 1e-12)), 4),
        "idle_round_trip_s": round(idle_round_trip_s, 6),
        "contention_factor": round(
            best_copy / max(idle_round_trip_s, 1e-12), 2),
        "calibrated_pcie_gbps": cal["pcie_gbps"],
        "pinned_host": cal["pinned_host"],
    }


def bench_serve(smoke: bool) -> dict:
    """(k) continuous-batching serve engine vs sequential generation.

    One deterministic open-loop trace (``repro.data.trace.gen_trace``,
    the same generator the serve tests use) is served twice at equal
    HBM budget:

      * engine     — ``ServeEngine``: bucketed cache pools, input-aware
                     admission, batched multi-token decode;
      * sequential — the old path: one ``generate()`` per request in
                     arrival order, cache bucketed to the same quantum
                     so both paths compile the same geometry family.

    Both paths run twice; the second (warm — every executable cached on
    the LM) pass is timed, so the comparison is steady-state serving
    throughput, not XLA compile time.  Alongside throughput:

      * admission  — the engine's predicted peak HBM must stay under
                     the budget AND bound the actual allocated peak
                     (admit-before-allocate is only safe if the
                     prediction is conservative);
      * estimator  — per-slot cache bytes predicted for buckets the
                     estimator never sampled vs the exact eval_shape
                     truth (relative error);
      * compiles   — decode geometries seen vs the O(#buckets x #tiers)
                     bound and vs #requests (continuous batching must
                     NOT compile per request).
    """
    from repro.data.trace import gen_trace
    from repro.train.engine import ServeEngine, cache_leaf_bytes
    from repro.train.serve import generate

    cfg = get_config("bert_base_paper").reduced(
        num_layers=2, d_model=96 if smoke else 128,
        d_ff=192 if smoke else 256, vocab_size=512, dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))

    quantum, max_slots = 32, 4
    n_req = 8 if smoke else 16
    new_tok = 8 if smoke else 16
    hbm = 64e6
    # burst trace (all arrive at t=0): throughput is service-bound, so
    # the engine/sequential comparison measures batching, not idle time
    trace = gen_trace(num_requests=n_req, vocab_size=cfg.vocab_size,
                      rate_rps=0.0, max_new_tokens=new_tok,
                      prompt_scale=0.25, seed=7)

    def run_engine():
        eng = ServeEngine(lm, params, hbm_bytes=hbm, quantum=quantum,
                          max_slots=max_slots, prefill_chunk=16,
                          decode_steps=4)
        return eng, eng.run(trace)

    def run_sequential():
        t0 = time.perf_counter()
        outs, total = {}, 0
        for r in trace:
            bucket = -(-(len(r.prompt) + r.max_new_tokens)
                       // quantum) * quantum
            out = generate(lm, params, jnp.asarray(r.prompt[None, :]),
                           r.max_new_tokens, cache_len=bucket)
            outs[r.rid] = np.asarray(out)[0]
            total += out.shape[1]
        jax.block_until_ready(out)
        return outs, total, time.perf_counter() - t0

    eng_cold, res_cold = run_engine()          # compile pass
    run_sequential()
    eng, res = run_engine()                    # warm: executables cached
    seq_outs, seq_tokens, seq_wall = run_sequential()

    outputs_match = all(
        np.array_equal(seq_outs[r.rid], np.asarray(res.outputs[r.rid]))
        for r in trace)

    # estimator accuracy on buckets it never sampled (warm-fit uses
    # quantum * {1, 3, 5}): predicted per-slot bytes vs eval_shape truth
    errs = []
    for bucket in (2 * quantum, 4 * quantum, 8 * quantum):
        truth = float(cache_leaf_bytes(lm, bucket).sum())
        errs.append(abs(eng.slot_bytes(bucket) - truth) / truth)

    n_buckets = len({eng.bucket_of(r) for r in trace})
    decode_geoms = res.compile_counts.get("decode", 0)
    eng_tps = res.total_tokens / res.wall_s
    seq_tps = seq_tokens / seq_wall
    return {
        "requests": n_req, "new_tokens": new_tok, "quantum": quantum,
        "max_slots": max_slots, "hbm_budget_mb": hbm / 1e6,
        "engine": res.summary(),
        "cold_wall_s": round(res_cold.wall_s, 4),
        "sequential_wall_s": round(seq_wall, 4),
        "sequential_tokens_per_s": round(seq_tps, 1),
        "engine_tokens_per_s": round(eng_tps, 1),
        "speedup_vs_sequential": round(eng_tps / seq_tps, 3),
        "outputs_match_sequential": bool(outputs_match),
        "peak_predicted_bytes": int(res.stats["peak_predicted_bytes"]),
        "peak_actual_bytes": int(res.stats["peak_actual_bytes"]),
        "budget_bytes": int(hbm),
        "estimator_max_rel_err": round(max(errs), 5),
        "buckets_seen": n_buckets,
        "decode_geometries": decode_geoms,
        "decode_geometry_bound": n_buckets * len(eng.tiers),
    }


def bench_telemetry(smoke: bool) -> dict:
    """(l) telemetry overhead + disabled-path identity.

    Runs the SAME training loop twice from the same initial params:
    once with ``Telemetry.disabled()`` (the default everywhere) and
    once with every surface on — structured events, span tracing, and
    all three file sinks.  The two loops are *interleaved* step-by-step
    so machine noise (frequency scaling, neighbours on a CI runner)
    hits both modes alike, and the comparison uses the **min** warm
    step time: noise only ever adds time, so the min is the clean
    estimate of intrinsic per-step cost.  Two acceptance gates read
    this point:

    * full telemetry costs <= 2% of the warm step time (min of the
      warm steps, compile excluded);
    * the disabled path is bitwise identical: the two loss trajectories
      match float-for-float, so telemetry can never change training.
    """
    cfg = get_config("bert_base_paper").reduced(
        num_layers=2 if smoke else 4, d_model=128, d_ff=256,
        vocab_size=512, dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    B, S = 4, 128 if smoke else 256
    steps = 8 if smoke else 16
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}

    def make(telemetry):
        planner = MimosePlanner(lm, 1e18, quantum=64, warmup_samples=1)
        tr = Trainer(lm, planner, AdamW(lr=1e-3), telemetry=telemetry)
        p = jax.tree_util.tree_map(jnp.copy, params)
        return {"tr": tr, "p": p, "opt": tr.optimizer.init(p),
                "losses": [], "times": []}

    tmp = tempfile.mkdtemp(prefix="bench_obs_")
    tel = build_telemetry(metrics_path=os.path.join(tmp, "metrics.json"),
                          events_path=os.path.join(tmp, "events.jsonl"),
                          trace_path=os.path.join(tmp, "trace.json"))
    modes = [make(Telemetry.disabled()), make(tel)]
    for _ in range(steps):
        for st in modes:              # interleaved: noise hits both alike
            t0 = time.perf_counter()
            st["p"], st["opt"], loss = st["tr"].step(
                st["p"], st["opt"], dict(batch))
            st["times"].append(time.perf_counter() - t0)
            st["losses"].append(float(loss))
    losses_off, t_off = modes[0]["losses"], modes[0]["times"]
    losses_on, t_on = modes[1]["losses"], modes[1]["times"]
    n_spans = len([e for e in tel.tracer.events() if e.get("ph") == "X"])
    flush_telemetry(tel)
    n_events = sum(1 for _ in open(os.path.join(tmp, "events.jsonl")))

    # min of the warm steps: step 0 compiles, step 1 still touches cold
    # caches — both excluded; min, not median, because noise is strictly
    # additive and the gate measures intrinsic cost, not runner load
    off = float(np.min(t_off[2:]))
    on = float(np.min(t_on[2:]))
    return {
        "steps": steps,
        "warm_step_off_s": round(off, 6),
        "warm_step_on_s": round(on, 6),
        "overhead_ratio": round(max(on - off, 0.0) / off, 6),
        "losses_bitwise_identical": losses_on == losses_off,
        "trace_spans": n_spans,
        "event_records": n_events,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (<1 min)")
    ap.add_argument("--out", default="BENCH_engine.json")
    args = ap.parse_args(argv)

    report = {
        "smoke": args.smoke,
        "scheduler": bench_scheduler(args.smoke),
        "collector": bench_collector(args.smoke),
        "engine": bench_engine(args.smoke),
        "sharded": bench_sharded(args.smoke),
        "ragged": bench_ragged(args.smoke),
        "remat_cost": bench_remat_cost(args.smoke),
        "hybrid": bench_hybrid(args.smoke),
        "microbatch": bench_microbatch(args.smoke),
        "solver": bench_solver(args.smoke),
        "offload_exec": bench_offload_exec(args.smoke),
        "serve": bench_serve(args.smoke),
        "telemetry": bench_telemetry(args.smoke),
    }
    sched96 = report["scheduler"]["units_96"]
    coll = report["collector"]
    eng = report["engine"]
    shd = report["sharded"]
    rag50 = report["ragged"]["sweep"]["pad_50pct"]
    rc = report["remat_cost"]["budgets"]
    hyb = report["hybrid"]
    mb = report["microbatch"]
    sv = report["solver"]["sweep"]
    ox = report["offload_exec"]
    srv = report["serve"]
    report["acceptance"] = {
        "compile_count_bounded_by_buckets":
            eng["mimose"]["compiles"] <= eng["mimose"]["buckets_seen"]
            and eng["mimose"]["compiles"] < eng["distinct_raw_shapes"],
        "collection_speedup_ge_5x": coll["speedup"] >= 5.0,
        "scheduler_faster_than_seed_96_units": sched96["speedup"] > 1.0,
        "sharded_fits_where_single_device_cannot":
            shd["single_device_infeasible"] and shd["sharded_fit_per_device"],
        # masked kernels win back >= half the padding throughput loss:
        # gated on the executed-work numbers (deterministic, and
        # bench_ragged asserts the masked executables reproduce the
        # ideal runs, so they describe real kernel behaviour) for both
        # kernels.  The flash wall-clock term is a regression tripwire
        # at a threshold below 0.5 on purpose: CPU interpret emulation
        # pays per-grid-cell overhead a TPU doesn't, and shared CI
        # runners add noise (this container measures ~0.84) — a masked
        # kernel that stopped skipping would read ~0.
        "ragged_recovers_half_loss_at_50pct_pad":
            all(rag50[k]["modeled_recovered"] >= 0.5
                for k in ("flash", "ssd"))
            and rag50["flash"]["measured_recovered"] >= 0.25,
        # cost-aware never recomputes longer than byte-only, is strictly
        # faster somewhere, and every plan stays per-device feasible
        "cost_aware_reduces_recompute_time":
            all(r["cost_aware"]["recompute_time_us"]
                <= r["byte_only"]["recompute_time_us"]
                and r["cost_aware"]["fits_budget"]
                and r["byte_only"]["fits_budget"]
                for r in rc.values())
            and any(r["time_reduction"] > 0 for r in rc.values()),
        # a budget no boolean remat mask can fit is feasible hybrid-only
        "hybrid_fits_below_remat_only_floor":
            not hyb["below_remat_floor"]["any_bool_plan_fits"]
            and hyb["below_remat_floor"]["hybrid_fits"]
            and hyb["below_remat_floor"]["n_offload"] > 0,
        # the floor property: at every equal (remat-feasible) budget the
        # hybrid plan's simulated step overhead is <= remat-only's
        "hybrid_never_worse_at_equal_budget":
            all(r["hybrid"]["fits"] and r["remat_only"]["fits"]
                and r["hybrid"]["overhead_us"]
                <= r["remat_only"]["overhead_us"] + 1e-6
                for r in hyb["equal_budget"].values()),
        # with the transfer fully overlapped, offload beats recompute
        "hybrid_wins_when_transfer_overlapped":
            hyb["overlapped_transfer"]["both_fit"]
            and hyb["overlapped_transfer"]["hybrid_overhead_us"]
            < hyb["overlapped_transfer"]["remat_only_overhead_us"],
        # a budget below the bucket's k=1 global-minimum footprint
        # (exhaustive over every action plan) is feasible only by
        # splitting the batch — k=2 gradient accumulation fits it
        "microbatch_fits_below_k1_floor":
            not mb["below_k1_floor"]["any_k1_plan_fits"]
            and mb["below_k1_floor"]["adaptive_fits"]
            and mb["below_k1_floor"]["chosen_microbatch"] == 2,
        # the floor property: k=1 always competes, so at every equal
        # (k=1-feasible) budget the adaptive planner's simulated step
        # overhead never exceeds the k=1 planner's
        "microbatch_never_worse_at_equal_budget":
            all(r["k1"]["fits"] and r["adaptive"]["fits"]
                and r["adaptive"]["overhead_us"]
                <= r["k1"]["overhead_us"] + 1e-6
                for r in mb["equal_budget"].values()),
        # the solver tier: never worse than greedy at any swept point,
        # strictly better on the PR-5 heterogeneous hybrid point, and
        # the chain DP reproduces the exhaustive (oracle) optimum
        "solver_never_worse_than_greedy":
            all(r["never_worse"] for r in sv.values()),
        "solver_strictly_beats_greedy_somewhere":
            any(r["strict_win"] for r in sv.values()),
        "solver_dp_matches_exhaustive":
            all(r["dp_matches_exhaustive"] for r in sv.values()),
        # MEASURED, not simulated: at the transfer-bound point the
        # double-buffered offload execution beats rematerialisation on
        # wall-clock (same math both ways — gated on the outputs
        # matching too)
        "measured_offload_beats_remat_only":
            ox["results_match"]
            and ox["offload_step_s"] < ox["remat_step_s"],
        # and the lane's measured exposed transfer stays inside the
        # simulator's zero-overlap bound at the realised bandwidth —
        # the lane's own copy wall time (x1.5 + 5 ms band)
        "measured_transfer_within_tolerance":
            ox["exposed_within_tolerance"],
        # continuous batching strictly beats one-generate-per-request
        # at equal HBM budget (warm pass both ways), token-for-token
        # identical outputs
        "serve_engine_beats_sequential":
            srv["outputs_match_sequential"]
            and srv["speedup_vs_sequential"] > 1.0,
        # admit-before-allocate safety: the admission ledger's peak
        # prediction bounds the actual allocated peak AND the budget —
        # zero admission OOMs by construction
        "serve_admission_within_budget":
            srv["peak_actual_bytes"] <= srv["peak_predicted_bytes"]
            <= srv["budget_bytes"],
        # the estimator's per-slot cache-bytes prediction tracks the
        # eval_shape ground truth on buckets it never sampled
        "serve_predicted_tracks_actual":
            srv["estimator_max_rel_err"] <= 0.05,
        # compile-once under serving: decode geometries bounded by
        # #buckets x #slot-tiers, and NOT one per request
        "serve_decode_compiles_bounded_by_buckets":
            srv["decode_geometries"] <= srv["decode_geometry_bound"]
            and srv["decode_geometries"] < srv["requests"],
        # full telemetry (events + spans + file sinks) costs <= 2% of
        # warm step time, and spans/events were actually recorded (the
        # cheap way to pass an overhead gate is to record nothing)
        "telemetry_overhead_le_2pct":
            report["telemetry"]["overhead_ratio"] <= 0.02
            and report["telemetry"]["trace_spans"] > 0
            and report["telemetry"]["event_records"] > 0,
        # telemetry off (the default) is bitwise identical to the
        # instrumented build: the loss trajectories match exactly
        "telemetry_disabled_bitwise_identical":
            report["telemetry"]["losses_bitwise_identical"],
    }

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))
    print(f"\nwrote {args.out}")
    ok = all(report["acceptance"].values())
    print("acceptance:", "PASS" if ok else "FAIL", report["acceptance"])
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
