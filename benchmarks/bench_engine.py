"""Engine benchmark: the compile-once bucketed execution path.

Measures the three quantities ISSUE 1's acceptance criteria name, plus
steady-state throughput, and writes everything to ``BENCH_engine.json``:

  1. scheduler  — ``greedy_plan`` (flat-array) vs the seed's python-list
     ``greedy_plan_reference`` on 24/96-unit inputs.
  2. collector  — deduplicated sheltered collection vs per-layer
     collection on an >= 8-layer homogeneous model.
  3. engine     — train steps over the SWAG-like length distributions for
     mimose / none / sublinear: XLA compile counts vs #buckets vs
     #distinct raw shapes, plan latency, cache hit rates, steps/s.
  4. sharded    — the mesh-budget scenario sweep (1-device, (4, 2),
     (16, 16)): the same per-device HBM budget is infeasible on one
     device (the fixed param/grad/optimizer bytes alone exceed it) but
     the sharding-aware planner fits it on the meshes, validated by the
     per-device liveness simulator.  MeshBudget is pure axis-size math,
     so the 256-chip scenario plans on this single-CPU container.

Usage:
    PYTHONPATH=src python benchmarks/bench_engine.py [--smoke] \
        [--out BENCH_engine.json]

``--smoke`` shrinks every axis so the whole file runs in under a minute
on CI while still exercising each measurement.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (MeshBudget, MimosePlanner, NonePlanner,
                        SublinearPlanner, simulate_sharded)
from repro.core.collector import ShuttlingCollector
from repro.core.planner import fixed_train_bytes
from repro.core.scheduler import greedy_plan, greedy_plan_reference
from repro.data.pipeline import DISTRIBUTIONS, bucket_edges, make_batches
from repro.models.lm import build_model
from repro.models.registry import get_config
from repro.optim.adamw import AdamW
from repro.train.trainer import Trainer


def bench_scheduler(smoke: bool) -> dict:
    """(c) greedy_plan latency: flat-array vs seed implementation."""
    rng = np.random.default_rng(0)
    reps = 30 if smoke else 300
    out = {}
    for n in (24, 96):
        est = rng.uniform(1e6, 1e9, n)
        budget = est.sum() * 0.4          # ~60% of units rematerialised
        rows = {}
        for fn, name in ((greedy_plan, "fast"),
                         (greedy_plan_reference, "reference")):
            fn(est, budget)               # warm any lazy imports
            t0 = time.perf_counter()
            for _ in range(reps):
                fn(est, budget)
            rows[name] = (time.perf_counter() - t0) / reps * 1e6
        agree = (greedy_plan(est, budget).remat
                 == greedy_plan_reference(est, budget).remat)
        out[f"units_{n}"] = {
            "fast_us": round(rows["fast"], 1),
            "reference_us": round(rows["reference"], 1),
            "speedup": round(rows["reference"] / rows["fast"], 2),
            "plans_identical": bool(agree),
        }
    return out


def bench_collector(smoke: bool) -> dict:
    """(b) sheltered collection: deduplicated vs per-layer traces."""
    layers = 8
    cfg = get_config("bert_base_paper").reduced(
        num_layers=layers, d_model=96 if smoke else 128,
        d_ff=192 if smoke else 256, vocab_size=512, dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))

    def one(dedup: bool, S: int) -> float:
        col = ShuttlingCollector(lm, dedup=dedup)
        batch = {"tokens": jnp.ones((2, S), jnp.int32),
                 "labels": jnp.ones((2, S), jnp.int32)}
        t0 = time.perf_counter()
        res = col.collect(params, batch)
        return time.perf_counter() - t0, res

    reps = 2 if smoke else 3
    t_base = min(one(False, 128)[0] for _ in range(reps))
    t_dedup, res = min(((t, r) for t, r in (one(True, 128)
                                            for _ in range(reps))),
                       key=lambda p: p[0])
    base_res = one(False, 128)[1]
    return {
        "layers": layers,
        "per_layer_s": round(t_base, 4),
        "dedup_s": round(t_dedup, 4),
        "speedup": round(t_base / t_dedup, 2),
        "traced_units": res.traced_units,
        "dedup_hits": res.dedup_hits,
        "byte_identical": bool(np.array_equal(res.activation_vector(),
                                              base_res.activation_vector())),
    }


def bench_engine(smoke: bool) -> dict:
    """(a) compile counts bounded by #buckets + throughput comparison.

    The pipeline emits batches at a fine quantum (many distinct raw
    shapes); the mimose planner buckets at a coarser quantum, so the
    engine's compile count collapses onto the bucket set while the
    unbucketed baseline compiles once per raw shape.
    """
    cfg = get_config("bert_base_paper").reduced(
        num_layers=2 if smoke else 4, d_model=128, d_ff=256,
        vocab_size=512, dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))

    dataset = "swag"
    batch_size = 4
    steps = 10 if smoke else 30
    raw_quantum = 8                  # fine-grained -> many raw shapes
    engine_quantum = 64              # planner bucket granularity

    col = ShuttlingCollector(lm)
    S_hi = DISTRIBUTIONS[dataset].hi
    tot = col.collect(params, {
        "tokens": jnp.ones((batch_size, S_hi), jnp.int32)
    }).total_activation_bytes()
    budget = fixed_train_bytes(params) + 0.5 * tot

    batches = list(make_batches(dataset, batch_size=batch_size,
                                vocab_size=cfg.vocab_size,
                                num_batches=steps, quantum=raw_quantum,
                                seed=1))
    raw_shapes = {b["tokens"].shape for b in batches}
    n_buckets_possible = len(bucket_edges(DISTRIBUTIONS[dataset],
                                          engine_quantum))

    results = {}
    for kind in ("mimose", "none", "sublinear"):
        if kind == "mimose":
            planner = MimosePlanner(lm, budget, quantum=engine_quantum,
                                    warmup_samples=3)
        elif kind == "sublinear":
            planner = SublinearPlanner(
                lm, budget,
                max_input_size=batch_size * S_hi, warmup_samples=3)
        else:
            planner = NonePlanner(lm)
        tr = Trainer(lm, planner, AdamW(lr=1e-3))
        p = jax.tree_util.tree_map(jnp.copy, params)
        opt_state = tr.optimizer.init(p)
        t0 = time.perf_counter()
        for b in batches:
            p, opt_state, _ = tr.step(p, opt_state, b)
        wall = time.perf_counter() - t0
        s = tr.summary()
        results[kind] = {
            "steps": steps,
            "compiles": s["compiles"],
            "buckets_seen": s["buckets"],
            "jit_hits": s["jit_hits"],
            "steps_per_s": round(steps / wall, 3),
            "tokens_per_s": round(s["tokens_per_s"], 1),
            "mean_plan_ms": round(s["total_plan_s"] / steps * 1e3, 3),
            "mean_remat_units": s["mean_remat_units"],
        }
        if kind == "mimose":
            results[kind]["plan_cache"] = {
                "hits": planner.stats["cache_hits"],
                "misses": planner.stats["cache_misses"],
                "collections": planner.stats["collections"],
            }
    results["distinct_raw_shapes"] = len(raw_shapes)
    results["bucket_set_size"] = n_buckets_possible
    results["engine_quantum"] = engine_quantum
    return results


def bench_sharded(smoke: bool) -> dict:
    """(d) mesh-budget scenario sweep: 1-device vs (4, 2) vs (16, 16).

    One per-device HBM budget (75% of the single-device fixed bytes, so
    a lone device cannot even hold the param/grad/optimizer state) is
    planned on each mesh shape; the per-device liveness simulation then
    checks the plan's peak against the budget.
    """
    cfg = get_config("bert_base_paper").reduced(
        num_layers=2 if smoke else 4, d_model=128, d_ff=256,
        vocab_size=512, dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    S = 32 if smoke else 64
    batch = {"tokens": jnp.ones((16, S), jnp.int32),
             "labels": jnp.ones((16, S), jnp.int32)}

    fixed_global = fixed_train_bytes(params)
    hbm = 0.75 * fixed_global
    out = {"hbm_per_device_bytes": int(hbm),
           "single_device_fixed_bytes": int(fixed_global),
           "scenarios": {}}
    for shape in ((1,), (4, 2), (16, 16)):
        budget = MeshBudget.from_shape(shape, hbm, zero1=True)
        # the scheduler models peak as fixed + saved residuals; the
        # liveness replay additionally charges the executing unit's
        # recomputed residuals + gradient working set (up to 2x the
        # largest unit), so plan with that much headroom
        col = ShuttlingCollector(lm, mesh_budget=budget).collect(
            params, batch)
        margin = 2 * float(col.device_activation_vector().max(initial=0.0))
        planner = MimosePlanner(lm, max(hbm - margin, 0.0),
                                mesh_budget=budget,
                                warmup_samples=1, quantum=32)
        t0 = time.perf_counter()
        mask, _info = planner.plan(params, batch)
        t_plan = time.perf_counter() - t0
        sim = simulate_sharded(col.device_activation_vector(), mask,
                               planner.resolve_fixed_bytes(params), budget.n_devices)
        name = "x".join(str(s) for s in shape)
        out["scenarios"][name] = {
            "n_devices": budget.n_devices,
            "fixed_bytes_per_device": int(planner.resolve_fixed_bytes(params)),
            "peak_bytes_per_device": int(sim.peak_bytes_per_device),
            "budget_bytes_per_device": int(hbm),
            "fits": bool(sim.fits(hbm)),
            "n_remat": int(sum(mask)),
            "plan_ms": round(t_plan * 1e3, 3),
        }
    sc = out["scenarios"]
    out["single_device_infeasible"] = not sc["1"]["fits"]
    out["sharded_fit_per_device"] = sc["4x2"]["fits"] and sc["16x16"]["fits"]
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (<1 min)")
    ap.add_argument("--out", default="BENCH_engine.json")
    args = ap.parse_args(argv)

    report = {
        "smoke": args.smoke,
        "scheduler": bench_scheduler(args.smoke),
        "collector": bench_collector(args.smoke),
        "engine": bench_engine(args.smoke),
        "sharded": bench_sharded(args.smoke),
    }
    sched96 = report["scheduler"]["units_96"]
    coll = report["collector"]
    eng = report["engine"]
    shd = report["sharded"]
    report["acceptance"] = {
        "compile_count_bounded_by_buckets":
            eng["mimose"]["compiles"] <= eng["mimose"]["buckets_seen"]
            and eng["mimose"]["compiles"] < eng["distinct_raw_shapes"],
        "collection_speedup_ge_5x": coll["speedup"] >= 5.0,
        "scheduler_faster_than_seed_96_units": sched96["speedup"] > 1.0,
        "sharded_fits_where_single_device_cannot":
            shd["single_device_infeasible"] and shd["sharded_fit_per_device"],
    }

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))
    print(f"\nwrote {args.out}")
    ok = all(report["acceptance"].values())
    print("acceptance:", "PASS" if ok else "FAIL", report["acceptance"])
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
