"""Paper Fig. 5: DTR's per-iteration replanning overhead vs memory budget."""
import jax.numpy as jnp

from benchmarks.common import TASKS, activation_budget, build_task, \
    csv_row, make_planner, run_epoch


def main(out) -> None:
    task = TASKS[0]                        # MC-Roberta on SWAG, as in paper
    cfg, lm, params = build_task(task)
    for frac in (0.3, 0.45, 0.6, 0.8):
        budget = activation_budget(lm, params, task, frac)
        dtr = make_planner("dtr", lm, params, task, budget)
        res = run_epoch(lm, params, dtr, task, num_batches=12)
        frac_plan = res["plan_s"] / max(res["compute_s"], 1e-9)
        out(csv_row(f"fig5.budget{frac:.2f}", 0.0,
                    f"plan_ops={dtr.stats['plan_ops']} "
                    f"replans={dtr.stats['replans']} "
                    f"plan_overhead={100 * frac_plan:.1f}% "
                    f"(paper: 4.4-6.1%, growing as budget shrinks)"))
