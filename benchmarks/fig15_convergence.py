"""Paper Fig. 15: loss curves of Mimose vs Baseline coincide (remat does
not change the math, incl. consistent RNG handling)."""
import numpy as np

from benchmarks.common import TASKS, activation_budget, build_task, \
    csv_row, make_planner, run_epoch


def main(out) -> None:
    for task in TASKS[:2]:
        cfg, lm, params = build_task(task)
        budget = activation_budget(lm, params, task, 0.45)
        base = run_epoch(lm, params,
                         make_planner("none", lm, params, task, 0),
                         task, num_batches=12, seed=5)
        mim = run_epoch(lm, params,
                        make_planner("mimose", lm, params, task, budget),
                        task, num_batches=12, seed=5)
        diff = float(np.max(np.abs(np.array(base["losses"])
                                   - np.array(mim["losses"]))))
        out(csv_row(f"fig15.{task.name}", 0.0,
                    f"max_loss_divergence={diff:.2e} "
                    f"final_base={base['final_loss']:.4f} "
                    f"final_mimose={mim['final_loss']:.4f} coincide={diff < 1e-3}"))
