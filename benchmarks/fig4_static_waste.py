"""Paper Fig. 4: a static (Sublinear) plan computed for the largest input
wastes budget + throughput on small inputs."""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import TASKS, activation_budget, build_task, \
    csv_row, make_planner, max_input_size
from repro.core import ShuttlingCollector
from repro.core.planner import fixed_train_bytes


def main(out) -> None:
    task = TASKS[3]                       # TC-Bert on QQP, as in the paper
    cfg, lm, params = build_task(task)
    budget = activation_budget(lm, params, task, 0.5)
    fixed = fixed_train_bytes(params)
    sub = make_planner("sublinear", lm, params, task, budget)
    mi = make_planner("mimose", lm, params, task, budget)
    col = ShuttlingCollector(lm)
    for S in (32, 64, 96):                # warm the mimose estimator
        mi.plan(params, {"tokens": jnp.ones((task.batch_size, S), jnp.int32)})

    for S in (64, 128, 224, 352):
        batch = {"tokens": jnp.ones((task.batch_size, S), jnp.int32)}
        act = col.collect(params, batch).activation_vector()
        m_sub, _ = sub.plan(params, batch)
        m_mi, _ = mi.plan(params, batch)
        used_sub = fixed + sum(a for a, m in zip(act, m_sub) if not m)
        unused_gb = (budget - used_sub) / 2**20
        recomp_sub = sum(a for a, m in zip(act, m_sub) if m)
        recomp_mi = sum(a for a, m in zip(act, m_mi) if m)
        out(csv_row(f"fig4.S{S}", 0.0,
                    f"sublinear_remat={sum(m_sub)} mimose_remat={sum(m_mi)} "
                    f"unused_budget_mb={unused_gb:.1f} "
                    f"recompute_bytes_sub={recomp_sub / 2**20:.1f}MB "
                    f"mimose={recomp_mi / 2**20:.1f}MB"))
