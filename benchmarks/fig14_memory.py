"""Paper Fig. 14: memory consumption vs sequence length under budgets
(MB-X).  Consumption tracks the input until the budget, then plateaus."""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import TASKS, activation_budget, build_task, \
    csv_row, make_planner
from repro.core import ShuttlingCollector, simulate
from repro.core.planner import fixed_train_bytes


def main(out) -> None:
    task = TASKS[0]
    cfg, lm, params = build_task(task)
    fixed = fixed_train_bytes(params)
    col = ShuttlingCollector(lm)
    for frac in (0.4, 0.7):
        budget = activation_budget(lm, params, task, frac)
        planner = make_planner("mimose", lm, params, task, budget)
        for S in (32, 64, 96):
            planner.plan(params, {"tokens": jnp.ones((task.batch_size, S),
                                                     jnp.int32)})
        peaks, fits = [], []
        for S in (32, 96, 160, 224, 288, 352):
            batch = {"tokens": jnp.ones((task.batch_size, S), jnp.int32)}
            mask, _ = planner.plan(params, batch)
            act = col.collect(params, batch).activation_vector()
            saved = fixed + sum(a for a, m in zip(act, mask) if not m)
            peaks.append(saved)
            fits.append(saved <= budget * 1.02)
            out(csv_row(f"fig14.MB{frac:.1f}.S{S}", 0.0,
                        f"consumption_mb={saved / 2**20:.1f} "
                        f"budget_mb={budget / 2**20:.1f} "
                        f"remat={sum(mask)} fits={saved <= budget * 1.02}"))
        out(csv_row(f"fig14.MB{frac:.1f}.summary", 0.0,
                    f"all_fit={all(fits)} "
                    f"rises_then_plateaus="
                    f"{bool(peaks[1] > peaks[0])}"))
