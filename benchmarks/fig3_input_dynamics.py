"""Paper Fig. 3: input-size distributions + memory vs input size."""
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import TASKS, build_task, csv_row
from repro.core import ShuttlingCollector
from repro.data.pipeline import DISTRIBUTIONS, epoch_sizes


def main(out) -> None:
    rng = np.random.default_rng(0)
    for name in ("swag", "squad", "qqp"):
        d = DISTRIBUTIONS[name]
        s = d.sample(rng, 5000)
        out(csv_row(f"fig3.dist.{name}", 0.0,
                    f"range={s.min()}~{s.max()} mean={s.mean():.0f} "
                    f"p50={np.percentile(s, 50):.0f} "
                    f"p95={np.percentile(s, 95):.0f}"))

    # memory vs input size is smooth and monotone (the premise for the
    # polynomial estimator)
    task = TASKS[0]
    cfg, lm, params = build_task(task)
    col = ShuttlingCollector(lm)
    sizes, mems = [], []
    for S in (32, 64, 96, 128, 160, 224, 288, 352):
        t0 = time.perf_counter()
        res = col.collect(params, {
            "tokens": jnp.ones((task.batch_size, S), jnp.int32)})
        dt = time.perf_counter() - t0
        sizes.append(res.input_size)
        mems.append(res.total_activation_bytes())
        out(csv_row(f"fig3.memcurve.S{S}", dt * 1e6,
                    f"input_size={res.input_size} act_mb="
                    f"{res.total_activation_bytes() / 2**20:.1f}"))
    ratios = np.diff(mems) / np.diff(sizes)
    out(csv_row("fig3.memcurve.monotone", 0.0,
                f"monotone={bool(np.all(np.diff(mems) > 0))} "
                f"slope_growth={ratios[-1] / ratios[0]:.2f}x (superlinear)"))
