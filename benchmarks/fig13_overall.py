"""Paper Fig. 13 (MAIN RESULT): single-epoch time per planner, normalised
to Baseline (no memory limit), across memory budgets.

Paper: Mimose beats Sublinear by ~17.1% and DTR by ~15.0% on average,
approaching Baseline as the budget grows (5.1% slowdown at 8 GB)."""
import numpy as np

from benchmarks.common import TASKS, activation_budget, build_task, \
    csv_row, make_planner, run_epoch

BUDGET_FRACS = (0.35, 0.55, 0.8)
PLANNERS = ("sublinear", "dtr", "mimose")


def main(out, num_batches: int = 10) -> None:
    speedups = {p: [] for p in PLANNERS}
    for task in TASKS:
        cfg, lm, params = build_task(task)
        base = run_epoch(lm, params,
                         make_planner("none", lm, params, task, 0), task,
                         num_batches=num_batches)
        out(csv_row(f"fig13.{task.name}.baseline",
                    1e6 * base["compute_s"] / base["steps"],
                    f"loss={base['final_loss']:.3f}"))
        for frac in BUDGET_FRACS:
            budget = activation_budget(lm, params, task, frac)
            row = {}
            for kind in PLANNERS:
                planner = make_planner(kind, lm, params, task, budget)
                res = run_epoch(lm, params, planner, task,
                                num_batches=num_batches)
                rel = res["compute_s"] / base["compute_s"]
                row[kind] = rel
                out(csv_row(
                    f"fig13.{task.name}.b{frac:.2f}.{kind}",
                    1e6 * res["compute_s"] / res["steps"],
                    f"rel_epoch_time={rel:.3f} "
                    f"remat_units={res['mean_remat_units']:.1f} "
                    f"loss={res['final_loss']:.3f}"))
            for p in ("sublinear", "dtr"):
                if row[p] > 0:
                    speedups[p].append(row[p] / row["mimose"])
    for p in ("sublinear", "dtr"):
        s = np.array(speedups[p])
        out(csv_row(f"fig13.summary.mimose_vs_{p}", 0.0,
                    f"mean_speedup={100 * (s.mean() - 1):.1f}% "
                    f"(paper: {'17.1' if p == 'sublinear' else '15.0'}%)"))
