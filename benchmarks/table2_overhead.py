"""Paper Table 2: Mimose overhead breakdown (collector / estimator /
scheduler), normalised to single-iteration time."""
import numpy as np

from benchmarks.common import TASKS, activation_budget, build_task, \
    csv_row, make_planner, run_epoch


def main(out) -> None:
    for task in TASKS:
        cfg, lm, params = build_task(task)
        budget = activation_budget(lm, params, task, 0.55)
        planner = make_planner("mimose", lm, params, task, budget)
        res = run_epoch(lm, params, planner, task, num_batches=20)
        iter_s = res["compute_s"] / res["steps"]
        st = planner.stats
        est_sched_ms = 1e3 * (st["estimate_time_s"] + st["schedule_time_s"])
        n_plans = max(st["cache_misses"] - st["collections"], 1)
        total_overhead_s = (st["collect_time_s"] + st["estimate_time_s"]
                            + st["schedule_time_s"])
        out(csv_row(
            f"table2.{task.name}", 1e6 * iter_s,
            f"collector={1e3 * st['collect_time_s']:.1f}ms"
            f"({st['collections']}x) "
            f"est+sched={est_sched_ms / n_plans:.3f}ms/plan({n_plans}x) "
            f"total={total_overhead_s / iter_s:.2f}iters "
            f"(paper: ~3.95 iters/epoch)"))
