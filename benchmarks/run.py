"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Each module validates one
of the paper's artifacts at CPU scale (see benchmarks/common.py for the
scale note); the roofline/dry-run benchmarks live in launch/ because they
need the 512-device environment.

Usage:
    PYTHONPATH=src python -m benchmarks.run             # all
    PYTHONPATH=src python -m benchmarks.run fig13 table2
"""
from __future__ import annotations

import importlib
import sys
import time

MODULES = [
    "fig3_input_dynamics",
    "fig4_static_waste",
    "fig5_dtr_overhead",
    "fig11_position",
    "fig13_overall",
    "fig14_memory",
    "fig15_convergence",
    "table2_overhead",
    "table34_estimator",
]


def main() -> None:
    sel = sys.argv[1:]
    rows: list[str] = []

    def out(row: str) -> None:
        print(row, flush=True)
        rows.append(row)

    print("name,us_per_call,derived")
    for modname in MODULES:
        if sel and not any(s in modname for s in sel):
            continue
        mod = importlib.import_module(f"benchmarks.{modname}")
        t0 = time.perf_counter()
        mod.main(out)
        out(f"{modname}.total,{1e6 * (time.perf_counter() - t0):.0f},done")
    print(f"# {len(rows)} rows", file=sys.stderr)


if __name__ == "__main__":
    main()
