"""Model / run configuration system.

Every assigned architecture is described by a single ``ModelConfig``
dataclass instance living in ``repro.configs.<arch>``.  The config is a
plain frozen dataclass so it can be hashed, printed, and overridden with
``dataclasses.replace`` (used by the smoke tests to build reduced
variants of the same family).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    # identity -----------------------------------------------------------
    name: str = "model"
    family: str = "dense"            # dense | moe | ssm | hybrid | encdec | vlm
    source: str = ""                 # paper / model-card citation

    # trunk --------------------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    vocab_size: int = 32000

    # attention ----------------------------------------------------------
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                # 0 -> d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    mrope: bool = False              # multimodal rotary (qwen2-vl)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)  # t/h/w split of head_dim/2
    sliding_window: int = 0          # 0 -> full attention
    global_interval: int = 0         # gemma3: every Nth layer is global, rest local

    # mlp ------------------------------------------------------------------
    d_ff: int = 1024
    mlp_act: str = "swiglu"          # swiglu | gelu | relu

    # moe ------------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    shared_expert_d_ff: int = 0      # optional dense shared expert (kimi-style)
    router_aux_coef: float = 0.01    # load-balance loss coefficient
    moe_capacity_factor: float = 1.25  # GShard capacity (tokens beyond drop)
    moe_group_size: int = 512        # tokens per dispatch group (GShard G)

    # ssm (mamba2 / SSD) ----------------------------------------------------
    ssm_state: int = 0               # N: state size per head
    ssm_heads: int = 0               # number of SSD heads (0 -> derive)
    ssm_head_dim: int = 64           # P: channels per head
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_chunk: int = 64              # chunk length for the SSD scan
    conv_kernel: int = 4

    # hybrid (hymba) ---------------------------------------------------------
    hybrid_attn_ratio: float = 0.5   # fraction of d_inner given to attention heads

    # encoder-decoder (seamless) ----------------------------------------------
    encoder_layers: int = 0          # 0 -> decoder-only
    encoder_frames: int = 0          # stub frontend output length (audio frames)

    # vlm ------------------------------------------------------------------
    vision_tokens: int = 0           # stub frontend: number of patch embeddings

    # norms / misc -------------------------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    remat_mode: str = "unrolled"     # unrolled | scan (chunked)
    scan_chunks: int = 8             # remat planning granularity for scanned models

    # ---------------------------------------------------------------------
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    def attn_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim()

    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim()

    def is_decoder_only(self) -> bool:
        return self.encoder_layers == 0

    def uses_attention(self) -> bool:
        return self.family != "ssm"

    def subquadratic(self) -> bool:
        """True when long_500k decode is feasible (SSM/hybrid/sliding-window)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    def reduced(self, **over) -> "ModelConfig":
        """Reduced smoke-test variant of the same family (<=2 layers etc.)."""
        base = dict(
            num_layers=2,
            d_model=min(self.d_model, 128),
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=32 if self.head_dim else 0,
            remat_mode="unrolled",
        )
        if self.num_experts:
            base.update(num_experts=4, experts_per_token=2,
                        moe_d_ff=min(self.moe_d_ff or 64, 64))
        if self.shared_expert_d_ff:
            base.update(shared_expert_d_ff=64)
        if self.ssm_state:
            base.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.encoder_layers:
            base.update(encoder_layers=2, encoder_frames=min(self.encoder_frames or 32, 32))
        if self.vision_tokens:
            base.update(vision_tokens=16)
        if self.global_interval:
            base.update(global_interval=2)
        if self.sliding_window:
            base.update(sliding_window=64)
        base.update(over)
        # keep num_kv_heads dividing num_heads
        if base["num_heads"] % base["num_kv_heads"]:
            base["num_kv_heads"] = 1
        return dataclasses.replace(self, **base)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs roofline)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim()
        total = V * d                       # embedding
        if not self.tie_embeddings:
            total += V * d
        def attn_params() -> int:
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            return q + kv + o
        def mlp_params(ff: int) -> int:
            mult = 3 if self.mlp_act == "swiglu" else 2
            return mult * d * ff
        def ssm_params() -> int:
            d_inner = self.ssm_expand * d
            nheads = d_inner // self.ssm_head_dim
            in_proj = d * (2 * d_inner + 2 * nheads * self.ssm_state + nheads)
            out = d_inner * d
            conv = self.conv_kernel * (d_inner + 2 * nheads * self.ssm_state)
            return in_proj + out + conv + 2 * nheads
        per_layer = 2 * d                   # two rmsnorm scales
        if self.family == "ssm":
            per_layer += ssm_params() + (mlp_params(self.d_ff) if self.d_ff else 0)
        elif self.family == "hybrid":
            per_layer += attn_params() + ssm_params() + mlp_params(self.d_ff)
        elif self.family in ("moe",):
            per_layer += attn_params()
            per_layer += self.num_experts * mlp_params(self.moe_d_ff)
            per_layer += d * self.num_experts          # router
            if self.shared_expert_d_ff:
                per_layer += mlp_params(self.shared_expert_d_ff)
        else:
            per_layer += attn_params() + mlp_params(self.d_ff)
        total += L * per_layer
        if self.encoder_layers:
            enc_layer = attn_params() + mlp_params(self.d_ff) + 2 * d
            dec_cross = attn_params() + d
            total += self.encoder_layers * enc_layer + L * dec_cross
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only routed experts)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        mult = 3 if self.mlp_act == "swiglu" else 2
        expert_p = mult * self.d_model * self.moe_d_ff
        inactive = self.num_layers * (self.num_experts - self.experts_per_token) * expert_p
        return full - inactive


@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
