"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch × shape × mesh), TPU v5e constants:

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bandwidth_per_chip
    collective = collective_bytes_per_device / ICI_link_bandwidth

``compiled.cost_analysis()`` reports *per-participating-device* FLOPs and
bytes (verified empirically: a 2MKN matmul across 256 chips reports
2MKN/256).  Collective bytes are parsed from the per-device SPMD HLO —
we sum the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (all-reduce counted
twice: reduce-scatter + all-gather equivalent traffic).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

# ---- TPU v5e constants (per task spec) ------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
# effective host<->device link for activation offload (PCIe 4.0 x16 is
# ~32 GB/s raw; 16 GB/s is the sustained-DMA default the --pcie-gbps
# knob overrides).  The hybrid scheduler prices OFFLOAD actions with it.
PCIE_BW = 16e9               # bytes/s host<->device
# fixed per-microbatch cost of gradient accumulation: one extra step
# dispatch plus the grad-buffer read-modify-write (~params bytes at
# HBM_BW) per additional microbatch.  The adaptive-microbatching
# scheduler charges (k - 1) of these when scoring a k-way split, so k
# never escalates for free — it must buy back more remat/offload
# overhead than the accumulation costs (planners override per model via
# ``microbatch_overhead_s=``).
MICROBATCH_OVERHEAD_S = 5e-4


def calibrated_pcie_gbps(default: float = PCIE_BW / 1e9) -> float:
    """The host link bandwidth planning should actually price:
    ``$MIMOSE_PCIE_GBPS`` wins, then this host's measured calibration
    file (``tools/bench_offload_bw.py`` writes it), then ``default`` —
    the 16 GB/s roofline constant unless a caller knows better."""
    from repro.train.transfer import calibrated_pcie_gbps as _measured
    return _measured(default)


def offload_transfer_s(bytes_moved: float,
                       pcie_bytes_per_s: float = PCIE_BW) -> float:
    """Round-trip host-offload time for ``bytes_moved`` residual bytes.

    An offloaded unit's residuals cross the link twice — out during the
    forward pass, back in before the unit's backward — so the charged
    time is ``2 x bytes / bandwidth``.  This is the OFFLOAD counterpart
    of the REMAT cost ``flops / PEAK_FLOPS``: the two numbers the hybrid
    scheduler compares when choosing how to free a unit's bytes.
    """
    return 2.0 * float(bytes_moved) / float(pcie_bytes_per_s)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([\d,]*)\][^\s]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
# tuple-shaped collectives: "= (f32[..], f32[..]) all-reduce(...)"
_TUPLE_RE = re.compile(
    r"=\s+\(((?:[a-z0-9]+\[[\d,]*\][^,)]*,?\s*)+)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved by each collective kind."""
    out: Dict[str, float] = {}
    seen_spans = []
    for m in _TUPLE_RE.finditer(hlo_text):
        total = sum(_shape_bytes(dt, dims)
                    for dt, dims in _SHAPE_RE.findall(m.group(1)))
        kind = m.group(2)
        out[kind] = out.get(kind, 0.0) + total
        seen_spans.append(m.span())
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        if any(s <= m.start() < e for s, e in seen_spans):
            continue
        dtype, dims, kind = m.groups()
        out[kind] = out.get(kind, 0.0) + _shape_bytes(dtype, dims)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_breakdown: Dict[str, float]
    temp_bytes_per_dev: float
    arg_bytes_per_dev: float
    model_flops: float              # 6 * N_active * tokens (global)

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_dev / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops aggregated over chips)."""
        hlo_global = self.flops_per_dev * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def step_time_bound_s(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu_bound(self) -> float:
        """MFU if the step ran exactly at the dominant roofline term."""
        t = self.step_time_bound_s
        if not t:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_ms": round(self.t_compute * 1e3, 3),
            "t_memory_ms": round(self.t_memory * 1e3, 3),
            "t_collective_ms": round(self.t_collective * 1e3, 3),
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": round(self.useful_flops_ratio, 3),
            "mfu_bound": round(self.mfu_bound, 3),
            "temp_gib_per_dev": round(self.temp_bytes_per_dev / 2**30, 2),
            "arg_gib_per_dev": round(self.arg_bytes_per_dev / 2**30, 2),
        }


# ---------------------------------------------------------------------------
# per-plan-unit analytic cost model
#
# Forward FLOPs of one schedulable unit (a block, or a layer chunk in
# scan mode) at a given batch geometry.  Rematerialising a unit re-runs
# exactly this forward, so these numbers ARE the recompute cost the
# cost-aware scheduler scores against (bytes freed per recompute-FLOP)
# and the simulator converts to seconds via PEAK_FLOPS.  Pure python
# math — no tracing, so the planner can evaluate it per bucket in
# microseconds.
# ---------------------------------------------------------------------------

def _attention_flops(cfg, B: int, S: int, *, causal: bool = True,
                     is_global: bool = True, kv_seq: int = 0) -> float:
    """QKVO projections + score/value matmuls for one attention layer.

    ``kv_seq`` > 0 switches to cross attention over that many keys
    (k/v projected from the encoder stream of length kv_seq).
    """
    d = cfg.d_model
    hd = cfg.resolved_head_dim()
    Sk = kv_seq or S
    proj = 2.0 * B * S * d * cfg.attn_dim()            # q
    proj += 2.0 * 2.0 * B * Sk * d * cfg.kv_dim()      # k, v
    proj += 2.0 * B * S * cfg.attn_dim() * d           # o
    W = cfg.sliding_window
    if kv_seq:
        pairs = float(S) * Sk                          # cross: full
    elif not is_global and W > 0:
        pairs = float(S) * min(W, S)                   # banded
    elif causal:
        pairs = float(S) * S / 2.0
    else:
        pairs = float(S) * S                           # bidirectional
    score = 4.0 * B * cfg.num_heads * hd * pairs       # qk^T and p@v
    return proj + score


def _mlp_flops(cfg, B: int, S: int, d_ff: int = 0) -> float:
    ff = d_ff or cfg.d_ff
    if not ff:
        return 0.0
    mult = 3.0 if cfg.mlp_act == "swiglu" else 2.0
    return 2.0 * B * S * cfg.d_model * ff * mult


def _moe_flops(cfg, B: int, S: int) -> float:
    router = 2.0 * B * S * cfg.d_model * cfg.num_experts
    experts = cfg.experts_per_token * _mlp_flops(cfg, B, S, cfg.moe_d_ff)
    shared = (_mlp_flops(cfg, B, S, cfg.shared_expert_d_ff)
              if cfg.shared_expert_d_ff else 0.0)
    return router + experts + shared


def _ssm_flops(cfg, B: int, S: int) -> float:
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    H = d_inner // cfg.ssm_head_dim
    N = cfg.ssm_state
    P = cfg.ssm_head_dim
    Q = cfg.ssm_chunk
    conv_dim = d_inner + 2 * N
    proj_out = 2 * d_inner + 2 * N + H
    proj = 2.0 * B * S * d * proj_out + 2.0 * B * S * d_inner * d
    conv = 2.0 * B * S * cfg.conv_kernel * conv_dim
    # chunked SSD: intra-chunk (Q,Q) matmuls + inter-chunk state terms
    scan = B * S * (2.0 * Q * N + H * (2.0 * Q * P + 4.0 * P * N))
    return proj + conv + scan


def unit_fwd_flops(cfg, kind: str, *, batch: int, seq: int, layers: int = 1,
                   is_global: bool = True, enc_frames: int = 0) -> float:
    """Analytic forward FLOPs of one plan unit (= ``layers`` blocks of
    ``kind`` at geometry (batch, seq)).  This is the recompute cost of
    rematerialising the unit."""
    B, S = int(batch), int(seq)
    if kind == "enc":
        per = _attention_flops(cfg, B, S, causal=False) + _mlp_flops(cfg, B, S)
    elif kind == "moe":
        per = (_attention_flops(cfg, B, S, is_global=is_global)
               + _moe_flops(cfg, B, S))
    elif kind == "ssm":
        per = _ssm_flops(cfg, B, S) + _mlp_flops(cfg, B, S)
    elif kind == "hybrid":
        per = (_attention_flops(cfg, B, S, is_global=is_global)
               + _ssm_flops(cfg, B, S) + _mlp_flops(cfg, B, S))
    elif kind == "dec":
        per = (_attention_flops(cfg, B, S, is_global=is_global)
               + _attention_flops(cfg, B, S, kv_seq=enc_frames or S)
               + _mlp_flops(cfg, B, S))
    else:                                              # dense
        per = (_attention_flops(cfg, B, S, is_global=is_global)
               + _mlp_flops(cfg, B, S))
    return float(layers) * per


def plan_unit_flops(lm, batch):
    """Per-plan-unit forward FLOPs vector for ``lm`` at this batch's
    geometry (``LM.plan_unit_meta`` supplies the static per-unit facts).
    Returns a float64 numpy array aligned with the planner's byte
    vectors — the ``flops`` argument of ``greedy_plan``/``simulate``."""
    return np.array([unit_fwd_flops(lm.cfg, m["kind"], batch=m["batch"],
                                    seq=m["seq"], layers=m["layers"],
                                    is_global=m["is_global"],
                                    enc_frames=m.get("enc_frames", 0))
                     for m in lm.plan_unit_meta(batch)], dtype=np.float64)


def model_flops_for(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode counts one token/seq."""
    n = cfg.active_param_count()
    if shape.kind == "decode":
        tokens = shape.global_batch          # one new token per sequence
        return 2.0 * n * tokens              # forward only
    tokens = shape.global_batch * shape.seq_len
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def analyse(compiled, *, arch: str, shape_cfg, cfg, mesh_name: str,
            chips: int) -> Roofline:
    ca = compiled.cost_analysis()
    # jaxlib returns one dict per computation on some versions, a bare
    # dict on others; normalise to a dict
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    ca = ca or {}
    ma = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    # all-reduce traffic ~ 2x payload (reduce-scatter + all-gather phases)
    total_coll = sum(v * (2.0 if k == "all-reduce" else 1.0)
                     for k, v in coll.items())
    return Roofline(
        arch=arch, shape=shape_cfg.name, mesh=mesh_name, chips=chips,
        flops_per_dev=float(ca.get("flops", 0.0)),
        bytes_per_dev=float(ca.get("bytes accessed", 0.0)),
        coll_bytes_per_dev=total_coll,
        coll_breakdown=coll,
        temp_bytes_per_dev=float(ma.temp_size_in_bytes),
        arg_bytes_per_dev=float(ma.argument_size_in_bytes),
        model_flops=model_flops_for(cfg, shape_cfg),
    )
