"""Step builders + abstract input specs for every (arch × input shape).

Everything here is allocation-free: parameters, optimizer state, batches
and caches are ``jax.ShapeDtypeStruct`` stand-ins with NamedShardings, so
``jax.jit(...).lower(...)`` traces the full-scale model without touching
device memory.  Used by the multi-pod dry-run and the roofline analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import INPUT_SHAPES, ModelConfig, ShapeConfig
from repro.models.lm import LM, build_model
from repro.optim.adamw import AdamW
from repro.sharding import specs as SP


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.subquadratic():
        return False, ("skipped: pure full-attention architecture; 500k-token "
                       "decode requires sub-quadratic attention (DESIGN.md §4)")
    return True, ""


# ---------------------------------------------------------------------------
# abstract batch specs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the mini-batch of this input shape."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        batch = {"tokens": _struct((B, 1), jnp.int32)}
        if cfg.family == "encdec":
            pass                      # cross-attn KV lives in the cache
        return batch
    # training / prefill
    text_len = S
    batch: Dict[str, Any] = {}
    if cfg.family == "vlm" and cfg.vision_tokens:
        text_len = S - cfg.vision_tokens
        batch["vision_embeds"] = _struct((B, cfg.vision_tokens, cfg.d_model),
                                         jnp.dtype(cfg.dtype))
    if cfg.family == "encdec":
        batch["frames"] = _struct((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
    batch["tokens"] = _struct((B, text_len), jnp.int32)
    if shape.kind == "train":
        batch["labels"] = _struct((B, text_len), jnp.int32)
        batch["weights"] = _struct((B, text_len), jnp.float32)
        # true per-sequence lengths: the ragged-execution operand the
        # length-aware kernels mask/skip on (full length in a dry run)
        batch["lengths"] = _struct((B,), jnp.int32)
    return batch


# ---------------------------------------------------------------------------
# remat plan for the dry-run
# ---------------------------------------------------------------------------

def plan_remat_mask(lm: LM, params_struct, batch_struct, *,
                    mode: str, mesh: Mesh,
                    hbm_per_chip: float = 16 * 2**30,
                    zero1: bool = False,
                    seq_parallel: bool = False,
                    attn_replicated: bool = False,
                    expert_2d: bool = False,
                    cost_aware: bool = True,
                    offload: bool = False,
                    pcie_gbps: float = 16.0,
                    max_microbatches: int = 1) -> Tuple[tuple, int]:
    """Returns ``(actions, microbatch)``: the per-unit action plan
    (``repro.actions.Action`` tuple; bool-compatible: KEEP/REMAT are
    value-identical to False/True) and the gradient-accumulation split
    factor the planner chose (1 unless ``max_microbatches > 1`` and a
    split wins on simulated step time / alone fits the budget)."""
    n = lm.num_plan_units()
    if mode == "none":
        return tuple([False] * n), 1
    if mode == "all":
        return tuple([True] * n), 1
    # mode == "mimose": run the input-aware planner abstractly at scale,
    # against the true per-device budget — activations divided by their
    # PartitionSpec divisors, fixed bytes as the param/opt shards.  The
    # policy flags must match what params_shardings is called with, or
    # the fixed bytes diverge from the real per-chip residency.
    # ``cost_aware=False`` restores the paper's byte-only Algorithm 1;
    # ``offload=True`` lets the plan stream residuals to pinned host
    # memory over a ``pcie_gbps`` link when that beats recompute.
    from repro.core.planner import MimosePlanner
    from repro.sharding.budget import MeshBudget
    budget = MeshBudget.from_mesh(mesh, hbm_per_chip, zero1=zero1,
                                  seq_parallel=seq_parallel,
                                  attn_replicated=attn_replicated,
                                  expert_2d=expert_2d)
    planner = MimosePlanner(lm, mesh_budget=budget,
                            warmup_samples=1, quantum=1,
                            cost_aware=cost_aware,
                            offload=offload, pcie_gbps=pcie_gbps,
                            max_microbatches=max_microbatches)
    mask, info = planner.plan(params_struct, batch_struct)
    return mask, max(int(info.plan.microbatch), 1)


# ---------------------------------------------------------------------------
# setups: (step_fn, example_args, in_shardings, out_shardings)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Setup:
    name: str
    fn: Callable
    args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()
    remat_mask: Optional[tuple] = None
    # gradient-accumulation split of the train step (1 = full batch)
    microbatch: int = 1


def build_setup(arch_cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                remat: str = "mimose", zero1: bool = False,
                seq_parallel: bool = False, logits_f32: bool = True,
                attn_replicated: bool = False,
                prefill_last_only: bool = False,
                remat_policy: str = "",
                expert_2d: bool = False,
                attn_impl: str = "xla",
                offload: bool = False,
                pcie_gbps: float = 16.0,
                max_microbatches: int = 1) -> Setup:
    lm = build_model(arch_cfg, attn_impl=attn_impl)
    lm.logits_f32 = logits_f32
    if offload:
        # probe whether THIS (jaxlib, backend, mesh) can shard the
        # host-offload custom-calls; only degrade OFFLOAD execution to
        # remat where the probe compile genuinely fails (warn-once per
        # mesh signature instead of silently dropping the offload axis
        # on every multi-device mesh)
        from repro.models.lm import configure_offload
        configure_offload(lm, mesh)
    if prefill_last_only and shape.kind == "prefill":
        lm.last_logits_only = True
    if seq_parallel:
        lm.act_sharding = NamedSharding(mesh, P(
            ("pod", "data") if "pod" in mesh.axis_names else "data",
            "model", None))

    params_struct = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
    p_sh = SP.params_shardings(params_struct, mesh,
                               scanned=arch_cfg.remat_mode == "scan",
                               attn_replicated=attn_replicated,
                               expert_2d=expert_2d)
    batch = input_specs(arch_cfg, shape)
    shard_seq = shape.name == "long_500k"
    b_sh = SP.batch_shardings(batch, mesh, shard_sequence=False)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        opt = AdamW()
        opt_struct = jax.eval_shape(opt.init, params_struct)
        o_sh = SP.opt_state_shardings(p_sh, opt_struct, mesh, zero1=zero1)
        mask, microbatch = plan_remat_mask(
            lm, params_struct, batch, mode=remat,
            mesh=mesh, zero1=zero1,
            seq_parallel=seq_parallel,
            attn_replicated=attn_replicated,
            expert_2d=expert_2d,
            offload=offload, pcie_gbps=pcie_gbps,
            max_microbatches=max_microbatches)
        policy = (getattr(jax.checkpoint_policies, remat_policy)
                  if remat_policy else None)

        if microbatch > 1:
            # the planner split the batch: lower the k-way accumulated
            # step (the split happens inside, so the batch shardings
            # still apply to the unsplit bucket-shaped batch)
            from repro.train.accumulate import accumulated_step_fn
            acc = accumulated_step_fn(lm, opt, mask, microbatch,
                                      remat_policy=policy)

            def train_step(params, opt_state, b):
                new_p, new_o, loss, _metrics = acc(params, opt_state, b)
                return new_p, new_o, loss
        else:
            def train_step(params, opt_state, b):
                def loss_fn(p):
                    return lm.loss(p, b, remat_mask=mask,
                                   remat_policy=policy)
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params)
                new_p, new_o = opt.update(grads, opt_state, params)
                return new_p, new_o, loss

        return Setup("train_step", train_step,
                     (params_struct, opt_struct, batch),
                     (p_sh, o_sh, b_sh), (p_sh, o_sh, repl),
                     donate_argnums=(0, 1), remat_mask=mask,
                     microbatch=microbatch)

    if shape.kind == "prefill":
        data_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        vocab_ax = ("model" if arch_cfg.vocab_size % mesh.shape["model"] == 0
                    else None)
        logits_sh = NamedSharding(
            mesh, P(data_axes if len(data_axes) > 1 else data_axes[0],
                    None, vocab_ax))

        def prefill_step(params, b):
            logits, _ = lm.forward(params, b)
            return logits

        return Setup("prefill_step", prefill_step, (params_struct, batch),
                     (p_sh, b_sh), logits_sh)

    # decode ---------------------------------------------------------------
    B = shape.global_batch
    cache_struct = jax.eval_shape(
        lambda: lm.init_cache(B, shape.seq_len))
    c_sh = SP.cache_shardings(cache_struct, mesh,
                              stacked=arch_cfg.remat_mode == "scan",
                              shard_sequence=shard_seq)
    if shard_seq:
        # long_500k: batch=1, the (1, 1) tokens stay replicated; the KV /
        # SSM caches carry the sequence sharding instead
        b_sh = jax.tree_util.tree_map(lambda _: repl, batch)
    else:
        b_sh = SP.batch_shardings(batch, mesh)
    index_struct = _struct((), jnp.int32)

    def serve_step(params, b, cache, index):
        logits, new_cache = lm.decode_step(params, b["tokens"], cache, index)
        return logits, new_cache

    return Setup("serve_step", serve_step,
                 (params_struct, batch, cache_struct, index_struct),
                 (p_sh, b_sh, c_sh, repl), (repl, c_sh),
                 donate_argnums=(2,))


def lower_setup(setup: Setup, mesh: Mesh):
    with mesh:
        jitted = jax.jit(setup.fn,
                         in_shardings=setup.in_shardings,
                         out_shardings=setup.out_shardings,
                         donate_argnums=setup.donate_argnums)
        return jitted.lower(*setup.args)
