"""Multi-pod dry-run: prove every (arch x input-shape x mesh) lowers,
compiles, and fits — without hardware.

The XLA_FLAGS line below runs before ANY other import (jax locks the
device count at first initialisation); only this entry point sees 512
host devices — tests and benchmarks see the single real CPU device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --json out.jsonl
    PYTHONPATH=src python -m repro.launch.dryrun --arch kimi-k2-1t-a32b \
        --shape train_4k --multi-pod --remat all --zero1
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k --mesh-shape 4x2      # small fake-device mesh
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys
import time
import traceback

import jax
import numpy as np

from repro.config import INPUT_SHAPES
from repro.launch.mesh import make_production_mesh, parse_mesh_shape
from repro.launch.roofline import analyse
from repro.launch.steps import build_setup, lower_setup, shape_applicable
from repro.models.registry import ARCH_IDS, canonical, get_config

ASSIGNED = [a for a in ARCH_IDS if a != "bert_base_paper"]


def run_one(arch: str, shape_name: str, *, multi_pod: bool, remat: str,
            zero1: bool, seq_parallel: bool, logits_f32: bool,
            unroll: bool = False, verbose: bool = True,
            mesh_shape=None, offload: bool = False,
            pcie_gbps: float = 16.0,
            max_microbatches: int = 1) -> dict:
    import dataclasses
    cfg = get_config(arch)
    if unroll:
        # XLA's cost analysis counts while-loop (lax.scan) bodies once,
        # not x trip-count; roofline sweeps therefore lower the unrolled
        # model.  (Compile-proof + memory sweeps keep the scanned form —
        # it is both the production form and the realistic peak-memory
        # one.)  See EXPERIMENTS.md §Dry-run.
        cfg = dataclasses.replace(cfg, remat_mode="unrolled")
    shape = INPUT_SHAPES[shape_name]
    if mesh_shape is not None:
        mesh_name = "x".join(str(s) for s in mesh_shape)
        chips = int(np.prod(mesh_shape))
    else:
        mesh_name = "2x16x16" if multi_pod else "16x16"
        chips = 512 if multi_pod else 256
    rec = {"arch": canonical(arch), "shape": shape_name, "mesh": mesh_name,
           "remat": remat, "zero1": zero1, "seq_parallel": seq_parallel,
           "logits_f32": logits_f32, "unroll": unroll, "offload": offload,
           "max_microbatches": max_microbatches}

    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    try:
        mesh = make_production_mesh(multi_pod=multi_pod, shape=mesh_shape)
        t0 = time.time()
        setup = build_setup(cfg, shape, mesh, remat=remat, zero1=zero1,
                            seq_parallel=seq_parallel, logits_f32=logits_f32,
                            offload=offload, pcie_gbps=pcie_gbps,
                            max_microbatches=max_microbatches)
        lowered = lower_setup(setup, mesh)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        roof = analyse(compiled, arch=rec["arch"], shape_cfg=shape, cfg=cfg,
                       mesh_name=mesh_name, chips=chips)
        rec.update(status="ok", step=setup.name,
                   lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
                   flops_per_dev=roof.flops_per_dev,
                   bytes_per_dev=roof.bytes_per_dev,
                   coll_bytes_per_dev=roof.coll_bytes_per_dev,
                   coll_breakdown={k: round(v) for k, v in
                                   roof.coll_breakdown.items()},
                   model_flops=roof.model_flops,
                   # one digit per unit (0=KEEP 1=REMAT 2=OFFLOAD-to-host),
                   # with the gradient-accumulation split factor appended
                   # when the planner chose to microbatch (e.g. "0110x2")
                   remat_mask=(("".join(str(int(m)) for m in setup.remat_mask)
                                + (f"x{setup.microbatch}"
                                   if setup.microbatch > 1 else ""))
                               if setup.remat_mask else None),
                   microbatch=setup.microbatch,
                   **roof.row())
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc(limit=8))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true",
                    help="sweep all assigned arch x shape pairs")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mesh-shape", default=None,
                    help="explicit mesh shape like 4x2 or 2x16x16 "
                         "(overrides --multi-pod; small shapes let the "
                         "dry-run validate sharded plans without 512 "
                         "fake devices)")
    ap.add_argument("--remat", default="mimose",
                    choices=["none", "all", "mimose"])
    ap.add_argument("--offload", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="let the mimose plan OFFLOAD unit residuals to "
                         "pinned host memory (typed action plans)")
    ap.add_argument("--pcie-gbps", type=float, default=16.0,
                    help="host<->device link bandwidth the planner "
                         "prices OFFLOAD actions at")
    ap.add_argument("--max-microbatches", type=int, default=1,
                    help="let the mimose plan split the train step into "
                         "up to K gradient-accumulation microbatches "
                         "when that wins on simulated step time (the "
                         "mask string then shows the factor, e.g. "
                         "'0110x2')")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--logits-bf16", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="lower unrolled layers (accurate roofline flops)")
    ap.add_argument("--json", default=None, help="append JSONL records here")
    ap.add_argument("--resume", action="store_true",
                    help="skip pairs already recorded ok in --json")
    ap.add_argument("--keep-going", action="store_true",
                    help="exit 0 even when sweep points failed (the "
                         "failure summary still prints); default is a "
                         "non-zero exit so CI flags partial sweeps")
    args = ap.parse_args(argv)

    done = set()
    if args.resume and args.json and os.path.exists(args.json):
        for line in open(args.json):
            r = json.loads(line)
            if r.get("status") in ("ok", "skipped"):
                done.add((r["arch"], r["shape"], r["mesh"]))

    pairs = []
    if args.all:
        for a in ASSIGNED:
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        pairs.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]
    mesh_shape = (parse_mesh_shape(args.mesh_shape)
                  if args.mesh_shape else None)

    out = open(args.json, "a") if args.json else None
    n_ok = n_skip = 0
    failures = []
    for arch, shape in pairs:
        for mp in meshes:
            mesh_name = ("x".join(str(s) for s in mesh_shape) if mesh_shape
                         else ("2x16x16" if mp else "16x16"))
            key = (canonical(arch), shape, mesh_name)
            if key in done:
                continue
            rec = run_one(arch, shape, multi_pod=mp, remat=args.remat,
                          zero1=args.zero1, seq_parallel=args.seq_parallel,
                          logits_f32=not args.logits_bf16,
                          unroll=args.unroll, mesh_shape=mesh_shape,
                          offload=args.offload, pcie_gbps=args.pcie_gbps,
                          max_microbatches=args.max_microbatches)
            line = json.dumps(rec)
            print(line, flush=True)
            if out:
                out.write(line + "\n")
                out.flush()
            if rec["status"] == "error":
                failures.append(rec)
            elif rec["status"] == "skipped":
                n_skip += 1
            else:
                n_ok += 1
    if out:
        out.close()
    # failure summary: a long sweep's errors must not scroll away into
    # the per-point JSONL noise — CI readers (and humans) get one table
    if failures:
        print(f"\n{len(failures)} of {n_ok + n_skip + len(failures)} "
              "sweep point(s) FAILED:", file=sys.stderr)
        print(f"  {'arch':<24} {'shape':<12} {'mesh':<10} error",
              file=sys.stderr)
        for r in failures:
            err = r.get("error", "?")
            print(f"  {r['arch']:<24} {r['shape']:<12} {r['mesh']:<10} "
                  f"{err[:90]}", file=sys.stderr)
        if args.keep_going:
            print("--keep-going: exiting 0 despite failures",
                  file=sys.stderr)
    else:
        print(f"\nsweep clean: {n_ok} ok, {n_skip} skipped",
              file=sys.stderr)
    sys.exit(0 if (args.keep_going or not failures) else 1)


if __name__ == "__main__":
    main()
