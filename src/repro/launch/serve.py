"""Continuous-batching serve driver (ROADMAP 1).

CPU-runnable example (reduced scale):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --reduced --num-requests 16 --rate-rps 8 --hbm-gb 0.5

Builds the model, generates (or loads, ``--trace``) a deterministic
open-loop trace, runs it through ``repro.train.engine.ServeEngine``
under the ``--hbm-gb`` budget, and prints the serve report — tokens/s,
TTFT and inter-token latency percentiles, the admission ledger
(admitted / deferred / rejected, predicted vs actual peak HBM), and the
compile audit proving decode stayed at O(#buckets) geometries.

The budget is input-aware end to end: the engine's PolyEstimator (the
paper's §4.3 estimator re-aimed at cache bytes) predicts the footprint
of each admit and each prefill chunk before allocating, so an
over-subscribed trace *defers* instead of OOMing; a request that can
never fit is rejected with a reason, never a crash.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.data.pipeline import DISTRIBUTIONS
from repro.data.trace import TraceRequest, gen_trace
from repro.launch.report import serve_report
from repro.models.lm import build_model
from repro.obs import build_telemetry, flush_telemetry
from repro.models.registry import get_config
from repro.train.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--dataset", default="swag", choices=list(DISTRIBUTIONS))
    ap.add_argument("--hbm-gb", type=float, default=0.5,
                    help="serve HBM budget (params + caches + workspace)")
    ap.add_argument("--quantum", type=int, default=64,
                    help="cache bucket granularity (padded total length)")
    ap.add_argument("--max-slots", type=int, default=4,
                    help="per-bucket batch-slot ceiling")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="largest prefill chunk (power of two)")
    ap.add_argument("--decode-steps", type=int, default=4,
                    help="decode iterations per scheduler loop")
    ap.add_argument("--num-requests", type=int, default=16)
    ap.add_argument("--rate-rps", type=float, default=8.0,
                    help="Poisson arrival rate; <=0 = burst at t=0")
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--prompt-scale", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None,
                    help="JSON trace from tools/gen_trace.py "
                         "(overrides the generator knobs)")
    ap.add_argument("--reduced", action="store_true",
                    help="shrink the model for CPU runs")
    ap.add_argument("--save", default=None,
                    help="write the run summary as JSON")
    # unified telemetry (repro.obs) — same flags as launch/train.py
    ap.add_argument("--metrics", default=None,
                    help="write the final metrics snapshot here at exit "
                         "(.json = JSON doc, else Prometheus text)")
    ap.add_argument("--events-out", default=None,
                    help="JSONL event log: admit/defer/reject decisions "
                         "with predicted bytes, pool grows, completions")
    ap.add_argument("--trace-out", default=None,
                    help="Chrome trace_event JSON (Perfetto): per-request "
                         "queue-wait, prefill-chunk and decode-batch spans")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(num_layers=2, d_model=128, d_ff=256,
                          vocab_size=512, dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name} (family={cfg.family}, "
          f"{cfg.num_layers}L d={cfg.d_model}) under "
          f"{args.hbm_gb:.3f} GB, quantum={args.quantum}, "
          f"max_slots={args.max_slots}")

    if args.trace:
        trace = [TraceRequest.from_json(r)
                 for r in json.load(open(args.trace))]
    else:
        trace = gen_trace(num_requests=args.num_requests,
                          vocab_size=cfg.vocab_size, dataset=args.dataset,
                          rate_rps=args.rate_rps,
                          max_new_tokens=args.max_new_tokens,
                          prompt_scale=args.prompt_scale, seed=args.seed)
    lens = [len(r.prompt) for r in trace]
    print(f"trace: {len(trace)} requests, prompt lens "
          f"{min(lens)}..{max(lens)}, "
          f"last arrival {trace[-1].arrival_s:.2f}s")

    telemetry = build_telemetry(metrics_path=args.metrics,
                                events_path=args.events_out,
                                trace_path=args.trace_out)
    engine = ServeEngine(lm, params, hbm_bytes=args.hbm_gb * 1e9,
                         quantum=args.quantum, max_slots=args.max_slots,
                         prefill_chunk=args.prefill_chunk,
                         decode_steps=args.decode_steps,
                         telemetry=telemetry)
    t0 = time.time()
    result = engine.run(trace)
    print(f"served in {time.time() - t0:.2f}s\n")
    print(serve_report(engine, result))
    if args.save:
        with open(args.save, "w") as f:
            json.dump(result.summary(), f, indent=2)
        print(f"\nsummary written to {args.save}")
    for kind, path in flush_telemetry(telemetry).items():
        print(f"{kind} written to {path}")


if __name__ == "__main__":
    main()
