"""Render EXPERIMENTS.md tables from the dry-run / roofline JSONL records,
plus the live engine report a training run prints at exit.

    PYTHONPATH=src python -m repro.launch.report dryrun_scan.jsonl --kind dryrun
    PYTHONPATH=src python -m repro.launch.report roofline.jsonl --kind roofline

``engine_report(trainer, planner)`` renders a per-bucket table — steps,
gradient-accumulation split factor ``k``, padded vs effective tokens,
pad fraction — so a run shows exactly where padding waste went and
where adaptive microbatching kicked in, alongside the plan cache and
jit cache hit rates (``launch/train.py`` prints it).  Both reports are
built from the run's :class:`repro.obs.MetricsRegistry` snapshot (the
single store every component writes to), not by reaching into
trainer/engine internals; the drift table comes from the
``plan_predicted_peak_bytes`` / ``plan_actual_peak_bytes`` gauges the
planner maintains per bucket.
"""
from __future__ import annotations

import argparse
import json
from collections import OrderedDict


# -- metrics-snapshot accessors ---------------------------------------------
def _by_label(snap: dict, name: str, label: str = "bucket") -> dict:
    """``{int(label-value): value}`` for one metric in a registry
    snapshot (labels are stored as strings; buckets parse back to int)."""
    out: dict = {}
    for row in snap.get(name, {}).get("values", []):
        raw = row["labels"].get(label)
        if raw is None:
            continue
        try:
            key = int(raw)
        except (TypeError, ValueError):
            key = raw
        out[key] = out.get(key, 0) + row["value"]
    return {k: int(v) if float(v).is_integer() else v
            for k, v in out.items()}


def _total(snap: dict, name: str) -> int:
    return int(snap.get(name, {}).get("total", 0))


def _ftotal(snap: dict, name: str) -> float:
    return float(snap.get(name, {}).get("total", 0.0))


def drift_table(snap: dict) -> list:
    """Per-bucket predicted-vs-actual peak-bytes rows from the planner's
    drift gauges.  ``actual`` renders ``-`` for buckets that only ever
    ran responsive (predicted) plans and were never audited."""
    pred = _by_label(snap, "plan_predicted_peak_bytes")
    act = _by_label(snap, "plan_actual_peak_bytes")
    if not pred and not act:
        return []
    lines = ["", "| bucket S | predicted peak MB | actual peak MB "
                 "| drift % |", "|---|---|---|---|"]
    for b in sorted(set(pred) | set(act)):
        p = pred.get(b)
        a = act.get(b)
        p_s = f"{p / 1e6:.2f}" if p else "-"
        a_s = f"{a / 1e6:.2f}" if a else "-"
        d_s = f"{100.0 * (p - a) / a:+.2f}" if p and a else "-"
        lines.append(f"| {b} | {p_s} | {a_s} | {d_s} |")
    return lines


def engine_report(trainer, planner=None) -> str:
    """Markdown report of the compile-once engine's caches and padding.

    ``trainer``: a ``repro.train.trainer.Trainer`` after some steps.
    ``planner``: optionally the planner, for the solver delta table
    (everything else comes from the trainer's metrics snapshot).
    """
    snap = trainer.telemetry.metrics.snapshot()
    bucket_steps = _by_label(snap, "train_bucket_steps")
    padded_by = _by_label(snap, "train_bucket_padded_tokens")
    eff_by = _by_label(snap, "train_bucket_tokens")
    k_by = _by_label(snap, "train_bucket_microbatch")
    lines = ["| bucket S | steps | k | padded tok | effective tok | pad % |",
             "|---|---|---|---|---|---|"]
    tot_pad = tot_eff = 0
    for bucket in sorted(bucket_steps):
        steps = bucket_steps[bucket]
        padded = padded_by.get(bucket, 0)
        eff = eff_by.get(bucket, 0)
        # gradient-accumulation split the planner picked for the bucket
        # (where adaptive microbatching kicked in; 1 = full-batch steps)
        k = k_by.get(bucket, 1)
        tot_pad += padded
        tot_eff += eff
        frac = 100.0 * (1.0 - eff / padded) if padded else 0.0
        lines.append(f"| {bucket} | {steps} | {k} | {padded} | {eff} "
                     f"| {frac:.1f} |")
    tot_frac = 100.0 * (1.0 - tot_eff / tot_pad) if tot_pad else 0.0
    lines.append(f"| **total** | {sum(bucket_steps.values())} | - "
                 f"| {tot_pad} | {tot_eff} | {tot_frac:.1f} |")
    lines.append("")
    lines.append(f"jit cache: {_total(snap, 'train_jit_compiles')} compiles "
                 f"(+{_total(snap, 'train_jit_prewarm_compiles')} "
                 f"prewarmed), {_total(snap, 'train_jit_hits')} hits")
    # plan-cache metrics only exist when an input-aware planner was
    # bound (baselines have no stats), so baseline reports stay short
    if "plan_cache_hits" in snap:
        lines.append(f"plan cache: {_total(snap, 'plan_cache_hits')} hits, "
                     f"{_total(snap, 'plan_cache_misses')} misses, "
                     f"{_total(snap, 'planner_collections')} collections")
    # background-solver tier — only when solves actually ran, so runs
    # with --solver off keep the report unchanged
    if _total(snap, "solver_solves") or _total(snap, "solver_timeouts"):
        lines.append(f"solver: {_total(snap, 'solver_solves')} solve(s), "
                     f"{_total(snap, 'solver_wins')} win(s), "
                     f"{_total(snap, 'solver_swaps')} swap(s), "
                     f"{_total(snap, 'solver_timeouts')} timeout(s)")
        stats = getattr(planner, "stats", None) \
            if planner is not None else None
        deltas = (stats or {}).get("solver_delta_by_bucket", {})
        if deltas:
            lines.append("")
            lines.append("| bucket S | greedy overhead s | solved overhead s "
                         "| delta % |")
            lines.append("|---|---|---|---|")
            for b in sorted(deltas):
                d = deltas[b]
                lines.append(f"| {b} | {d['greedy_s']:.6f} "
                             f"| {d['solved_s']:.6f} "
                             f"| {d['improvement_pct']:.2f} |")
    # real-offload execution — only when something moved or degraded,
    # so remat-only runs keep the report unchanged.  The degradation
    # line is the anti-silent-failure guarantee: a mesh that cannot
    # shard the host-offload calls shows up HERE, not as a mystery
    # step-time regression
    degraded = _total(snap, "train_offload_degraded_steps")
    exposed = _ftotal(snap, "train_exposed_transfer_s")
    sim_x = _ftotal(snap, "train_sim_transfer_s")
    fallbacks = _total(snap, "offload_fallbacks")
    if exposed or degraded or fallbacks:
        lines.append(f"offload: exposed transfer {exposed:.4f}s measured "
                     f"vs {sim_x:.4f}s simulated")
    if degraded or fallbacks:
        lines.append(f"offload degraded to remat: {degraded} step(s), "
                     f"{fallbacks} mesh/bucket fallback(s) — host offload "
                     f"unavailable on this runtime (plans keep their "
                     f"typed actions)")
    # elastic-resilience counters (repro.train.resilience) — only when
    # something actually happened, so quiet runs keep a quiet report
    oom = _total(snap, "train_oom_events")
    snaps = _total(snap, "snapshots_written")
    restores = int(getattr(trainer, "restores", 0))
    if oom or snaps or restores:
        lines.append(f"resilience: {snaps} snapshot(s) written, "
                     f"{restores} restore(s), {oom} OOM event(s), "
                     f"{_total(snap, 'train_escalations')} escalation(s), "
                     f"{_total(snap, 'train_retry_successes')} retry "
                     f"success(es), "
                     f"{_total(snap, 'train_retry_failures')} retry "
                     "failure(s)")
        esc_by = _by_label(snap, "train_escalations")
        if esc_by:
            per = ", ".join(f"{b}: {n}" for b, n in sorted(esc_by.items()))
            lines.append(f"escalations by bucket: {per}")
    # input-aware memory drift: predicted vs audited per-device peak
    lines.extend(drift_table(snap))
    return "\n".join(lines)


def serve_report(engine, result) -> str:
    """Markdown report of one continuous-batching serve run.

    ``engine``: the ``repro.train.engine.ServeEngine`` after ``run``;
    ``result``: the ``ServeResult`` it returned.  Shows throughput and
    latency percentiles, the admission ledger (admitted / deferred /
    rejected and predicted-vs-actual peak HBM), and the compile audit —
    the serving analogue of ``engine_report``'s jit-cache line
    (``launch/serve.py`` prints it).
    """
    snap = engine.telemetry.metrics.snapshot()
    lines = ["| metric | value |", "|---|---|"]
    lines.append(f"| completed / rejected | {result.completed} / "
                 f"{result.rejected} |")
    lines.append(f"| tokens | {result.total_tokens} "
                 f"({result.tokens_per_s:.1f} tok/s) |")
    lines.append(f"| TTFT p50 / p99 | {result.ttft_p50_s * 1e3:.1f} / "
                 f"{result.ttft_p99_s * 1e3:.1f} ms |")
    lines.append(f"| inter-token p50 / p99 | {result.itl_p50_s * 1e3:.2f} / "
                 f"{result.itl_p99_s * 1e3:.2f} ms |")
    lines.append(f"| admission | {_total(snap, 'serve_admitted')} admitted, "
                 f"{_total(snap, 'serve_deferrals')} deferral(s), "
                 f"{_total(snap, 'serve_rejected')} rejected |")
    lines.append(f"| peak HBM predicted / actual | "
                 f"{_ftotal(snap, 'serve_peak_predicted_bytes') / 1e6:.2f} / "
                 f"{_ftotal(snap, 'serve_peak_actual_bytes') / 1e6:.2f} MB "
                 f"(budget {engine.hbm_bytes / 1e6:.0f} MB) |")
    lines.append(f"| pools | {_total(snap, 'serve_pool_grows')} grow(s), "
                 f"{_total(snap, 'serve_decode_batches')} decode batch(es), "
                 f"{_total(snap, 'serve_prefill_chunks')} prefill chunk(s) |")
    comp = ", ".join(f"{k}: {v}" for k, v in
                     sorted(result.compile_counts.items()))
    lines.append(f"| compiled geometries | {comp} |")
    return "\n".join(lines)


def load(path):
    recs = [json.loads(l) for l in open(path)]
    seen = OrderedDict()
    for r in recs:                      # keep the latest record per key
        seen[(r["arch"], r["shape"], r.get("mesh", ""))] = r
    return list(seen.values())


def dryrun_table(recs):
    print("| arch | shape | mesh | status | step | compile_s | "
          "temp GiB/dev | args GiB/dev | remat plan |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] == "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                  f"{r['step']} | {r['compile_s']} | "
                  f"{r['temp_gib_per_dev']} | {r['arg_gib_per_dev']} | "
                  f"`{r.get('remat_mask') or '-'}` |")
        else:
            reason = r.get("reason", r.get("error", ""))[:60]
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"{r['status']} | - | - | - | - | {reason} |")


def roofline_table(recs):
    print("| arch | shape | t_compute ms | t_memory ms | t_coll ms | "
          "bottleneck | useful FLOPs | MFU bound | temp GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | - | - | - | "
                  f"{r['status']} | - | - | - |")
            continue
        print(f"| {r['arch']} | {r['shape']} | {r['t_compute_ms']} | "
              f"{r['t_memory_ms']} | {r['t_collective_ms']} | "
              f"**{r['bottleneck']}** | {r['useful_flops_ratio']} | "
              f"{r['mfu_bound']} | {r['temp_gib_per_dev']} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--kind", choices=["dryrun", "roofline"],
                    default="dryrun")
    args = ap.parse_args()
    recs = load(args.path)
    (dryrun_table if args.kind == "dryrun" else roofline_table)(recs)


if __name__ == "__main__":
    main()
