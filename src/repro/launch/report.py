"""Render EXPERIMENTS.md tables from the dry-run / roofline JSONL records,
plus the live engine report a training run prints at exit.

    PYTHONPATH=src python -m repro.launch.report dryrun_scan.jsonl --kind dryrun
    PYTHONPATH=src python -m repro.launch.report roofline.jsonl --kind roofline

``engine_report(trainer, planner)`` turns the trainer's cache stats into
a per-bucket table — steps, gradient-accumulation split factor ``k``,
padded vs effective tokens, pad fraction — so a run shows exactly where
padding waste went and where adaptive microbatching kicked in,
alongside the plan cache and jit cache hit rates (``launch/train.py``
prints it).
"""
from __future__ import annotations

import argparse
import json
from collections import OrderedDict


def engine_report(trainer, planner=None) -> str:
    """Markdown report of the compile-once engine's caches and padding.

    ``trainer``: a ``repro.train.trainer.Trainer`` after some steps.
    ``planner``: optionally the planner, for plan-cache hit rates.
    """
    cs = trainer.cache_stats
    lines = ["| bucket S | steps | k | padded tok | effective tok | pad % |",
             "|---|---|---|---|---|---|"]
    tot_pad = tot_eff = 0
    for bucket in sorted(cs["bucket_steps"]):
        steps = cs["bucket_steps"][bucket]
        padded, eff = cs.get("bucket_tokens", {}).get(bucket, (0, 0))
        # gradient-accumulation split the planner picked for the bucket
        # (where adaptive microbatching kicked in; 1 = full-batch steps)
        k = cs.get("bucket_microbatch", {}).get(bucket, 1)
        tot_pad += padded
        tot_eff += eff
        frac = 100.0 * (1.0 - eff / padded) if padded else 0.0
        lines.append(f"| {bucket} | {steps} | {k} | {padded} | {eff} "
                     f"| {frac:.1f} |")
    tot_frac = 100.0 * (1.0 - tot_eff / tot_pad) if tot_pad else 0.0
    lines.append(f"| **total** | {sum(cs['bucket_steps'].values())} | - "
                 f"| {tot_pad} | {tot_eff} | {tot_frac:.1f} |")
    lines.append("")
    lines.append(f"jit cache: {cs['compiles']} compiles "
                 f"(+{cs['prewarm_compiles']} prewarmed), "
                 f"{cs['jit_hits']} hits")
    stats = getattr(planner, "stats", None) if planner is not None else None
    if stats and "cache_hits" in stats:
        lines.append(f"plan cache: {stats['cache_hits']} hits, "
                     f"{stats['cache_misses']} misses, "
                     f"{stats['collections']} collections")
    # background-solver tier — only when solves actually ran, so runs
    # with --solver off keep the report unchanged
    if stats and (stats.get("solves") or stats.get("solver_timeouts")):
        lines.append(f"solver: {stats.get('solves', 0)} solve(s), "
                     f"{stats.get('solver_wins', 0)} win(s), "
                     f"{stats.get('solver_swaps', 0)} swap(s), "
                     f"{stats.get('solver_timeouts', 0)} timeout(s)")
        deltas = stats.get("solver_delta_by_bucket", {})
        if deltas:
            lines.append("")
            lines.append("| bucket S | greedy overhead s | solved overhead s "
                         "| delta % |")
            lines.append("|---|---|---|---|")
            for b in sorted(deltas):
                d = deltas[b]
                lines.append(f"| {b} | {d['greedy_s']:.6f} "
                             f"| {d['solved_s']:.6f} "
                             f"| {d['improvement_pct']:.2f} |")
    # real-offload execution — only when something moved or degraded,
    # so remat-only runs keep the report unchanged.  The degradation
    # line is the anti-silent-failure guarantee: a mesh that cannot
    # shard the host-offload calls shows up HERE, not as a mystery
    # step-time regression
    hist = getattr(trainer, "history", [])
    degraded = sum(getattr(s, "offload_degraded", False) for s in hist)
    exposed = sum(getattr(s, "exposed_transfer_s", 0.0) for s in hist)
    sim_x = sum(getattr(s, "sim_transfer_s", 0.0) for s in hist)
    fallbacks = (stats or {}).get("offload_fallbacks", 0)
    if exposed or degraded or fallbacks:
        lines.append(f"offload: exposed transfer {exposed:.4f}s measured "
                     f"vs {sim_x:.4f}s simulated")
    if degraded or fallbacks:
        lines.append(f"offload degraded to remat: {degraded} step(s), "
                     f"{fallbacks} mesh/bucket fallback(s) — host offload "
                     f"unavailable on this runtime (plans keep their "
                     f"typed actions)")
    # elastic-resilience counters (repro.train.resilience) — only when
    # something actually happened, so quiet runs keep a quiet report
    wd = getattr(trainer, "watchdog", None)
    sn = getattr(trainer, "snapshots", None)
    oom = int(wd.stats["oom_events"]) if wd is not None else 0
    snaps = int(sn.written) if sn is not None else 0
    restores = int(getattr(trainer, "restores", 0))
    if oom or snaps or restores:
        lines.append(f"resilience: {snaps} snapshot(s) written, "
                     f"{restores} restore(s), {oom} OOM event(s), "
                     f"{wd.stats['escalations'] if wd else 0} escalation(s), "
                     f"{wd.stats['retry_successes'] if wd else 0} retry "
                     f"success(es), "
                     f"{wd.stats['retry_failures'] if wd else 0} retry "
                     "failure(s)")
        esc_by = (stats or {}).get("escalations_by_bucket", {})
        if esc_by:
            per = ", ".join(f"{b}: {n}" for b, n in sorted(esc_by.items()))
            lines.append(f"escalations by bucket: {per}")
    return "\n".join(lines)


def serve_report(engine, result) -> str:
    """Markdown report of one continuous-batching serve run.

    ``engine``: the ``repro.train.engine.ServeEngine`` after ``run``;
    ``result``: the ``ServeResult`` it returned.  Shows throughput and
    latency percentiles, the admission ledger (admitted / deferred /
    rejected and predicted-vs-actual peak HBM), and the compile audit —
    the serving analogue of ``engine_report``'s jit-cache line
    (``launch/serve.py`` prints it).
    """
    s = result.stats
    lines = ["| metric | value |", "|---|---|"]
    lines.append(f"| completed / rejected | {result.completed} / "
                 f"{result.rejected} |")
    lines.append(f"| tokens | {result.total_tokens} "
                 f"({result.tokens_per_s:.1f} tok/s) |")
    lines.append(f"| TTFT p50 / p99 | {result.ttft_p50_s * 1e3:.1f} / "
                 f"{result.ttft_p99_s * 1e3:.1f} ms |")
    lines.append(f"| inter-token p50 / p99 | {result.itl_p50_s * 1e3:.2f} / "
                 f"{result.itl_p99_s * 1e3:.2f} ms |")
    lines.append(f"| admission | {s['admitted']} admitted, "
                 f"{s['deferrals']} deferral(s), "
                 f"{s['rejected']} rejected |")
    lines.append(f"| peak HBM predicted / actual | "
                 f"{s['peak_predicted_bytes'] / 1e6:.2f} / "
                 f"{s['peak_actual_bytes'] / 1e6:.2f} MB "
                 f"(budget {engine.hbm_bytes / 1e6:.0f} MB) |")
    lines.append(f"| pools | {s['pool_grows']} grow(s), "
                 f"{s['decode_batches']} decode batch(es), "
                 f"{s['prefill_chunks']} prefill chunk(s) |")
    comp = ", ".join(f"{k}: {v}" for k, v in
                     sorted(result.compile_counts.items()))
    lines.append(f"| compiled geometries | {comp} |")
    return "\n".join(lines)


def load(path):
    recs = [json.loads(l) for l in open(path)]
    seen = OrderedDict()
    for r in recs:                      # keep the latest record per key
        seen[(r["arch"], r["shape"], r.get("mesh", ""))] = r
    return list(seen.values())


def dryrun_table(recs):
    print("| arch | shape | mesh | status | step | compile_s | "
          "temp GiB/dev | args GiB/dev | remat plan |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] == "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                  f"{r['step']} | {r['compile_s']} | "
                  f"{r['temp_gib_per_dev']} | {r['arg_gib_per_dev']} | "
                  f"`{r.get('remat_mask') or '-'}` |")
        else:
            reason = r.get("reason", r.get("error", ""))[:60]
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"{r['status']} | - | - | - | - | {reason} |")


def roofline_table(recs):
    print("| arch | shape | t_compute ms | t_memory ms | t_coll ms | "
          "bottleneck | useful FLOPs | MFU bound | temp GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | - | - | - | "
                  f"{r['status']} | - | - | - |")
            continue
        print(f"| {r['arch']} | {r['shape']} | {r['t_compute_ms']} | "
              f"{r['t_memory_ms']} | {r['t_collective_ms']} | "
              f"**{r['bottleneck']}** | {r['useful_flops_ratio']} | "
              f"{r['mfu_bound']} | {r['temp_gib_per_dev']} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--kind", choices=["dryrun", "roofline"],
                    default="dryrun")
    args = ap.parse_args()
    recs = load(args.path)
    (dryrun_table if args.kind == "dryrun" else roofline_table)(recs)


if __name__ == "__main__":
    main()
