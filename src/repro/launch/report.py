"""Render EXPERIMENTS.md tables from the dry-run / roofline JSONL records.

    PYTHONPATH=src python -m repro.launch.report dryrun_scan.jsonl --kind dryrun
    PYTHONPATH=src python -m repro.launch.report roofline.jsonl --kind roofline
"""
from __future__ import annotations

import argparse
import json
from collections import OrderedDict


def load(path):
    recs = [json.loads(l) for l in open(path)]
    seen = OrderedDict()
    for r in recs:                      # keep the latest record per key
        seen[(r["arch"], r["shape"], r.get("mesh", ""))] = r
    return list(seen.values())


def dryrun_table(recs):
    print("| arch | shape | mesh | status | step | compile_s | "
          "temp GiB/dev | args GiB/dev | remat plan |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] == "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                  f"{r['step']} | {r['compile_s']} | "
                  f"{r['temp_gib_per_dev']} | {r['arg_gib_per_dev']} | "
                  f"`{r.get('remat_mask') or '-'}` |")
        else:
            reason = r.get("reason", r.get("error", ""))[:60]
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"{r['status']} | - | - | - | - | {reason} |")


def roofline_table(recs):
    print("| arch | shape | t_compute ms | t_memory ms | t_coll ms | "
          "bottleneck | useful FLOPs | MFU bound | temp GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | - | - | - | "
                  f"{r['status']} | - | - | - |")
            continue
        print(f"| {r['arch']} | {r['shape']} | {r['t_compute_ms']} | "
              f"{r['t_memory_ms']} | {r['t_collective_ms']} | "
              f"**{r['bottleneck']}** | {r['useful_flops_ratio']} | "
              f"{r['mfu_bound']} | {r['temp_gib_per_dev']} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--kind", choices=["dryrun", "roofline"],
                    default="dryrun")
    args = ap.parse_args()
    recs = load(args.path)
    (dryrun_table if args.kind == "dryrun" else roofline_table)(recs)


if __name__ == "__main__":
    main()
