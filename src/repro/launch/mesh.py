"""Production mesh construction.

TPU v5e target: one pod = 256 chips as a (16, 16) = (data, model) mesh;
two pods = 512 chips as (2, 16, 16) = (pod, data, model).

Defined as functions (never module-level constants) so importing this
module touches no jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before jax
initialises; everything else sees the single real CPU device.
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py sets this automatically)")
    dev_array = np.array(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices exist (tests on CPU)."""
    n = data * model
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return jax.sharding.Mesh(np.array(devices).reshape(data, model),
                             ("data", "model"))
