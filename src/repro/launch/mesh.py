"""Production mesh construction + per-device budget derivation.

TPU v5e target: one pod = 256 chips as a (16, 16) = (data, model) mesh;
two pods = 512 chips as (2, 16, 16) = (pod, data, model).  Any explicit
shape — (4, 2) for tests, (1, 1) for CPU demos — is accepted via the
``shape`` argument so small dry-runs don't need 512 fake devices.

Defined as functions (never module-level constants) so importing this
module touches no jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before jax
initialises; everything else sees the single real CPU device.

``budget_from_mesh`` turns a live mesh into the planner's ``MeshBudget``
(see ``sharding/budget.py``) — the bridge from the launch layer to
sharding-aware planning.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

import jax

from repro.sharding.budget import MeshBudget, resolve_axis_names


def make_production_mesh(*, multi_pod: bool = False,
                         shape: Optional[Sequence[int]] = None,
                         axis_names: Optional[Sequence[str]] = None):
    """Build a mesh over the first ``prod(shape)`` visible devices.

    Without ``shape``, the production defaults apply: (16, 16) single
    pod, or (2, 16, 16) with ``multi_pod``.  An explicit ``shape`` (1-3
    axes) overrides both; ``axis_names`` defaults by rank via the same
    ``resolve_axis_names`` the planner's MeshBudget uses, so the mesh
    the launcher builds and the budget the planner plans with can never
    disagree about axis naming.
    """
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    shape, axis_names = resolve_axis_names(shape, axis_names)
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py sets this automatically)")
    dev_array = np.array(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axis_names)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices exist (tests on CPU)."""
    n = data * model
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return jax.sharding.Mesh(np.array(devices).reshape(data, model),
                             ("data", "model"))


def budget_from_mesh(mesh, hbm_per_device: float, *,
                     zero1: bool = False,
                     seq_parallel: bool = False) -> MeshBudget:
    """Per-device planning budget for a live mesh (see sharding/budget)."""
    return MeshBudget.from_mesh(mesh, hbm_per_device, zero1=zero1,
                                seq_parallel=seq_parallel)


def parse_mesh_shape(text: str) -> tuple:
    """Parse a CLI mesh shape like ``"4x2"`` or ``"2x16x16"``."""
    try:
        shape = tuple(int(p) for p in text.lower().split("x"))
    except ValueError:
        raise ValueError(f"bad mesh shape {text!r}; expected e.g. '4x2'")
    if not shape or any(s < 1 for s in shape):
        raise ValueError(f"bad mesh shape {text!r}; axes must be >= 1")
    return shape
