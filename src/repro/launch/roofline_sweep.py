"""Roofline sweep: accurate compute/memory/collective terms per
(arch x input shape) on the single-pod production mesh.

XLA's cost analysis counts a ``lax.scan`` (while-loop) body ONCE, not
x trip-count.  For train shapes we exploit that: lowering the scanned
model with K chunks costs ``non_block + K * layer`` in reported terms
(each chunk is one scan whose body is one layer), so two cheap lowerings
at K=4 and K=8 give exact per-layer terms by linear extrapolation:

    layer      = (m_K8 - m_K4) / 4
    non_block  = m_K4 - 4 * layer
    corrected  = non_block + num_layers * layer

Decode and prefill shapes lower the unrolled model directly (small
graphs).  Memory figures come from the production scan-mode dry-run
(dryrun_scan.jsonl), which is the deployable configuration.

Usage:
    PYTHONPATH=src python -m repro.launch.roofline_sweep --json roofline.jsonl
    PYTHONPATH=src python -m repro.launch.roofline_sweep --arch qwen3-1.7b \
        --shape train_4k [--remat all|none|mimose] [--seq-parallel] ...
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.config import INPUT_SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyse, collective_bytes
from repro.launch.steps import build_setup, lower_setup, shape_applicable
from repro.models.registry import ARCH_IDS, canonical, get_config

ASSIGNED = [a for a in ARCH_IDS if a != "bert_base_paper"]


def _measure(cfg, shape, mesh, **opts):
    setup = build_setup(cfg, shape, mesh, **opts)
    lowered = lower_setup(setup, mesh)
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    total_coll = sum(v * (2.0 if k == "all-reduce" else 1.0)
                     for k, v in coll.items())
    ma = compiled.memory_analysis()
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": total_coll,
        "coll_breakdown": coll,
        "temp": float(ma.temp_size_in_bytes),
        "args": float(ma.argument_size_in_bytes),
        "mask": setup.remat_mask,
    }


def roofline_pair(arch: str, shape_name: str, *, remat: str = "all",
                  ssm_chunk: int = 0, moe_group: int = 0, **opts) -> dict:
    from repro.launch.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                       model_flops_for)
    cfg0 = get_config(arch)
    if ssm_chunk:
        cfg0 = dataclasses.replace(cfg0, ssm_chunk=ssm_chunk)
    if moe_group:
        cfg0 = dataclasses.replace(cfg0, moe_group_size=moe_group)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh()
    rec = {"arch": canonical(arch), "shape": shape_name, "mesh": "16x16",
           "remat": remat, **{k: v for k, v in opts.items()}}
    ok, why = shape_applicable(cfg0, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    t0 = time.time()

    def _extrapolate(cfg, keep_temp_from=8):
        """Two scan lowerings with different chunk counts -> exact
        per-layer terms.  Requires uniform (type-homogeneous) layers."""
        m = {}
        for K in (4, 8):
            c = dataclasses.replace(cfg, scan_chunks=K)
            m[K] = _measure(c, shape, mesh, remat=remat, **opts)
        L = cfg.num_layers
        layer = {k: (m[8][k] - m[4][k]) / 4.0
                 for k in ("flops", "bytes", "coll")}
        nb = {k: m[4][k] - 4 * layer[k] for k in layer}
        corrected = {k: nb[k] + L * layer[k] for k in layer}
        return corrected, layer, nb, m[keep_temp_from]

    try:
        hybrid_pattern = (shape.kind == "train"
                          and cfg0.remat_mode == "scan"
                          and cfg0.sliding_window and cfg0.global_interval)
        if hybrid_pattern:
            # pattern-chunked models (gemma3/hymba local:global mix) keep
            # their chunk structure regardless of scan_chunks, so vary the
            # PATTERN instead: measure the all-local and all-global
            # homogeneous variants and recombine by layer counts.
            lm_probe = __import__("repro.models.lm", fromlist=["LM"])
            n_global = sum((i + 1) % cfg0.global_interval == 0
                           for i in range(cfg0.num_layers))
            n_local = cfg0.num_layers - n_global
            cfg_l = dataclasses.replace(cfg0, global_interval=0)  # all local
            cfg_g = dataclasses.replace(cfg0, sliding_window=0)   # all global
            cor_l, lay_l, nb_l, m_l = _extrapolate(cfg_l)
            cor_g, lay_g, nb_g, m_g = _extrapolate(cfg_g)
            corrected = {k: nb_l[k] + n_local * lay_l[k] + n_global * lay_g[k]
                         for k in lay_l}
            # memory/temp from one direct lowering of the true pattern
            m_direct = _measure(cfg0, shape, mesh, remat=remat, **opts)
            temp, args_b = m_direct["temp"], m_direct["args"]
            breakdown = m_direct["coll_breakdown"]
            rec["method"] = "pattern-composed(all-local,all-global)"
            rec["per_layer_flops"] = lay_l["flops"]
            rec["per_layer_flops_global"] = lay_g["flops"]
        elif shape.kind == "train" and cfg0.remat_mode == "scan":
            corrected, layer, _, m8 = _extrapolate(cfg0)
            temp, args_b = m8["temp"], m8["args"]
            breakdown = m8["coll_breakdown"]
            rec["method"] = "scan-extrapolated(K=4,8)"
            rec["per_layer_flops"] = layer["flops"]
        else:
            cfg = dataclasses.replace(cfg0, remat_mode="unrolled") \
                if shape.kind != "train" else cfg0
            mm = _measure(cfg, shape, mesh, remat=remat, **opts)
            corrected = {k: mm[k] for k in ("flops", "bytes", "coll")}
            temp, args_b, breakdown = mm["temp"], mm["args"], \
                mm["coll_breakdown"]
            rec["method"] = ("unrolled" if cfg.remat_mode == "unrolled"
                             else "direct")

        mf = model_flops_for(cfg0, shape)
        t_c = corrected["flops"] / PEAK_FLOPS
        t_m = corrected["bytes"] / HBM_BW
        t_x = corrected["coll"] / ICI_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_x}
        bound = max(terms.values())
        rec.update(
            status="ok", wall_s=round(time.time() - t0, 1),
            flops_per_dev=corrected["flops"],
            bytes_per_dev=corrected["bytes"],
            coll_bytes_per_dev=corrected["coll"],
            coll_breakdown={k: round(v) for k, v in breakdown.items()},
            t_compute_ms=round(t_c * 1e3, 3),
            t_memory_ms=round(t_m * 1e3, 3),
            t_collective_ms=round(t_x * 1e3, 3),
            bottleneck=max(terms, key=terms.get),
            model_flops=mf,
            useful_flops_ratio=round(mf / (corrected["flops"] * 256), 3)
            if corrected["flops"] else 0.0,
            mfu_bound=round(mf / (256 * PEAK_FLOPS * bound), 4) if bound else 0,
            temp_gib_per_dev=round(temp / 2**30, 2),
            arg_gib_per_dev=round(args_b / 2**30, 2),
        )
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc(limit=6))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--remat", default="all",
                    choices=["none", "all", "mimose"])
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--logits-bf16", action="store_true")
    ap.add_argument("--attn-replicated", action="store_true")
    ap.add_argument("--prefill-last-only", action="store_true")
    ap.add_argument("--ssm-chunk", type=int, default=0)
    ap.add_argument("--moe-group", type=int, default=0)
    ap.add_argument("--remat-policy", default="",
                    help="a jax.checkpoint_policies name, e.g. "
                         "dots_with_no_batch_dims_saveable")
    ap.add_argument("--expert-2d", action="store_true",
                    help="shard expert weights over data x model")
    ap.add_argument("--json", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    pairs = ([(args.arch, args.shape)] if args.arch
             else [(a, s) for a in ASSIGNED for s in INPUT_SHAPES])
    done = set()
    if args.resume and args.json and os.path.exists(args.json):
        for line in open(args.json):
            r = json.loads(line)
            if r.get("status") in ("ok", "skipped"):
                done.add((r["arch"], r["shape"]))
    out = open(args.json, "a") if args.json else None
    fails = 0
    for arch, shape in pairs:
        if (canonical(arch), shape) in done:
            continue
        rec = roofline_pair(arch, shape, remat=args.remat,
                            ssm_chunk=args.ssm_chunk,
                            moe_group=args.moe_group,
                            zero1=args.zero1,
                            seq_parallel=args.seq_parallel,
                            logits_f32=not args.logits_bf16,
                            attn_replicated=args.attn_replicated,
                            prefill_last_only=args.prefill_last_only,
                            remat_policy=args.remat_policy,
                            expert_2d=args.expert_2d)
        line = json.dumps(rec)
        print(line, flush=True)
        if out:
            out.write(line + "\n")
            out.flush()
        fails += rec["status"] == "error"
    sys.exit(1 if fails else 0)


if __name__ == "__main__":
    main()
