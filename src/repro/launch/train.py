"""End-to-end training driver with the Mimose planner on the critical path.

CPU-runnable example (reduced scale):
    PYTHONPATH=src python -m repro.launch.train --arch bert_base_paper \
        --dataset swag --planner mimose --budget-mb 600 --steps 50 --reduced

Sharding-aware planning: ``--mesh-shape 4x2 --hbm-gb 16`` plans against
the *per-device* budget of a (data=4, model=2) mesh — activations and
fixed bytes divided by their PartitionSpec divisors, ZeRO-1 aware with
``--zero1``.  When enough devices are visible the step compiles under
the Mesh context (inputs stay replicated — this driver passes no
explicit shardings); end-to-end *sharded* execution is validated by the
dry-run path (launch/dryrun.py), which lowers the step with full
param/batch/optimizer NamedShardings.
"""
from __future__ import annotations

import argparse
import itertools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DTRSimPlanner, MeshBudget, MimosePlanner,
                        NonePlanner, SublinearPlanner)
from repro.launch.mesh import make_production_mesh, parse_mesh_shape
from repro.launch.report import engine_report
from repro.obs import build_telemetry, flush_telemetry
from repro.data.pipeline import (DISTRIBUTIONS, bucket_length, make_batches,
                                 top_buckets)
from repro.models.lm import build_model
from repro.models.registry import get_config
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train import checkpoint as ckpt
from repro.train.resilience import (FaultInjector, OOMWatchdog,
                                    SnapshotManager)
from repro.train.trainer import Trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bert_base_paper")
    ap.add_argument("--dataset", default="swag", choices=list(DISTRIBUTIONS))
    ap.add_argument("--planner", default="mimose",
                    choices=["mimose", "sublinear", "dtr", "none"])
    ap.add_argument("--budget-mb", type=float, default=0.0,
                    help="GPU/TPU memory budget; 0 = unlimited")
    ap.add_argument("--mesh-shape", default=None,
                    help="plan against a per-device mesh budget, e.g. 4x2 "
                         "(data x model) or 2x16x16 (pod x data x model)")
    ap.add_argument("--hbm-gb", type=float, default=16.0,
                    help="per-device HBM for --mesh-shape planning")
    ap.add_argument("--zero1", action="store_true",
                    help="ZeRO-1 optimizer-state sharding in the budget")
    ap.add_argument("--byte-only-remat", action="store_true",
                    help="paper's byte-only Algorithm 1 instead of "
                         "cost-aware (bytes per recompute-FLOP) selection")
    ap.add_argument("--offload", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="hybrid remat+offload plans: units may stream "
                         "residuals to pinned host memory when that beats "
                         "recompute (never worse at equal budget)")
    ap.add_argument("--pcie-gbps", type=float, default=None,
                    help="host<->device link bandwidth (GB/s) the planner "
                         "prices OFFLOAD actions at; default: this host's "
                         "measured calibration (tools/bench_offload_bw.py "
                         "writes it; $MIMOSE_PCIE_GBPS overrides), else 16")
    ap.add_argument("--opt-offload", action="store_true",
                    help="ZeRO-Offload-style fourth action: a plan may "
                         "park a unit's fp32 optimizer moments in host "
                         "memory for the whole step when the freed fixed "
                         "bytes beat the per-step link round trip "
                         "(needs --offload)")
    ap.add_argument("--max-microbatches", type=int, default=1,
                    help="adaptive microbatching: the planner may split "
                         "a bucket's step into up to K gradient-"
                         "accumulation microbatches when that wins on "
                         "simulated step time — or alone fits the "
                         "budget (k=1 always competes, so enabling "
                         "this never loses at equal budget)")
    ap.add_argument("--solver", default="off", choices=["off", "dp"],
                    help="optimal-plan tier: a background thread solves "
                         "each bucket's (k, action) assignment exactly "
                         "(DP over the layer chain, exhaustive on small "
                         "instances) and swaps the improved plan into the "
                         "cache — greedy still serves the first steps "
                         "instantly")
    ap.add_argument("--solver-budget-ms", type=float, default=50.0,
                    help="per-bucket wall-clock budget for the background "
                         "solve; on timeout the best plan found so far "
                         "still competes")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--quantum", type=int, default=32)
    ap.add_argument("--prewarm", type=int, default=0,
                    help="AOT-compile the top-K likeliest buckets before "
                         "step 0 (0 = off)")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced model variant (CPU demo)")
    ap.add_argument("--save", default=None)
    # elastic resilience (repro.train.resilience)
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for periodic full-state snapshots "
                         "(params + optimizer + planner state + data "
                         "cursor); atomic, hash-manifested, last-k kept")
    ap.add_argument("--checkpoint-every-steps", type=int, default=25,
                    help="snapshot cadence in steps (0 = off)")
    ap.add_argument("--checkpoint-every-secs", type=float, default=0.0,
                    help="wall-clock snapshot cadence in seconds (0 = off; "
                         "fires on the first step boundary past the mark)")
    ap.add_argument("--checkpoint-keep", type=int, default=3,
                    help="retain the newest K snapshots")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest valid snapshot from "
                         "--checkpoint-dir (params, optimizer, planner "
                         "warmup state, data cursor) and continue — works "
                         "across a different --mesh-shape: estimator "
                         "samples replay abstractly under the new mesh")
    ap.add_argument("--max-oom-retries", type=int, default=3,
                    help="OOM watchdog: retries per step, each after a "
                         "DTR-style plan escalation (more remat -> "
                         "offload -> higher microbatch split)")
    ap.add_argument("--inject-oom", default=None,
                    help="deterministic fault injection for drills: an "
                         "int N (fail the first N step executions) or "
                         'JSON like {"bucket": {"1024": 2}} — also '
                         "readable from $MIMOSE_INJECT_OOM")
    # unified telemetry (repro.obs): all three sinks are opt-in and the
    # run is bitwise-identical with them off
    ap.add_argument("--metrics", default=None,
                    help="write the final metrics snapshot here at exit "
                         "(.json = JSON doc, anything else = Prometheus "
                         "text exposition)")
    ap.add_argument("--events-out", default=None,
                    help="structured JSONL event log: every planner "
                         "decision (plan/drift/refit/escalation), OOM, "
                         "snapshot and train step with provenance")
    ap.add_argument("--trace-out", default=None,
                    help="Chrome trace_event JSON (load in Perfetto / "
                         "chrome://tracing): per-step plan/compile/execute "
                         "spans, planner and transfer tracks")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(num_layers=4, d_model=256, d_ff=512,
                          vocab_size=1024, dtype="float32")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params / 1e6:.1f}M "
          f"units={lm.num_plan_units()}")

    budget = args.budget_mb * 2**20 if args.budget_mb else 1e18
    mesh_budget = mesh = None
    if args.mesh_shape:
        shape = parse_mesh_shape(args.mesh_shape)
        mesh_budget = MeshBudget.from_shape(shape, args.hbm_gb * 2**30,
                                            zero1=args.zero1)
        # explicit --budget-mb overrides the per-device HBM
        budget = args.budget_mb * 2**20 if args.budget_mb else None
        n_dev = int(np.prod(shape))
        if len(jax.devices()) >= n_dev:
            # the Mesh context lets XLA honour any sharding constraints
            # the model emits; this driver does not device_put explicit
            # param/batch shardings, so data stays replicated — fully
            # sharded execution is the dry-run's job (launch/dryrun.py)
            mesh = make_production_mesh(shape=shape)
            print(f"mesh {shape}: planning per-device; compiling under "
                  f"the {n_dev}-device mesh context (inputs replicated — "
                  "see launch/dryrun.py for sharded execution)")
        else:
            print(f"mesh {shape}: {n_dev} devices unavailable "
                  f"({len(jax.devices())} visible) — planning per-device, "
                  "executing single-device (see launch/dryrun.py for "
                  "sharded execution)")
    dist = DISTRIBUTIONS[args.dataset]
    max_size = args.batch_size * bucket_length(dist.hi, args.quantum)
    if args.offload and args.byte_only_remat:
        ap.error("--offload needs the cost-aware selector "
                 "(drop --byte-only-remat)")
    if args.opt_offload and not args.offload:
        ap.error("--opt-offload needs --offload (moment parking rides "
                 "the same host link)")
    if args.opt_offload and args.planner != "mimose":
        ap.error("--opt-offload needs --planner mimose")
    if args.solver != "off" and args.planner != "mimose":
        ap.error("--solver needs --planner mimose (the solver tier swaps "
                 "plans into the Mimose bucket cache)")
    if args.pcie_gbps is None:
        # price the link at what THIS host measured, not the roofline
        # constant (tools/bench_offload_bw.py writes the calibration)
        from repro.launch.roofline import PCIE_BW, calibrated_pcie_gbps
        args.pcie_gbps = calibrated_pcie_gbps(PCIE_BW / 1e9)
    offload_degraded = False
    if args.offload:
        # probe-based: only degrade OFFLOAD execution to remat where a
        # minimal offloaded grad genuinely fails to compile under this
        # mesh (warn-once per mesh signature; the plan keeps its typed
        # actions either way)
        from repro.models.lm import configure_offload
        offload_degraded = configure_offload(lm, mesh)
    planner = {
        "mimose": lambda: MimosePlanner(lm, budget, quantum=args.quantum,
                                        mesh_budget=mesh_budget,
                                        warmup_samples=3,
                                        cost_aware=not args.byte_only_remat,
                                        offload=args.offload,
                                        opt_offload=args.opt_offload,
                                        pcie_gbps=args.pcie_gbps,
                                        max_microbatches=args.max_microbatches,
                                        solver=args.solver,
                                        solver_budget_ms=args.solver_budget_ms),
        "sublinear": lambda: SublinearPlanner(lm, budget,
                                              max_input_size=max_size,
                                              mesh_budget=mesh_budget,
                                              cost_aware=not args.byte_only_remat,
                                              offload=args.offload,
                                              pcie_gbps=args.pcie_gbps,
                                              max_microbatches=args.max_microbatches),
        "dtr": lambda: DTRSimPlanner(lm, budget, mesh_budget=mesh_budget,
                                     max_microbatches=args.max_microbatches),
        "none": lambda: NonePlanner(lm),
    }[args.planner]()
    if offload_degraded and isinstance(getattr(planner, "stats", None), dict):
        planner.stats["offload_fallbacks"] = (
            planner.stats.get("offload_fallbacks", 0) + 1)

    opt = AdamW(lr=cosine_schedule(args.lr, 10, args.steps))
    snapshots = None
    if args.checkpoint_dir:
        snapshots = SnapshotManager(args.checkpoint_dir,
                                    every_steps=args.checkpoint_every_steps,
                                    every_secs=args.checkpoint_every_secs,
                                    keep=args.checkpoint_keep)
    injector = (FaultInjector(args.inject_oom) if args.inject_oom
                else FaultInjector.from_env())
    watchdog = OOMWatchdog(max_retries=args.max_oom_retries,
                           injector=injector)
    telemetry = build_telemetry(metrics_path=args.metrics,
                                events_path=args.events_out,
                                trace_path=args.trace_out)
    trainer = Trainer(lm, planner, opt, mesh=mesh,
                      watchdog=watchdog, snapshots=snapshots,
                      telemetry=telemetry)
    batches = make_batches(args.dataset, batch_size=args.batch_size,
                           vocab_size=cfg.vocab_size,
                           num_batches=args.steps, quantum=args.quantum,
                           seed=0)
    t0 = time.time()
    opt_state = opt.init(params)
    if args.resume:
        if snapshots is None:
            ap.error("--resume needs --checkpoint-dir")
        restored = snapshots.restore_latest(params_like=params,
                                            opt_like=opt_state,
                                            planner=planner)
        params, opt_state = restored.params, restored.opt_state
        trainer.global_step = restored.step
        trainer.data_cursor = restored.data_cursor
        trainer.restores = 1
        # the batch stream is deterministic (seeded) — the cursor says
        # how many batches the snapshot already consumed
        batches = itertools.islice(iter(batches), restored.data_cursor,
                                   None)
        print(f"resumed {restored.path} at step {restored.step} "
              f"(cursor={restored.data_cursor}, "
              f"planner={restored.planner_summary})")
    if args.prewarm:
        likely = top_buckets(args.dataset, batch_size=args.batch_size,
                             quantum=max(args.quantum,
                                         getattr(planner, "quantum", 1)),
                             k=args.prewarm)
        tw = time.time()
        n = trainer.prewarm(params, opt_state, [S for S, _ in likely],
                            args.batch_size)
        print(f"prewarmed {n} bucket(s) {[S for S, _ in likely]} "
              f"in {time.time() - tw:.1f}s")
    for i, batch in enumerate(batches):
        params, opt_state, loss = trainer.step(params, opt_state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            st = trainer.history[-1]
            print(f"step {i:4d} loss {loss:.4f} S={batch['tokens'].shape[1]}"
                  f" remat={st.remat_units} offload={st.offload_units}"
                  f" k={st.microbatches} step_s={st.step_time_s:.3f}")
    bs = getattr(planner, "background_solver", None)
    if bs is not None:
        # let in-flight solves land so the final snapshot and report see
        # the solved plans (bounded wait; training is already done)
        bs.drain(timeout=5.0)
    if snapshots is not None:
        final = snapshots.save(step=trainer.global_step, params=params,
                               opt_state=opt_state, planner=planner,
                               data_cursor=trainer.data_cursor)
        print("snapshot", final)
    print(f"done in {time.time() - t0:.1f}s")
    print("summary:", trainer.summary())
    print("\nengine report (where the padding went):")
    print(engine_report(trainer, planner))
    if hasattr(planner, "stats"):
        print("planner:", planner.stats, "plans cached:",
              len(getattr(planner, "cache", {})))
    if args.save:
        ckpt.save(args.save, params)
        print("saved", args.save)
    for kind, path in flush_telemetry(telemetry).items():
        print(f"{kind} written to {path}")


if __name__ == "__main__":
    main()
