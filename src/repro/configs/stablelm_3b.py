"""stablelm-3b — dense MHA (kv=heads)  [hf:stabilityai/stablelm-2-1_6b family]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b (family); 3b config",
    num_layers=32,
    d_model=2560,
    num_heads=32, num_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50304,
    tie_embeddings=False,
    remat_mode="scan",
    scan_chunks=8,
)
