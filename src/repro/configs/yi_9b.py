"""yi-9b — llama-architecture dense with GQA kv=4  [arXiv:2403.04652]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    source="arXiv:2403.04652 (Yi); 9B config",
    num_layers=48,
    d_model=4096,
    num_heads=32, num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=10_000.0,
    tie_embeddings=False,
    remat_mode="scan",
    scan_chunks=8,
)
