"""gemma3-12b — dense, 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt family, 12b trunk]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    source="hf:google/gemma-3-12b-pt (5:1 local:global sliding window)",
    num_layers=48,
    d_model=3840,
    num_heads=16, num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    sliding_window=1024,
    global_interval=6,        # every 6th layer global, 5 local before it
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    remat_mode="scan",
    scan_chunks=8,            # 6 layers/chunk, aligned with the 5:1 pattern
)
