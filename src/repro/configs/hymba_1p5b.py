"""hymba-1.5b — hybrid parallel attention + mamba heads  [arXiv:2411.13676]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    source="arXiv:2411.13676 (Hymba 1.5B)",
    num_layers=32,
    d_model=1600,
    num_heads=25, num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    sliding_window=1024,      # most layers use SWA; every 8th is global
    global_interval=8,
    ssm_state=16,
    ssm_head_dim=50,          # d_inner 3200 / 64 heads
    ssm_expand=2,
    ssm_chunk=64,
    tie_embeddings=True,
    remat_mode="scan",
    scan_chunks=8,
)
