"""kimi-k2-1t-a32b — trillion-parameter MoE, 384 experts top-8
[arXiv:2501.kimi2] (paper-table scale)."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    source="arXiv:2501.kimi2 (Kimi K2); 1T total / 32B active",
    num_layers=61,
    d_model=7168,
    num_heads=64, num_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    moe_d_ff=2048,
    num_experts=384,
    experts_per_token=8,
    shared_expert_d_ff=2048,  # one always-on shared expert
    vocab_size=163840,
    tie_embeddings=False,
    remat_mode="scan",
    scan_chunks=8,
)
