"""qwen2-vl-7b — VLM backbone with M-RoPE  [arXiv:2409.12191].

The vision frontend (ViT encoder + projector) is a STUB per the
assignment: ``input_specs()`` provides precomputed patch embeddings of
shape (B, vision_tokens, d_model) which the decoder consumes as prefix
tokens with 3D M-RoPE positions.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    source="arXiv:2409.12191 (Qwen2-VL 7B); language decoder backbone",
    num_layers=28,
    d_model=3584,
    num_heads=28, num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    mrope=True,
    mrope_sections=(16, 24, 24),
    vision_tokens=1024,       # stub frontend: 32x32 patch grid
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    remat_mode="scan",
    scan_chunks=7,
)
