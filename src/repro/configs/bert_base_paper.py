"""bert_base_paper — the paper's own evaluation trunk (Bert-base scale).

Mimose's evaluation (§6) trains Bert-base / Roberta-base (12 encoders,
d=768) on SWAG / SQuAD / GLUE-QQP with dynamic sequence lengths.  We keep
it as a decoder-only 12-layer causal LM of the same dimensions — the
planner sees exactly the paper's granularity: 12 equal encoder blocks
(paper Fig. 11).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="bert-base-paper",
    family="dense",
    source="Mimose paper §6 (Bert-base, 110M params)",
    num_layers=12,
    d_model=768,
    num_heads=12, num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    mlp_act="gelu",
    vocab_size=30522,
    tie_embeddings=True,
    remat_mode="unrolled",    # per-encoder planning, as in the paper
    dtype="float32",
)
