"""mamba2-1.3b — SSD (state-space duality), attention-free  [arXiv:2405.21060]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    source="arXiv:2405.21060 (Mamba-2, SSD); 1.3b config",
    num_layers=48,
    d_model=2048,
    d_ff=0,                  # attention-free, no MLP blocks
    vocab_size=50280,
    num_heads=1, num_kv_heads=1,   # unused (no attention)
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    # chunk length trades intra-chunk matmul efficiency against the
    # (B, S, Q, H) decay-matrix working set the XLA path materialises;
    # 64 keeps the transient under control at train_4k scale (the Pallas
    # kernel tiles it in VMEM and has no such constraint).
    ssm_chunk=64,
    conv_kernel=4,
    tie_embeddings=True,
    remat_mode="scan",
    scan_chunks=8,
)
