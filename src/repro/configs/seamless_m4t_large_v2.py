"""seamless-m4t-large-v2 — multimodal encoder-decoder backbone
[arXiv:2308.11596].

The speech frontend (mel-spectrogram + conformer conv feature extractor)
is a STUB per the assignment: ``input_specs()`` provides precomputed frame
embeddings (B, frames, d_model) consumed by the transformer encoder.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    source="arXiv:2308.11596 (SeamlessM4T large v2); text decoder + speech encoder backbone",
    num_layers=24,            # decoder layers
    encoder_layers=24,
    encoder_frames=4096,      # default stub frame count (overridden per shape)
    d_model=1024,
    num_heads=16, num_kv_heads=16,
    d_ff=8192,
    mlp_act="gelu",
    vocab_size=256206,
    tie_embeddings=True,
    remat_mode="unrolled",    # enc+dec planned jointly per block
)
