"""Sharding rules: parameter / activation PartitionSpecs per model family.

Mesh axes:
  * ``data``  — batch (and sequence, for the long-context decode shape)
  * ``model`` — tensor parallel: attention heads / MLP hidden / experts
  * ``pod``   — optional outer data-parallel axis across pods

Scheme (megatron-style 1D tensor parallel + expert parallel):
  * column-parallel: wq/wk/wv, mlp wi/wg, mamba in_proj  -> (None, 'model')
  * row-parallel:    wo, mlp wo, mamba out_proj          -> ('model', None)
  * embeddings vocab-sharded over 'model'
  * MoE expert weights (E, d, f) sharded ('model', None, None) = expert parallel
  * scan-stacked params get a leading None for the layer axis
  * optional ZeRO-1: optimizer moments additionally sharded over 'data'
    on the largest divisible axis
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _data_axes(mesh: Mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

_COLUMN = {"wq", "wk", "wv", "wi", "wg", "in_proj", "conv_w"}
_ROW = {"wo", "out_proj"}


def param_spec(path: tuple, leaf, *, scanned: bool, mesh: Mesh,
               model_dim: int, attn_replicated: bool = False,
               expert_2d: bool = False, data_dim: int = 0) -> P:
    """PartitionSpec for one parameter, from its tree path.

    ``attn_replicated`` turns tensor parallelism OFF for the attention
    projections (they stay data-parallel-replicated, MLP/MoE keep TP) —
    the right call when num_heads is not divisible by the model axis and
    head-crossing reshards would otherwise dominate collectives (see
    EXPERIMENTS.md §Perf, qwen2-vl)."""
    names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
    leafname = names[-1]
    if attn_replicated and ("attn" in names or "cross" in names):
        return P(*([None] * len(leaf.shape)))
    shape = leaf.shape
    lead = (None,) if (scanned and "blocks" in names) else ()
    body_rank = len(shape) - len(lead)

    def ok(dim_from_end: int) -> bool:
        return shape[len(shape) - dim_from_end] % model_dim == 0

    if leafname == "embed" or leafname == "lm_head":
        if leafname == "embed" and shape[0] % model_dim == 0:
            return P("model", None)
        if leafname == "lm_head" and shape[1] % model_dim == 0:
            return P(None, "model")
        return P(None, None)
    if leafname == "router":
        return P(*lead, None, None)
    if leafname in ("wi", "wg", "wo") and body_rank == 3:
        # stacked expert weights (E, d, f): expert parallel
        E, d2, d3 = shape[len(lead):]
        if expert_2d and data_dim and E % data_dim == 0:
            # 2D expert sharding: experts over 'data', hidden over 'model'
            # (1T-param serving: weights shard over ALL chips)
            if leafname == "wo" and d2 % model_dim == 0:
                return P(*lead, "data", "model", None)
            if leafname != "wo" and d3 % model_dim == 0:
                return P(*lead, "data", None, "model")
            return P(*lead, "data", None, None)
        if E % model_dim == 0:
            return P(*lead, "model", None, None)
        return P(*lead, None, None, None)
    if leafname in _COLUMN and body_rank == 2:
        if ok(1):
            return P(*lead, None, "model")
        return P(*lead, None, None)
    if leafname in _ROW and body_rank == 2:
        if ok(2):
            return P(*lead, "model", None)
        return P(*lead, None, None)
    # everything else (norm scales, biases, A_log, dt_bias, D, scalars)
    return P(*([None] * len(shape)))


def params_shardings(params, mesh: Mesh, *, scanned: bool,
                     attn_replicated: bool = False,
                     expert_2d: bool = False):
    model_dim = mesh.shape["model"]
    data_dim = mesh.shape.get("data", 1)

    def one(path, leaf):
        spec = param_spec(path, leaf, scanned=scanned, mesh=mesh,
                          model_dim=model_dim,
                          attn_replicated=attn_replicated,
                          expert_2d=expert_2d, data_dim=data_dim)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def opt_state_shardings(params_sh, opt_state_struct, mesh: Mesh, *,
                        zero1: bool = False):
    """AdamW state: step replicated; m/v like params (optionally ZeRO-1)."""
    data_axes = _data_axes(mesh)
    data_dim = int(np.prod([mesh.shape[a] for a in data_axes]))

    def moment_spec(p_sh: NamedSharding, leaf):
        spec = list(p_sh.spec) + [None] * (len(leaf.shape) - len(p_sh.spec))
        if zero1:
            for i, s in enumerate(spec):
                if s is None and leaf.shape[i] % data_dim == 0:
                    spec[i] = data_axes if len(data_axes) > 1 else data_axes[0]
                    break
        return NamedSharding(mesh, P(*spec))

    step_sh = NamedSharding(mesh, P())
    m_sh = jax.tree_util.tree_map(moment_spec, params_sh, opt_state_struct.m)
    v_sh = jax.tree_util.tree_map(moment_spec, params_sh, opt_state_struct.v)
    return type(opt_state_struct)(step_sh, m_sh, v_sh)


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------

def batch_spec(name: str, shape: tuple, mesh: Mesh,
               shard_sequence: bool = False) -> P:
    """Input tensors.  Normally batch over data; the long-context decode
    shape (batch=1) shards the *sequence* axis over data instead."""
    data = _data_axes(mesh)
    data = data if len(data) > 1 else data[0]
    if name == "lengths":                 # (B,) per-sequence true lengths
        return P(data)
    if name in ("tokens", "labels", "weights", "positions"):
        if shard_sequence:
            return P(None, data)
        return P(data, *([None] * (len(shape) - 1)))
    if name in ("vision_embeds", "frames"):
        if shard_sequence:
            return P(None, data, None)
        return P(data, None, None)
    return P(*([None] * len(shape)))


def cache_spec(name: str, shape: tuple, mesh: Mesh,
               shard_sequence: bool = False) -> P:
    """KV / SSM caches, per layer (add a leading None if stacked).

    Attention KV: (B, S, Hkv, hd) — batch over data, kv heads over model
    when divisible (else sequence over model).  SSM state: (B, H, P, N) —
    heads over model.  Conv buffer: (B, K-1, C) — channels over model.
    """
    data = _data_axes(mesh)
    data = data if len(data) > 1 else data[0]
    model_dim = mesh.shape["model"]
    if name in ("k", "v", "ck", "cv"):
        B, S, Hkv, hd = shape[-4:]
        lead = [None] * (len(shape) - 4)
        batch_ax = None if shard_sequence else data
        seq_ax = data if shard_sequence else None
        head_ax = "model" if Hkv % model_dim == 0 else None
        if head_ax is None and seq_ax is None and S % model_dim == 0:
            seq_ax = "model"
        return P(*lead, batch_ax, seq_ax, head_ax, None)
    if name == "ssm":
        B, H, Pd, N = shape[-4:]
        lead = [None] * (len(shape) - 4)
        head_ax = "model" if H % model_dim == 0 else None
        return P(*lead, None if shard_sequence else data, head_ax, None, None)
    if name == "conv":
        B, K, C = shape[-3:]
        lead = [None] * (len(shape) - 3)
        ch_ax = "model" if C % model_dim == 0 else None
        return P(*lead, None if shard_sequence else data, None, ch_ax)
    return P(*([None] * len(shape)))


def cache_shardings(cache_struct, mesh: Mesh, *, stacked: bool,
                    shard_sequence: bool = False):
    def one(path, leaf):
        name = getattr(path[-1], "key", None)
        return NamedSharding(mesh, cache_spec(name, leaf.shape, mesh,
                                              shard_sequence))
    return jax.tree_util.tree_map_with_path(one, cache_struct)


def batch_shardings(batch_struct, mesh: Mesh, shard_sequence: bool = False):
    def one(path, leaf):
        name = getattr(path[-1], "key", None)
        return NamedSharding(mesh, batch_spec(name, leaf.shape, mesh,
                                              shard_sequence))
    return jax.tree_util.tree_map_with_path(one, batch_struct)
