"""Per-device memory budgets derived from a mesh shape (sharding-aware
planning).

The planner pipeline historically reasoned about one *global* memory
budget, which is only correct on a single device.  Under a mesh, the
bytes that actually land on each device are the global bytes divided by
the product of the mesh-axis sizes the tensor is sharded over — and that
divisor differs between parameters (tensor-parallel over ``model`` per
``sharding/specs.py``), optimizer moments (additionally ZeRO-1 sharded
over ``data``) and activations (batch over ``data``, tensor-parallel
intermediates over ``model``).

``MeshBudget`` captures exactly that arithmetic as *pure axis-size math*:
it never touches ``jax.Mesh`` or device state, so a (16, 16) pod budget
can be planned, simulated, and benchmarked on a single-CPU container.
The divisor rules deliberately mirror ``sharding/specs.py``:

* parameters / gradients — ``specs.param_spec`` is evaluated per leaf and
  the divisor is the product of the mesh-axis sizes named in the spec
  (exact: the same rule the launcher shards real arrays with);
* optimizer moments — like parameters, with the ZeRO-1 extra ``data``
  sharding of ``specs.opt_state_shardings`` replayed leaf-wise;
* activations — batch-leading tensors divide by the data ways
  (``specs.batch_spec``); tensor-parallel *intermediates* (anything that
  is not a residual-stream boundary tensor ``(B, S, d_model)``) further
  divide by the model ways when divisible, matching megatron-style
  column/row parallelism where only block boundaries are replicated;
  with ``seq_parallel`` the boundary tensors shard their sequence axis
  over ``model`` too (the launcher's ``lm.act_sharding``).

Entry points:
    budget = MeshBudget.from_shape((4, 2), hbm_per_device=16 << 30)
    budget = MeshBudget.from_mesh(mesh, hbm_per_device=16 << 30)
    budget.activation_divisor(leaf_shape, batch=B, d_model=d)
    fixed_train_bytes_per_device(params, budget, scanned=...)
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.sharding import specs as SP

_DEFAULT_AXES = {1: ("data",), 2: ("data", "model"),
                 3: ("pod", "data", "model")}


def resolve_axis_names(shape: Sequence[int],
                       axis_names: Optional[Sequence[str]] = None) -> tuple:
    """Validate a mesh shape and resolve its axis names (shared by
    ``MeshBudget.from_shape`` and ``launch.mesh.make_production_mesh``
    so the launcher's mesh and the planner's budget can never
    desynchronise).  Defaults by rank: ("data",), ("data", "model"),
    ("pod", "data", "model")."""
    shape = tuple(int(s) for s in shape)
    if not shape or any(s < 1 for s in shape):
        raise ValueError(f"mesh shape must be positive, got {shape}")
    if axis_names is None:
        if len(shape) not in _DEFAULT_AXES:
            raise ValueError(
                f"no default axis_names for a rank-{len(shape)} mesh "
                f"{shape}; pass axis_names explicitly")
        axis_names = _DEFAULT_AXES[len(shape)]
    axis_names = tuple(axis_names)
    if len(axis_names) != len(shape):
        raise ValueError(f"axis_names {axis_names} does not match "
                         f"shape {shape}")
    return shape, axis_names


def spec_divisor(spec, axis_sizes: Mapping[str, int]) -> int:
    """Product of the mesh-axis sizes a PartitionSpec shards over.

    Entries may be ``None`` (replicated), an axis name, or a tuple of
    axis names (e.g. ``("pod", "data")`` from ZeRO-1).
    """
    div = 1
    for entry in spec:
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        for nm in names:
            div *= int(axis_sizes.get(nm, 1))
    return div


@dataclasses.dataclass(frozen=True)
class MeshBudget:
    """Per-device budget + sharding divisors for one mesh shape.

    ``axis_sizes`` is an ordered tuple of (axis name, size) pairs — e.g.
    ``(("data", 4), ("model", 2))``.  ``hbm_per_device_bytes`` is the
    memory each device offers; the planner subtracts the fixed
    (param/grad/optimizer shard) bytes and plans activations into the
    remainder.
    """
    axis_sizes: Tuple[Tuple[str, int], ...]
    hbm_per_device_bytes: float
    zero1: bool = False
    seq_parallel: bool = False
    # param-sharding policy flags — must match what the launcher passes
    # to specs.params_shardings or fixed bytes diverge from reality:
    # attn_replicated keeps attention projections data-parallel only,
    # expert_2d spreads expert weights over data x model
    attn_replicated: bool = False
    expert_2d: bool = False

    # ------------------------------------------------------------------
    @classmethod
    def from_shape(cls, shape: Sequence[int], hbm_per_device: float, *,
                   axis_names: Optional[Sequence[str]] = None,
                   zero1: bool = False, seq_parallel: bool = False,
                   attn_replicated: bool = False,
                   expert_2d: bool = False) -> "MeshBudget":
        shape, axis_names = resolve_axis_names(shape, axis_names)
        return cls(tuple(zip(axis_names, shape)), float(hbm_per_device),
                   zero1=zero1, seq_parallel=seq_parallel,
                   attn_replicated=attn_replicated, expert_2d=expert_2d)

    @classmethod
    def from_mesh(cls, mesh, hbm_per_device: float, *,
                  zero1: bool = False, seq_parallel: bool = False,
                  attn_replicated: bool = False,
                  expert_2d: bool = False) -> "MeshBudget":
        """Build from a live ``jax.sharding.Mesh`` (dry-run / launcher)."""
        return cls(tuple((a, int(mesh.shape[a])) for a in mesh.axis_names),
                   float(hbm_per_device), zero1=zero1,
                   seq_parallel=seq_parallel,
                   attn_replicated=attn_replicated, expert_2d=expert_2d)

    # ------------------------------------------------------------------
    @property
    def axis_dict(self) -> dict:
        return dict(self.axis_sizes)

    @property
    def n_devices(self) -> int:
        return int(np.prod([s for _, s in self.axis_sizes]))

    @property
    def data_ways(self) -> int:
        """Product of all non-``model`` axes (pod x data)."""
        return int(np.prod([s for a, s in self.axis_sizes if a != "model"]))

    @property
    def model_ways(self) -> int:
        return int(self.axis_dict.get("model", 1))

    def sig(self) -> tuple:
        """Hashable identity for plan / jit cache keys: two budgets with
        different mesh shapes (or sharding-policy settings) must never
        share a cached plan or executable."""
        return (self.axis_sizes, self.zero1, self.seq_parallel,
                self.attn_replicated, self.expert_2d)

    # -- activations ----------------------------------------------------
    def activation_divisor(self, shape: Sequence[int], *, batch: int,
                           d_model: int) -> int:
        """Sharding divisor for one saved-residual (activation) leaf.

        Mirrors the activation side of ``sharding/specs.py``: leaves that
        do not lead with the batch axis are treated as replicated
        (broadcast constants, scalars).  Batch-leading leaves shard the
        batch over the data ways; residual-stream boundary tensors
        ``(B, S, d_model)`` stay replicated over ``model`` (megatron)
        unless ``seq_parallel``, while every other batch-leading leaf is
        a tensor-parallel intermediate (attention heads / scores, MLP
        hidden, qkv) and divides by the model ways when divisible.
        """
        shape = tuple(int(s) for s in shape)
        if not shape or shape[0] != int(batch):
            return 1
        div = 1
        if self.data_ways > 1 and shape[0] % self.data_ways == 0:
            div *= self.data_ways
        boundary = len(shape) == 3 and shape[-1] == int(d_model)
        if boundary:
            if (self.seq_parallel and self.model_ways > 1
                    and shape[1] % self.model_ways == 0):
                div *= self.model_ways
        elif self.model_ways > 1:
            rest = int(np.prod(shape[1:])) if len(shape) > 1 else 1
            if rest % self.model_ways == 0:
                div *= self.model_ways
        return div

    # -- parameters -----------------------------------------------------
    def _param_spec(self, path: tuple, leaf, *, scanned: bool):
        return SP.param_spec(path, leaf, scanned=scanned, mesh=None,
                             model_dim=self.model_ways,
                             attn_replicated=self.attn_replicated,
                             expert_2d=self.expert_2d,
                             data_dim=self.axis_dict.get("data", 1))

    def param_divisor(self, path: tuple, leaf, *, scanned: bool) -> int:
        """Exact divisor for one parameter leaf via ``specs.param_spec``
        (honouring this budget's attn_replicated / expert_2d policy)."""
        return spec_divisor(self._param_spec(path, leaf, scanned=scanned),
                            self.axis_dict)

    def _moment_divisor(self, path: tuple, leaf, *, scanned: bool) -> int:
        """Optimizer-moment divisor: like the parameter, plus ZeRO-1's
        extra data sharding on the first unsharded divisible axis
        (replaying ``specs.opt_state_shardings``)."""
        spec = self._param_spec(path, leaf, scanned=scanned)
        div = spec_divisor(spec, self.axis_dict)
        if self.zero1 and self.data_ways > 1:
            padded = list(spec) + [None] * (len(leaf.shape) - len(spec))
            for i, s in enumerate(padded):
                if s is None and leaf.shape[i] % self.data_ways == 0:
                    div *= self.data_ways
                    break
        return div


def unit_moment_bytes(unit_params, budget: Optional[MeshBudget] = None, *,
                      scanned: bool = False) -> float:
    """Fp32 AdamW moment bytes (m + v) owned by ONE plan unit — the
    per-unit price vector of the ``OFFLOAD_OPT`` action.

    ``unit_params`` is the unit's parameter subtree (one block in
    unrolled mode, a scan-stacked layer slice in scan mode — the
    stacked leaves count every layer in the chunk, which is exactly
    what parking the chunk's moments frees).  Without a budget the
    bytes are global (``2 x 4 x n`` per leaf); with a ``MeshBudget``
    each leaf divides by its moment divisor (param sharding plus the
    ZeRO-1 data sharding), matching ``fixed_train_bytes_per_device``'s
    accounting leaf for leaf so the freed bytes subtract consistently
    from the fixed footprint.  ``scanned=True`` prepends a synthetic
    ``blocks`` path entry so ``specs.param_spec`` sees the stacked
    leaves' leading layer axis.
    """
    prefix = (jax.tree_util.DictKey("blocks"),) if scanned else ()
    total = 0.0

    def one(path, leaf):
        nonlocal total
        if not hasattr(leaf, "shape"):
            return leaf
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        div = (budget._moment_divisor(prefix + tuple(path), leaf,
                                      scanned=scanned)
               if budget is not None else 1)
        total += 2 * 4 * n / div                     # fp32 m + v
        return leaf

    jax.tree_util.tree_map_with_path(one, unit_params)
    return float(total)


def fixed_train_bytes_per_device(params, budget: MeshBudget, *,
                                 scanned: bool = False,
                                 optimizer: str = "adamw",
                                 grad_dtype_bytes: Optional[int] = None
                                 ) -> float:
    """Per-device resident bytes independent of input size.

    The sharded counterpart of ``planner.fixed_train_bytes``: each
    parameter leaf is divided by its ``specs.param_spec`` divisor
    (under the budget's attn_replicated / expert_2d policy), gradients
    shard like parameters, and the fp32 AdamW moments shard like
    parameters plus the ZeRO-1 data sharding when enabled.
    """
    total = 0.0

    def one(path, leaf):
        nonlocal total
        if not hasattr(leaf, "shape"):
            return leaf
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        itemsize = np.dtype(leaf.dtype).itemsize
        pdiv = budget.param_divisor(path, leaf, scanned=scanned)
        pb = n * itemsize / pdiv
        gb = (n * grad_dtype_bytes / pdiv if grad_dtype_bytes is not None
              else pb)
        ob = 0.0
        if optimizer == "adamw":
            mdiv = budget._moment_divisor(path, leaf, scanned=scanned)
            ob = 2 * 4 * n / mdiv                    # fp32 m + v
        total += pb + gb + ob
        return leaf

    jax.tree_util.tree_map_with_path(one, params)
    return float(total)
