"""Model/optimizer state persistence (msgpack + raw numpy buffers).

``save`` is crash-consistent (tmp + atomic rename) and ``load`` is
strict: the stored treedef string, per-leaf dtype and per-leaf shape are
all validated against the ``like`` structure, with the offending leaf's
tree path in every error message.  A truncated or bit-flipped file
raises a ``CheckpointError`` instead of silently restoring garbage —
the snapshot layer (``repro.train.resilience``) additionally guards
whole snapshots with a content-hash manifest.
"""
from __future__ import annotations

import os
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


class CheckpointError(ValueError):
    """A checkpoint file does not match the expected structure/content."""


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _path_str(path) -> str:
    """Human-readable tree path for error messages."""
    return jax.tree_util.keystr(path) if path else "<root>"


def save(path: str, tree) -> None:
    leaves, treedef = _flatten(tree)
    payload = {
        "treedef": str(treedef),
        "leaves": [
            {"dtype": str(np.asarray(l).dtype),
             "shape": list(np.shape(l)),
             "data": np.asarray(l, order="C").tobytes()}
            for l in leaves
        ],
    }
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def load(path: str, like) -> Any:
    """Restore into the structure of ``like``.

    Validates treedef, per-leaf shape AND dtype against ``like`` and the
    stored byte count against the declared shape — a checkpoint written
    for a different model/optimizer (or truncated on disk) fails loudly
    with the leaf path in the message, never silently reinterprets
    bytes.  Leaf buffers are copied out of the msgpack payload before
    ``jnp.asarray`` so no returned array aliases the (read-only) file
    buffer.
    """
    with open(path, "rb") as f:
        try:
            payload = msgpack.unpackb(f.read(), raw=False)
        except Exception as e:
            raise CheckpointError(f"{path}: not a readable checkpoint "
                                  f"({type(e).__name__}: {e})") from e
    if not isinstance(payload, dict) or "leaves" not in payload:
        raise CheckpointError(f"{path}: malformed checkpoint payload")
    path_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    stored_treedef = payload.get("treedef")
    if stored_treedef is not None and stored_treedef != str(treedef):
        raise CheckpointError(
            f"{path}: treedef mismatch — checkpoint was written for a "
            f"different structure.\n  stored:   {stored_treedef[:200]}\n"
            f"  expected: {str(treedef)[:200]}")
    stored = payload["leaves"]
    if len(stored) != len(path_leaves):
        raise CheckpointError(f"{path}: checkpoint has {len(stored)} "
                              f"leaves, expected {len(path_leaves)}")
    out = []
    for (leaf_path, ref), rec in zip(path_leaves, stored):
        where = _path_str(leaf_path)
        ref_dtype = np.asarray(ref).dtype
        if str(rec["dtype"]) != str(ref_dtype):
            raise CheckpointError(
                f"{path}: dtype mismatch at {where}: stored "
                f"{rec['dtype']}, expected {ref_dtype}")
        dtype = np.dtype(rec["dtype"])
        shape = tuple(int(d) for d in rec["shape"])
        want = int(np.prod(shape)) * dtype.itemsize if shape else dtype.itemsize
        if len(rec["data"]) != want:
            raise CheckpointError(
                f"{path}: truncated/corrupt leaf at {where}: "
                f"{len(rec['data'])} bytes stored, {want} expected "
                f"for shape {shape} {dtype}")
        # frombuffer returns a read-only view over the msgpack bytes —
        # copy before handing it to jnp so nothing downstream aliases
        # (or trips over) the immutable buffer
        arr = np.frombuffer(rec["data"], dtype=dtype).reshape(shape).copy()
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise CheckpointError(
                f"{path}: shape mismatch at {where}: stored {arr.shape}, "
                f"expected {np.shape(ref)}")
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), out)
