"""Model/optimizer state persistence (msgpack + raw numpy buffers)."""
from __future__ import annotations

import os
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, tree) -> None:
    leaves, treedef = _flatten(tree)
    payload = {
        "treedef": str(treedef),
        "leaves": [
            {"dtype": str(np.asarray(l).dtype),
             "shape": list(np.shape(l)),
             "data": np.asarray(l, order="C").tobytes()}
            for l in leaves
        ],
    }
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def load(path: str, like) -> Any:
    """Restore into the structure of ``like`` (shape/dtype checked)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves, treedef = _flatten(like)
    stored = payload["leaves"]
    if len(stored) != len(leaves):
        raise ValueError(f"checkpoint has {len(stored)} leaves, "
                         f"expected {len(leaves)}")
    out = []
    for ref, rec in zip(leaves, stored):
        arr = np.frombuffer(rec["data"], dtype=rec["dtype"]).reshape(rec["shape"])
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(f"shape mismatch {arr.shape} vs {np.shape(ref)}")
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), out)
