"""Training loop with the Mimose planner on the critical path (paper §4.1).

The trainer is the execution half of the *compile-once bucketed engine*:

  1. Each incoming batch is padded up to the planner's quantum
     (``repro.data.pipeline.pad_batch``) so batch geometry is always
     drawn from the small fixed bucket set; the true ``lengths`` stay in
     the batch dict until the loss weights are materialised, so masking
     is exact and padded positions contribute nothing.
  2. ``planner.plan`` maps the bucket to a typed action plan
     (``repro.actions.Action``: KEEP / REMAT / OFFLOAD-to-host) — cached
     plans are O(1); new buckets cost <1 ms (estimator + scheduler) or
     one deduplicated abstract collection during sheltered execution.
  3. The plan cache and the jit-step cache share one key: the planner's
     ``bucket_key`` (quantised input size).  Because padding collapses
     every raw shape in a bucket onto the bucket's canonical shape, a
     repeated bucket never recompiles *or* replans, and total XLA
     compiles are bounded by #buckets, not #distinct raw shapes.  Both
     caches are bounded LRUs (``max_cached_steps`` here, ``max_plans``
     on the planner) with eviction counters, so a long-tailed bucket
     distribution cannot pin a compiled executable per rare bucket.
  4. ``prewarm`` AOT-compiles (``jit.lower(...).compile()``) the top-k
     buckets off the critical path before step 0, so the first epoch
     never stalls on mid-training compilation.
  5. When the plan carries a gradient-accumulation split
     (``Plan.microbatch > 1``, chosen by the adaptive-microbatching
     planner), the step executes as a ``lax.scan`` over ``k``
     microbatches (``repro.train.accumulate``) with token-weighted
     accumulation, so loss/grads match the full-batch step exactly.
     The jit-step cache key includes ``k``; ``StepStats.microbatches``
     and ``summary()['mean_microbatches']`` report where it kicked in.

Sharding: pass ``mesh`` to build and run every step under that Mesh
context (required for ``with_sharding_constraint`` in the model).  The
jit-step cache key embeds the planner's mesh signature, so executables
compiled for one mesh shape are never replayed under another — the
execution-side mirror of the planner's (bucket, mesh) plan-cache key.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from collections.abc import MutableMapping
from typing import Any, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import LRUCache
from repro.core.planner import PlannerBase
from repro.data.pipeline import pad_batch
from repro.models.lm import LM
from repro.obs import LabelView, StatsView, Telemetry, TRACK_STEP
from repro.optim.adamw import AdamW, AdamWState
from repro.train.accumulate import accumulated_grads, build_accumulated_step
from repro.train.transfer import TransferLane


@dataclasses.dataclass
class StepStats:
    loss: float
    step_time_s: float
    plan_time_s: float
    compile: bool
    remat_units: int
    tokens: int                # effective (unpadded) tokens in the step
    bucket: int = 0
    padded_tokens: int = 0     # bucket-shape tokens actually computed over
    offload_units: int = 0     # units whose residuals went to host memory
    microbatches: int = 1      # gradient-accumulation split of the step
    opt_offload_units: int = 0  # units whose optimizer moments were parked
    # True when the plan carried OFFLOAD actions but this runtime/mesh
    # cannot execute real host offload (lm.offload_exec == False): the
    # step ran them as plain remat — the silent SPMD degradation, made
    # visible (see launch/report.engine_report)
    offload_degraded: bool = False
    # measured wall time this step spent BLOCKED on host<->device
    # moment traffic (TransferLane accounting), and what the simulator's
    # pricing predicts for the same bytes — the pair the bench gate
    # holds to a tolerance band
    exposed_transfer_s: float = 0.0
    sim_transfer_s: float = 0.0


class Trainer:
    def __init__(self, lm: LM, planner: PlannerBase,
                 optimizer: Optional[AdamW] = None,
                 remat_policy=None,
                 bucket_pad: bool = True,
                 mesh=None,
                 max_cached_steps: int = 64,
                 watchdog=None,
                 snapshots=None,
                 telemetry: Optional[Telemetry] = None):
        self.lm = lm
        self.planner = planner
        # ONE registry per run: the trainer's telemetry is authoritative
        # and the planner / watchdog / snapshot manager re-home their
        # metrics into it, so overlapping counters (oom_events,
        # escalations) become a single shared metric instead of
        # parallel bookkeeping (repro.obs)
        self.telemetry = telemetry if telemetry is not None \
            else Telemetry.disabled()
        planner.bind_telemetry(self.telemetry)
        self.optimizer = optimizer or AdamW()
        self.remat_policy = remat_policy
        self.bucket_pad = bucket_pad
        self.mesh = mesh                  # jax.sharding.Mesh or None
        # elastic resilience (repro.train.resilience): the OOM watchdog
        # wraps step execution in a bounded retry/escalate loop, and the
        # snapshot manager periodically persists full training state
        self.watchdog = watchdog          # resilience.OOMWatchdog or None
        self.snapshots = snapshots        # resilience.SnapshotManager or None
        self.global_step = 0              # across restarts (set on resume)
        self.data_cursor = 0              # batches consumed from the stream
        self.restores = 0                 # snapshots restored into this run
        # real offload execution: one dedicated transfer lane (lazy —
        # only plans with OFFLOAD_OPT units ever create it) moves
        # optimizer moments device<->host with double buffering; the
        # parked-unit set is the execution-side record of which units'
        # moments currently live on the host
        self.transfer_lane: Optional[TransferLane] = None
        self._parked: set = set()
        self._degraded_buckets: set = set()
        # bounded LRU: a long-tailed bucket distribution must not pin a
        # compiled executable per rare bucket forever
        self._step_cache = LRUCache(max_cached_steps)
        self.history: list[StepStats] = []
        reg = self.telemetry.metrics
        # per bucket: padded vs effective tokens (where the padding
        # waste went — launch/report.engine_report) and the largest
        # gradient-accumulation split the planner picked
        self._m_padded_tokens = reg.counter(
            "train_bucket_padded_tokens",
            "bucket-shape tokens actually computed over")
        self._m_eff_tokens = reg.counter(
            "train_bucket_tokens", "effective (unpadded) tokens")
        self._g_bucket_k = reg.gauge(
            "train_bucket_microbatch",
            "largest gradient-accumulation split seen per bucket")
        self._h_step_s = reg.histogram(
            "train_step_time_s", "wall time per executed train step")
        self.cache_stats = StatsView(
            reg,
            scalars={"compiles": "train_jit_compiles",
                     "prewarm_compiles": "train_jit_prewarm_compiles",
                     "jit_hits": "train_jit_hits",
                     "evictions": "train_jit_evictions"},
            labeled={"bucket_steps": ("train_bucket_steps", "bucket")},
            composite={
                "bucket_tokens": self._bucket_tokens_view,
                "bucket_microbatch":
                    lambda: LabelView(self._g_bucket_k, "bucket")})

    # watchdog / snapshots are properties so a post-construction
    # assignment (``tr.watchdog = OOMWatchdog(...)``) still re-homes the
    # component's metrics into the trainer's registry — the shared
    # oom_events / escalations counters only exist when both sides are
    # bound to the same registry
    @property
    def watchdog(self):
        return self._watchdog

    @watchdog.setter
    def watchdog(self, wd) -> None:
        if wd is not None and hasattr(wd, "bind_telemetry"):
            wd.bind_telemetry(self.telemetry)
        self._watchdog = wd

    @property
    def snapshots(self):
        return self._snapshots

    @snapshots.setter
    def snapshots(self, sm) -> None:
        if sm is not None and hasattr(sm, "bind_telemetry"):
            sm.bind_telemetry(self.telemetry)
        self._snapshots = sm

    def _bucket_tokens_view(self) -> dict:
        """``{bucket: [padded_tokens, effective_tokens]}`` materialised
        from the two per-bucket token counters."""
        padded = LabelView(self._m_padded_tokens, "bucket")
        eff = LabelView(self._m_eff_tokens, "bucket")
        return {b: [padded.get(b, 0), eff.get(b, 0)]
                for b in set(padded) | set(eff)}

    # ------------------------------------------------------------------
    def _batch_key(self, batch) -> tuple:
        # dtypes matter, not just shapes: prewarmed entries are AOT
        # Compiled executables fixed to the exact avals they were lowered
        # with — a same-shape/different-dtype batch must miss the cache
        # and compile, not crash inside a Compiled call.  ``lengths`` is
        # excluded: _prepare always materialises it as (B,) int32, whose
        # aval is implied by the tokens shape already in the key — its
        # *values* are runtime operands of the length-aware kernels, so
        # raggedness never forces a recompile.
        return tuple(sorted((k, tuple(np.shape(v)),
                             str(getattr(v, "dtype", "")))
                            for k, v in batch.items() if k != "lengths"))

    def _prepare(self, batch) -> dict:
        """Bucket-pad and device-put one batch.

        The true ``lengths`` stay in the batch (defaulted to the full
        sequence when absent) so the model can thread them into the
        length-aware kernels — padded positions are masked out of
        attention/SSD and skipped blockwise, not just zero-weighted in
        the loss."""
        if self.bucket_pad:
            batch = pad_batch(batch, getattr(self.planner, "quantum", 1))
        B, S = np.shape(batch["tokens"])
        if "lengths" not in batch:
            batch = dict(batch)
            batch["lengths"] = np.full((B,), S, np.int32)
        return {k: jnp.asarray(np.asarray(v, np.int32) if k == "lengths"
                               else v)
                for k, v in batch.items()}

    def _build_step(self, mask, microbatch: int = 1):
        opt = self.optimizer
        lm = self.lm
        policy = self.remat_policy
        opt_units = tuple(i for i, m in enumerate(mask) if int(m) == 3)
        if opt_units and lm.cfg.remat_mode != "scan":
            # OFFLOAD_OPT (ZeRO-Offload style): the step splits into a
            # grad phase and an update phase, because the parked units'
            # moments must be OFF the device exactly while activations
            # peak (forward/backward) and on it only for opt.update.
            # The trainer runs the choreography (_run_opt_split): grads
            # dispatch async, the TransferLane uploads parked moments
            # behind the backward pass, update runs, fresh moments
            # stream back out.
            if microbatch > 1:
                def grad_fn(p, b):
                    return accumulated_grads(lm, p, b, microbatch,
                                             actions=mask,
                                             remat_policy=policy)
            else:
                def grad_fn(p, b):
                    def loss_fn(pp):
                        return lm.loss(pp, b, remat_mask=mask,
                                       remat_policy=policy)
                    (loss, metrics), grads = jax.value_and_grad(
                        loss_fn, has_aux=True)(p)
                    return loss, metrics, grads

            # donate grads (aliases new_params) and the moment state;
            # params must NOT be donated too — outputs consume only two
            # params-worth of buffers, a third donated set would just
            # warn as unusable
            update_fn = jax.jit(
                lambda g, s, p: opt.update(g, s, p),
                donate_argnums=(0, 1))
            return ("opt_split", jax.jit(grad_fn), update_fn, opt_units)
        if microbatch > 1:
            # k-way gradient accumulation: one lax.scan over the split
            # batch, token-weighted so loss/grads match the full-batch
            # step exactly (repro.train.accumulate)
            return build_accumulated_step(lm, opt, mask, microbatch,
                                          remat_policy=policy)

        def train_step(params, opt_state, batch):
            def loss_fn(p):
                loss, metrics = lm.loss(p, batch, remat_mask=mask,
                                        remat_policy=policy)
                return loss, metrics
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            new_params, new_opt = opt.update(grads, opt_state, params)
            return new_params, new_opt, loss, metrics

        return jax.jit(train_step, donate_argnums=(0, 1))

    def _step_key(self, mask, batch, microbatch: int = 1) -> tuple:
        # the bucket id is fully determined by the padded shapes already in
        # the batch signature (bucket = quantised element count), so the
        # jit cache keys on (shapes, action plan, microbatch split, mesh
        # signature) and aligns with the plan cache (keyed on (bucket id,
        # mesh signature, max_microbatches)) through the shared
        # bucket_length rounding + planner.mesh_sig.  ``mask`` is the
        # planner's typed action tuple (or a legacy bool tuple) — two
        # plans that remat the same units but offload or split
        # differently must compile separately.
        return (self._batch_key(batch), tuple(int(m) for m in mask),
                int(microbatch), self.planner.mesh_sig())

    def _mesh_ctx(self):
        """Mesh context for compile + execute (no-op without a mesh)."""
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def _get_step_fn(self, mask, batch, microbatch: int = 1):
        key = self._step_key(mask, batch, microbatch)
        fn = self._step_cache.get(key)
        if fn is None:
            fn = self._build_step(mask, microbatch)
            self._step_cache[key] = fn
            self.cache_stats["compiles"] += 1
            self.cache_stats["evictions"] = self._step_cache.evictions
            return fn, True
        self.cache_stats["jit_hits"] += 1
        return fn, False

    # -- optimizer-moment parking (OFFLOAD_OPT execution) ---------------
    def _lane(self) -> TransferLane:
        if self.transfer_lane is None:
            self.transfer_lane = TransferLane(
                mesh_sig=self.planner.mesh_sig(),
                telemetry=self.telemetry)
        return self.transfer_lane

    def _moment_get(self, tree, u: int):
        """The moment subtree of plan unit ``u`` (unrolled mode: enc
        units first, then decoder blocks — mirrors LM.plan_units)."""
        enc = self.lm._num_enc_units()
        if u < enc:
            return tree["encoder"]["blocks"][u]
        return tree["blocks"][u - enc]

    def _moment_set(self, tree, u: int, val):
        enc = self.lm._num_enc_units()
        t = dict(tree)
        if u < enc:
            te = dict(t["encoder"])
            bl = list(te["blocks"])
            bl[u] = val
            te["blocks"] = bl
            t["encoder"] = te
        else:
            bl = list(t["blocks"])
            bl[u - enc] = val
            t["blocks"] = bl
        return t

    def _park_moments(self, opt_state: AdamWState, opt_units) -> AdamWState:
        """Stream the fp32 AdamW m/v of every OFFLOAD_OPT unit to host
        memory on the transfer lane and splice the host buffers into the
        state tree — those bytes are genuinely off the device until the
        next update phase.  All copies are started before any is waited
        on, so the lane double-buffers across units."""
        if not opt_units:
            self._parked = set()
            return opt_state
        lane = self._lane()
        m, v = opt_state.m, opt_state.v
        pending = []
        for u in opt_units:
            for which, tree in (("m", m), ("v", v)):
                leaves, tdef = jax.tree_util.tree_flatten(
                    self._moment_get(tree, u))
                pending.append((u, which, tdef,
                                [lane.offload(x) for x in leaves]))
        for u, which, tdef, hs in pending:
            sub = jax.tree_util.tree_unflatten(
                tdef, [lane.host_value(h) for h in hs])
            if which == "m":
                m = self._moment_set(m, u, sub)
            else:
                v = self._moment_set(v, u, sub)
        self._parked = set(opt_units)
        return opt_state._replace(m=m, v=v)

    def _unpark_moments(self, opt_state: AdamWState) -> AdamWState:
        """Bring every parked moment subtree back to the device (called
        with the backward pass already dispatched, so the lane's H2D
        copies ride behind device compute)."""
        if not self._parked:
            return opt_state
        lane = self._lane()
        m, v = opt_state.m, opt_state.v
        pending = []
        for u in sorted(self._parked):
            for which, tree in (("m", m), ("v", v)):
                leaves, tdef = jax.tree_util.tree_flatten(
                    self._moment_get(tree, u))
                pending.append((u, which, tdef,
                                [lane.upload(x) for x in leaves]))
        for u, which, tdef, hs in pending:
            sub = jax.tree_util.tree_unflatten(
                tdef, [lane.fetch(h) for h in hs])
            if which == "m":
                m = self._moment_set(m, u, sub)
            else:
                v = self._moment_set(v, u, sub)
        self._parked = set()
        return opt_state._replace(m=m, v=v)

    def _run_opt_split(self, fn, params, opt_state, batch):
        """Execute one OFFLOAD_OPT step: grads dispatch asynchronously,
        parked moments stream home behind the backward pass, the update
        runs with everything on device, and the new plan's moments
        stream back out."""
        _tag, grad_fn, update_fn, opt_units = fn
        loss, metrics, grads = grad_fn(params, batch)
        opt_state = self._unpark_moments(opt_state)
        new_params, new_opt = update_fn(grads, opt_state, params)
        new_opt = self._park_moments(new_opt, opt_units)
        return new_params, new_opt, loss, metrics

    # ------------------------------------------------------------------
    def prewarm(self, params, opt_state: AdamWState,
                seq_lens: Iterable[int], batch_size: int,
                extra=None) -> int:
        """AOT-compile the train step for the given bucket seq-lens off
        the critical path (``jit.lower(...).compile()`` — no step is
        executed, params are untouched).  Plans for those buckets are
        computed and cached along the way, so the first real batch of a
        prewarmed bucket is a pure cache hit on both caches.

        ``extra`` maps additional batch keys to ``fn(batch_size, S) ->
        array`` builders (the ``make_batches`` convention) — required for
        families whose batches carry more than tokens/labels/weights
        (encoder ``frames``, VLM ``vision_embeds``).  Returns the number
        of executables compiled."""
        n = 0
        for S in seq_lens:
            raw = {
                "tokens": np.zeros((batch_size, int(S)), np.int32),
                "labels": np.zeros((batch_size, int(S)), np.int32),
                "weights": np.ones((batch_size, int(S)), np.float32),
            }
            if extra:
                raw.update({k: v(batch_size, int(S))
                            for k, v in extra.items()})
            batch = self._prepare(raw)
            mask, _info = self.planner.plan(params, batch)
            k = max(int(getattr(_info.plan, "microbatch", 1)), 1)
            key = self._step_key(mask, batch, k)
            if key in self._step_cache:
                continue
            fn = self._build_step(mask, k)
            with self._mesh_ctx():
                if isinstance(fn, tuple):
                    # opt-split step: AOT-compile the grad phase (the
                    # memory-critical one); the small update phase jits
                    # on first use
                    tag, gf, uf, units = fn
                    gf = gf.lower(params, batch).compile()
                    self._step_cache[key] = (tag, gf, uf, units)
                else:
                    self._step_cache[key] = fn.lower(params, opt_state,
                                                     batch).compile()
            self.cache_stats["prewarm_compiles"] += 1
            self.cache_stats["evictions"] = self._step_cache.evictions
            n += 1
        return n

    # ------------------------------------------------------------------
    def step(self, params, opt_state: AdamWState, batch) -> tuple:
        tel = self.telemetry
        tracer = tel.tracer
        batch = self._prepare(batch)
        t0 = time.perf_counter()
        with tracer.span("plan", TRACK_STEP):
            mask, info = self.planner.plan(params, batch)
        t_plan = time.perf_counter() - t0

        bucket = self.planner.bucket_key(batch)
        wd = self.watchdog
        attempt = 0
        while True:
            k = max(int(getattr(info.plan, "microbatch", 1)), 1)
            t_c0 = time.perf_counter()
            fn, is_new = self._get_step_fn(mask, batch, k)
            if is_new:
                tracer.complete("build_step", t_c0,
                                time.perf_counter() - t_c0, TRACK_STEP,
                                args={"bucket": bucket}
                                if tel.trace_on else None)
            if self.transfer_lane is not None:
                self.transfer_lane.reset_stats()
            t1 = time.perf_counter()
            try:
                if wd is not None:
                    # injected faults fire BEFORE the jit call so no
                    # donated buffer is consumed by a simulated failure
                    wd.maybe_inject(step=self.global_step, bucket=bucket)
                with self._mesh_ctx(), tracer.span("execute", TRACK_STEP):
                    if isinstance(fn, tuple) and fn[0] == "opt_split":
                        params, opt_state, loss, metrics = \
                            self._run_opt_split(fn, params, opt_state,
                                                batch)
                    else:
                        params, opt_state, loss, metrics = fn(
                            params, opt_state, batch)
                    # device sync: an async allocation failure surfaces
                    # here, inside the try, not on a later unrelated line
                    loss = float(loss)
            except Exception as e:
                if wd is None or not wd.is_oom(e):
                    raise
                # the plan predicted this bucket fits; reality disagreed —
                # book it (ONE bump of the shared train_oom_events
                # counter — the planner's stats view reads the same
                # metric), poison the compiled step for the failed plan,
                # and ask the planner for a strictly more aggressive one
                wd.on_oom(bucket)
                self._step_cache.pop(self._step_key(mask, batch, k))
                if tel.events_on:
                    tel.events.emit("oom", step=self.global_step,
                                    bucket=bucket, attempt=attempt + 1)
                tracer.instant("oom", TRACK_STEP, args={"bucket": bucket})
                attempt += 1
                if attempt > wd.max_retries \
                        or not self.planner.escalate(params, batch):
                    wd.on_retry_failure()
                    raise
                t0b = time.perf_counter()
                with tracer.span("plan", TRACK_STEP):
                    mask, info = self.planner.plan(params, batch)
                t_plan += time.perf_counter() - t0b
                continue
            break
        if wd is not None and attempt:
            wd.on_retry_success()
        t_step = time.perf_counter() - t1
        eff_tokens = int(metrics["tokens"])
        padded_tokens = int(np.prod(np.shape(batch["tokens"])))
        if k > 1:
            # a non-divisor split pads the batch axis to ceil(B/k)*k
            # rows and computes over them — count what actually ran, or
            # the padding-waste accounting understates those buckets
            B0 = int(np.shape(batch["tokens"])[0])
            padded_tokens = padded_tokens // B0 * (-(-B0 // k) * k)
        self.cache_stats.inc("bucket_steps", bucket=bucket)
        self._m_padded_tokens.inc(padded_tokens, bucket=bucket)
        self._m_eff_tokens.inc(eff_tokens, bucket=bucket)
        self._g_bucket_k.set_max(k, bucket=bucket)
        self._h_step_s.observe(t_step)
        # transfer telemetry: what the lane measured this step vs what
        # the simulator's (1 - overlap) pricing predicts for the SAME
        # bytes — the bench gate holds the pair to a tolerance band
        exposed_s = 0.0
        sim_s = 0.0
        if self.transfer_lane is not None:
            xfer = self.transfer_lane.reset_stats()
            exposed_s = float(xfer["exposed_s"])
            moved = float(xfer["bytes_out"] + xfer["bytes_in"])
            if moved:
                pcie = float(getattr(self.planner, "pcie_gbps", 16.0)) * 1e9
                ov = float(getattr(self.planner, "offload_overlap", 0.5))
                sim_s = (1.0 - ov) * moved / pcie
        if exposed_s or sim_s:
            reg = tel.metrics
            reg.counter("train_exposed_transfer_s").inc(exposed_s)
            reg.counter("train_sim_transfer_s").inc(sim_s)
        degraded = bool(info.plan.n_offload and not self.lm.offload_exec)
        if degraded:
            tel.metrics.counter("train_offload_degraded_steps").inc()
        if degraded and bucket not in self._degraded_buckets:
            # surface the silent SPMD offload->remat degradation: once
            # per bucket into the planner's stats (engine_report reads
            # it), every step into StepStats
            self._degraded_buckets.add(bucket)
            st = getattr(self.planner, "stats", None)
            if isinstance(st, MutableMapping):
                st["offload_fallbacks"] = st.get("offload_fallbacks", 0) + 1
        self.history.append(StepStats(loss, t_step, t_plan, is_new,
                                      info.plan.n_remat, eff_tokens, bucket,
                                      padded_tokens,
                                      offload_units=info.plan.n_offload,
                                      microbatches=k,
                                      opt_offload_units=getattr(
                                          info.plan, "n_opt", 0),
                                      offload_degraded=degraded,
                                      exposed_transfer_s=exposed_s,
                                      sim_transfer_s=sim_s))
        if tel.events_on:
            tel.events.emit("train_step", step=self.global_step,
                            bucket=bucket, loss=loss, k=k,
                            compile=bool(is_new),
                            plan_source=info.plan.source,
                            cache_hit=bool(info.cache_hit),
                            n_remat=int(info.plan.n_remat),
                            n_offload=int(info.plan.n_offload),
                            step_time_s=t_step, plan_time_s=t_plan,
                            exposed_transfer_s=exposed_s,
                            predicted_peak_bytes=float(
                                self.planner.fixed_bytes or 0.0)
                            + float(info.plan.est_activation_bytes))
        self.global_step += 1
        self.data_cursor += 1
        if self.snapshots is not None and self.snapshots.due(self.global_step):
            self.snapshots.save(step=self.global_step, params=params,
                                opt_state=opt_state, planner=self.planner,
                                data_cursor=self.data_cursor)
        return params, opt_state, loss

    def run(self, params, batches, opt_state: Optional[AdamWState] = None):
        if opt_state is None:
            opt_state = self.optimizer.init(params)
        for batch in batches:
            params, opt_state, loss = self.step(params, opt_state, batch)
        return params, opt_state

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        h = self.history
        if not h:
            return {}
        # throughput is measured over WARM (post-compile) steps only; a
        # run where every step compiled has no warm-rate evidence, so
        # the throughput fields are zeroed rather than computed from
        # compile-dominated wall time (or dividing by an empty sum)
        warm = [s for s in h if not s.compile]
        warm_s = max(float(np.sum([s.step_time_s for s in warm])), 1e-9)
        eff = float(np.sum([s.tokens for s in warm]))
        padded = float(np.sum([s.padded_tokens for s in warm]))
        return {
            "steps": len(h),
            "mean_step_s": (float(np.mean([s.step_time_s for s in warm]))
                            if warm else 0.0),
            "total_plan_s": float(np.sum([s.plan_time_s for s in h])),
            "compiles": int(sum(s.compile for s in h)),
            "prewarm_compiles": int(self.cache_stats["prewarm_compiles"]),
            "jit_hits": int(self.cache_stats["jit_hits"]),
            "buckets": len(self.cache_stats["bucket_steps"]),
            "step_cache_evictions": int(self.cache_stats["evictions"]),
            "mean_remat_units": float(np.mean([s.remat_units for s in h])),
            "mean_offload_units": float(np.mean([s.offload_units
                                                 for s in h])),
            "mean_opt_offload_units": float(np.mean([s.opt_offload_units
                                                     for s in h])),
            "mean_microbatches": float(np.mean([s.microbatches
                                                for s in h])),
            # real-offload telemetry: measured lane blocking vs the
            # simulator's pricing of the same traffic, and how often
            # OFFLOAD plans degraded to remat at execution time
            "exposed_transfer_s": float(np.sum([s.exposed_transfer_s
                                                for s in h])),
            "sim_transfer_s": float(np.sum([s.sim_transfer_s
                                            for s in h])),
            "offload_degraded_steps": int(sum(s.offload_degraded
                                              for s in h)),
            "offload_fallbacks": int(getattr(self.planner, "stats", {})
                                     .get("offload_fallbacks", 0)),
            # throughput over *effective* (unpadded) tokens — the number
            # padded and ragged runs are comparable on; the raw padded
            # rate rides along as a secondary diagnostic
            "tokens_per_s": eff / warm_s if warm else 0.0,
            "padded_tokens_per_s": padded / warm_s if warm else 0.0,
            "pad_fraction": (1.0 - eff / max(padded, 1.0)) if warm else 0.0,
            "final_loss": h[-1].loss,
            # elastic-resilience counters (zero when the watchdog /
            # snapshot manager are not attached)
            "snapshots_written": int(self.snapshots.written)
            if self.snapshots is not None else 0,
            "restores": int(self.restores),
            "oom_events": int(self.watchdog.stats["oom_events"])
            if self.watchdog is not None else 0,
            "escalations": int(self.watchdog.stats["escalations"])
            if self.watchdog is not None else 0,
            "retry_successes": int(self.watchdog.stats["retry_successes"])
            if self.watchdog is not None else 0,
            "retry_failures": int(self.watchdog.stats["retry_failures"])
            if self.watchdog is not None else 0,
            "escalations_by_bucket": dict(
                getattr(self.planner, "stats", {})
                .get("escalations_by_bucket", {})),
            # background-solver counters (zero for planners without the
            # solver tier, or with --solver off)
            "solves": int(getattr(self.planner, "stats", {})
                          .get("solves", 0)),
            "solver_swaps": int(getattr(self.planner, "stats", {})
                                .get("solver_swaps", 0)),
            "solver_wins": int(getattr(self.planner, "stats", {})
                               .get("solver_wins", 0)),
            "solver_timeouts": int(getattr(self.planner, "stats", {})
                                   .get("solver_timeouts", 0)),
        }
