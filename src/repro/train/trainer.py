"""Training loop with the Mimose planner on the critical path (paper §4.1).

Per batch:
  1. ``planner.plan`` maps the batch's input size to a remat mask —
     cached plans are O(1); new sizes cost <1 ms (estimator + scheduler)
     or one abstract collection during sheltered execution.
  2. The (shape, mask) pair selects a jitted train step.  JAX recompiles
     per shape regardless; Mimose's plan cache keys align with the jit
     cache so a repeated size never recompiles *or* replans.
  3. loss -> grad -> AdamW update, loss includes MoE aux losses.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.planner import PlannerBase
from repro.models.lm import LM
from repro.optim.adamw import AdamW, AdamWState


@dataclasses.dataclass
class StepStats:
    loss: float
    step_time_s: float
    plan_time_s: float
    compile: bool
    remat_units: int
    tokens: int


class Trainer:
    def __init__(self, lm: LM, planner: PlannerBase,
                 optimizer: Optional[AdamW] = None,
                 remat_policy=None):
        self.lm = lm
        self.planner = planner
        self.optimizer = optimizer or AdamW()
        self.remat_policy = remat_policy
        self._step_cache: Dict[Any, Any] = {}
        self.history: list[StepStats] = []

    # ------------------------------------------------------------------
    def _batch_key(self, batch) -> tuple:
        return tuple(sorted((k, tuple(np.shape(v)))
                            for k, v in batch.items() if k != "lengths"))

    def _get_step_fn(self, mask: Tuple[bool, ...], batch):
        key = (self._batch_key(batch), mask)
        fn = self._step_cache.get(key)
        compiled = key in self._step_cache
        if fn is None:
            opt = self.optimizer
            lm = self.lm
            policy = self.remat_policy

            def train_step(params, opt_state, batch):
                def loss_fn(p):
                    loss, metrics = lm.loss(p, batch, remat_mask=mask,
                                            remat_policy=policy)
                    return loss, metrics
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params)
                new_params, new_opt = opt.update(grads, opt_state, params)
                return new_params, new_opt, loss, metrics

            fn = jax.jit(train_step, donate_argnums=(0, 1))
            self._step_cache[key] = fn
        return fn, not compiled

    # ------------------------------------------------------------------
    def step(self, params, opt_state: AdamWState, batch) -> tuple:
        batch = {k: jnp.asarray(v) for k, v in batch.items() if k != "lengths"}
        t0 = time.perf_counter()
        mask, info = self.planner.plan(params, batch)
        t_plan = time.perf_counter() - t0

        fn, is_new = self._get_step_fn(mask, batch)
        t1 = time.perf_counter()
        params, opt_state, loss, metrics = fn(params, opt_state, batch)
        loss = float(loss)
        t_step = time.perf_counter() - t1
        self.history.append(StepStats(loss, t_step, t_plan, is_new,
                                      int(sum(mask)),
                                      int(metrics["tokens"])))
        return params, opt_state, loss

    def run(self, params, batches, opt_state: Optional[AdamWState] = None):
        if opt_state is None:
            opt_state = self.optimizer.init(params)
        for batch in batches:
            params, opt_state, loss = self.step(params, opt_state, batch)
        return params, opt_state

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        h = self.history
        if not h:
            return {}
        warm = [s for s in h if not s.compile] or h
        return {
            "steps": len(h),
            "mean_step_s": float(np.mean([s.step_time_s for s in warm])),
            "total_plan_s": float(np.sum([s.plan_time_s for s in h])),
            "compiles": int(sum(s.compile for s in h)),
            "mean_remat_units": float(np.mean([s.remat_units for s in h])),
            "tokens_per_s": float(np.sum([s.tokens for s in warm])
                                  / max(np.sum([s.step_time_s for s in warm]),
                                        1e-9)),
            "final_loss": h[-1].loss,
        }
