"""Adaptive microbatching: gradient accumulation as a planner action.

The third axis of the memory/step-time trade space.  REMAT trades bytes
for recompute FLOPs and OFFLOAD trades bytes for PCIe traffic, but both
must keep *something* per unit on device — when a large bucket exceeds
the budget under even the most aggressive action plan, the only lever
left is the batch itself.  Splitting a mini-batch into ``k``
microbatches with gradient accumulation scales the batch-linear
activation terms by ~1/k while keeping the optimizer semantics of the
full mini-batch, so the planner can treat ``k`` as one more knob chosen
*per bucket*, jointly with the per-unit action plan
(``scheduler.greedy_plan_adaptive``).

This module is the execution half:

* ``split_batch`` — split (and, when ``B % k != 0``, zero-pad) a batch
  dict into ``k`` equal microbatches along the batch axis, the ragged
  ``lengths`` operand included.  Padded rows carry zero loss weight and
  zero length, so they contribute nothing to the loss, the gradients,
  or the length-aware kernels' executed work.
* ``accumulated_grads`` — one forward+backward per microbatch under a
  ``lax.scan``, accumulating *token-weighted* loss and gradients so the
  result matches the full-batch step exactly (the full-batch loss is
  ``sum(nll * w) / sum(w)``; weighting each microbatch's mean by its
  token count recovers the same global mean even when raggedness makes
  the microbatch weights unequal).  Activation liveness is bounded by
  ONE microbatch: each scan iteration completes its own backward before
  the next begins.
* ``accumulated_step_fn`` / ``build_accumulated_step`` — the trainer's
  train-step counterpart: grads -> optimizer update, one XLA compile
  per ``(actions, k, bucket)`` key (the trainer's jit cache adds ``k``
  to the step key).

Numerical contract (locked by ``tests/test_microbatch.py``): for
families without an auxiliary loss (dense / SSM / hybrid / enc-dec —
``aux == 0``), loss and grads from the ``k``-microbatch scan match the
full-batch step to fp32 allclose for any ``k``, including ragged
batches — exactness is why the planner may substitute a ``k``-split
step for the full step freely.  For MoE families the cross-entropy
term keeps that exactness, but the load-balance auxiliary loss is a
*nonlinear* statistic of router probabilities: the accumulated step
uses the token-weighted mean of the per-microbatch aux — the standard
gradient-accumulation semantics — which regularises balance per
microbatch rather than per mini-batch (an all-pad microbatch from
batch-axis padding contributes zero, see ``body``).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def split_batch(batch: dict, k: int) -> dict:
    """Split a batch dict into ``k`` equal microbatches along axis 0.

    Every entry with the batch leading dimension (tokens, labels,
    weights, ``lengths``, frames, vision_embeds, positions...) gains a
    leading microbatch axis: ``(B, ...) -> (k, ceil(B/k), ...)``.  When
    ``k`` does not divide ``B`` the batch axis is zero-padded first —
    pad rows get token 0, weight 0.0 and length 0, so they are inert in
    the loss and in the length-aware kernels.  ``weights`` is
    materialised (all-ones over the original rows) when absent, because
    ``lm.loss`` would otherwise give the pad rows weight 1.
    """
    k = max(int(k), 1)
    B = int(np.shape(batch["tokens"])[0])
    out = dict(batch)
    if "weights" not in out:
        out["weights"] = jnp.ones(jnp.shape(batch["tokens"]), jnp.float32)
    Bp = -(-B // k) * k
    split = {}
    for key, v in out.items():
        a = jnp.asarray(v)
        assert a.ndim >= 1 and a.shape[0] == B, (
            f"batch entry {key!r} has no batch axis to split: "
            f"shape {a.shape}, batch {B}")
        if Bp != B:
            a = jnp.pad(a, [(0, Bp - B)] + [(0, 0)] * (a.ndim - 1))
        split[key] = a.reshape((k, Bp // k) + a.shape[1:])
    return split


def accumulated_grads(lm, params, batch, k: int, actions=None,
                      remat_policy=None) -> Tuple[jax.Array, dict, dict]:
    """Loss, metrics and gradients of ``lm.loss`` over ``k`` microbatches.

    Returns ``(loss, metrics, grads)`` matching
    ``jax.value_and_grad(lm.loss, has_aux=True)`` on the full batch to
    fp32 allclose (aux-free families; the MoE auxiliary loss follows
    per-microbatch semantics — module docstring).  Each scan iteration
    accumulates the *unnormalised*
    quantities (``loss_i * tokens_i`` recovers the microbatch's nll sum
    regardless of the loss's internal weight clamp; ``grads_i *
    tokens_i`` likewise) and the final division by the true global
    token count restores the full-batch mean.  Accumulators are fp32;
    grads are cast back to the parameter dtypes at the end.
    """
    k = max(int(k), 1)
    mbs = split_batch(batch, k)

    def loss_fn(p, mb):
        return lm.loss(p, mb, remat_mask=actions, remat_policy=remat_policy)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def body(carry, mb):
        g_acc, l_acc, a_acc, w_acc = carry
        (loss, metrics), grads = grad_fn(params, mb)
        w_raw = jnp.sum(mb["weights"]).astype(jnp.float32)
        # weight by the loss's (clamped) token count so loss * t
        # recovers the microbatch's nll sum exactly — but zero it for
        # an all-pad microbatch (w_raw == 0, t clamped to 1), which
        # must contribute nothing: its ce grads vanish on their own,
        # but a family's aux loss (MoE load balance) would not
        t = jnp.where(w_raw > 0, metrics["tokens"].astype(jnp.float32),
                      0.0)
        g_acc = jax.tree_util.tree_map(
            lambda a, g: a + g.astype(jnp.float32) * t, g_acc, grads)
        l_acc = l_acc + loss.astype(jnp.float32) * t
        a_acc = a_acc + metrics["aux"].astype(jnp.float32) * t
        w_acc = w_acc + w_raw
        return (g_acc, l_acc, a_acc, w_acc), None

    init = (jax.tree_util.tree_map(
                lambda a: jnp.zeros(jnp.shape(a), jnp.float32), params),
            jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
            jnp.zeros((), jnp.float32))
    (g_acc, l_acc, a_acc, w_acc), _ = jax.lax.scan(body, init, mbs)

    denom = jnp.maximum(w_acc, 1.0)
    grads = jax.tree_util.tree_map(
        lambda g, p: (g / denom).astype(jnp.asarray(p).dtype), g_acc, params)
    loss = l_acc / denom
    aux = a_acc / denom
    metrics = {"ce": loss - aux, "aux": aux, "tokens": denom}
    return loss, metrics, grads


def accumulated_step_fn(lm, optimizer, actions, k: int, remat_policy=None):
    """Raw (un-jitted) ``k``-way accumulated train step.

    Same contract as the trainer's inner ``train_step``:
    ``(params, opt_state, batch) -> (params, opt_state, loss, metrics)``
    — the split happens *inside* the step, so callers pass the ordinary
    bucket-shaped batch and shard it as usual (``launch/steps.py`` jits
    this with its own NamedShardings for the dry-run).
    """
    def train_step(params, opt_state, batch):
        loss, metrics, grads = accumulated_grads(
            lm, params, batch, k, actions=actions, remat_policy=remat_policy)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, loss, metrics

    return train_step


def build_accumulated_step(lm, optimizer, actions, k: int,
                           remat_policy=None):
    """Jitted ``accumulated_step_fn`` (params/opt_state donated) — what
    the trainer caches under its ``(bucket, actions, k, mesh)`` key."""
    return jax.jit(accumulated_step_fn(lm, optimizer, actions, k,
                                       remat_policy=remat_policy),
                   donate_argnums=(0, 1))
