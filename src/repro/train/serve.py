"""Batched serving: prefill + token-by-token decode with KV / SSM caches.

The compiled decode step is cached per LM (``cached_serve_step``): a
``jax.jit`` callable caches its executables by input shape, so one
jitted step per model serves every (batch, chunk, cache-geometry)
bucket — the old per-call ``jax.jit(make_serve_step(lm))`` built a new
closure each time and re-traced on *every* ``generate`` /
``prefill_into_cache`` call.  ``tests/test_serve.py`` asserts the
compile counts.  The continuous-batching engine on top of this lives in
``repro.train.engine``.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LM


def make_serve_step(lm: LM):
    """jit-able decode step: (params, tokens(B,C), cache, index) -> (logits, cache)."""
    def serve_step(params, tokens, cache, index):
        return lm.decode_step(params, tokens, cache, index)
    return serve_step


def cached_serve_step(lm: LM):
    """The LM's compiled serve step — built once, cached on the model.

    ``jax.jit`` keys executables on input shapes internally, so shape
    buckets (decode (B,1), prefill chunks (B,c), different cache
    lengths) share this one callable and each geometry compiles exactly
    once per LM.  Use ``cached_serve_step(lm)._cache_size()`` to audit
    compile counts."""
    step = getattr(lm, "_serve_step_jit", None)
    if step is None:
        step = jax.jit(make_serve_step(lm))
        lm._serve_step_jit = step
    return step


def prefill_into_cache(lm: LM, params, tokens, cache, chunk: int = 32):
    """Advance the cache over the prompt a ``chunk``-token block at a
    time: ``ceil(S/chunk)`` jit dispatches instead of the S per-token
    dispatches the old reference path paid (``chunk=1`` restores it).
    At most two shapes compile — the full chunk and the remainder —
    and the numerics match the token-by-token path (the decode step
    handles any block width; ``tests/test_microbatch.py`` locks
    generation equivalence).  The dry-run prefill shape still lowers
    the one-shot forward instead."""
    B, S = tokens.shape
    chunk = max(int(chunk), 1)
    step = cached_serve_step(lm)
    logits = None
    for t in range(0, S, chunk):
        logits, cache = step(params, tokens[:, t:t + chunk], cache, t)
    return logits, cache


def generate(lm: LM, params, prompt: jnp.ndarray, max_new_tokens: int,
             temperature: float = 0.0, seed: int = 0,
             prefill_chunk: int = 32, cache_len: Optional[int] = None):
    """Greedy / sampled generation for the examples.

    ``cache_len``: total cache length to allocate (default: exactly
    ``S + max_new_tokens``).  Passing a quantum-bucketed length keeps
    the number of compiled cache geometries bounded across requests of
    different lengths — generation output is identical either way (the
    decode mask never reads past each query's own position)."""
    B, S = prompt.shape
    if cache_len is None:
        cache_len = S + max_new_tokens
    assert cache_len >= S + max_new_tokens, (cache_len, S, max_new_tokens)
    cache = lm.init_cache(B, cache_len)
    logits, cache = prefill_into_cache(lm, params, prompt, cache,
                                       chunk=prefill_chunk)
    step = cached_serve_step(lm)
    key = jax.random.PRNGKey(seed)
    toks = []
    for i in range(max_new_tokens):
        lg = logits[:, -1]
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, lg / temperature)[:, None]
        else:
            nxt = jnp.argmax(lg, axis=-1)[:, None]
        toks.append(nxt)
        logits, cache = step(params, nxt, cache, S + i)
    return jnp.concatenate(toks, axis=1)
