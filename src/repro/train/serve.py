"""Batched serving: prefill + token-by-token decode with KV / SSM caches."""
from __future__ import annotations

import time
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LM


def make_serve_step(lm: LM):
    """jit-able decode step: (params, tokens(B,1), cache, index) -> (logits, cache)."""
    def serve_step(params, tokens, cache, index):
        return lm.decode_step(params, tokens, cache, index)
    return serve_step


def prefill_into_cache(lm: LM, params, tokens, cache):
    """Feed a prompt token-by-token (reference implementation; fine for the
    CPU-scale examples.  The dry-run prefill shape lowers the one-shot
    forward instead)."""
    B, S = tokens.shape
    step = jax.jit(make_serve_step(lm))
    logits = None
    for t in range(S):
        logits, cache = step(params, tokens[:, t:t + 1], cache, t)
    return logits, cache


def generate(lm: LM, params, prompt: jnp.ndarray, max_new_tokens: int,
             temperature: float = 0.0, seed: int = 0):
    """Greedy / sampled generation for the examples."""
    B, S = prompt.shape
    cache = lm.init_cache(B, S + max_new_tokens)
    logits, cache = prefill_into_cache(lm, params, prompt, cache)
    step = jax.jit(make_serve_step(lm))
    key = jax.random.PRNGKey(seed)
    toks = []
    for i in range(max_new_tokens):
        lg = logits[:, -1]
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, lg / temperature)[:, None]
        else:
            nxt = jnp.argmax(lg, axis=-1)[:, None]
        toks.append(nxt)
        logits, cache = step(params, nxt, cache, S + i)
    return jnp.concatenate(toks, axis=1)
