"""Batched serving: prefill + token-by-token decode with KV / SSM caches."""
from __future__ import annotations

import time
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LM


def make_serve_step(lm: LM):
    """jit-able decode step: (params, tokens(B,1), cache, index) -> (logits, cache)."""
    def serve_step(params, tokens, cache, index):
        return lm.decode_step(params, tokens, cache, index)
    return serve_step


def prefill_into_cache(lm: LM, params, tokens, cache, chunk: int = 32):
    """Advance the cache over the prompt a ``chunk``-token block at a
    time: ``ceil(S/chunk)`` jit dispatches instead of the S per-token
    dispatches the old reference path paid (``chunk=1`` restores it).
    At most two shapes compile — the full chunk and the remainder —
    and the numerics match the token-by-token path (the decode step
    handles any block width; ``tests/test_microbatch.py`` locks
    generation equivalence).  The dry-run prefill shape still lowers
    the one-shot forward instead."""
    B, S = tokens.shape
    chunk = max(int(chunk), 1)
    step = jax.jit(make_serve_step(lm))
    logits = None
    for t in range(0, S, chunk):
        logits, cache = step(params, tokens[:, t:t + chunk], cache, t)
    return logits, cache


def generate(lm: LM, params, prompt: jnp.ndarray, max_new_tokens: int,
             temperature: float = 0.0, seed: int = 0,
             prefill_chunk: int = 32):
    """Greedy / sampled generation for the examples."""
    B, S = prompt.shape
    cache = lm.init_cache(B, S + max_new_tokens)
    logits, cache = prefill_into_cache(lm, params, prompt, cache,
                                       chunk=prefill_chunk)
    step = jax.jit(make_serve_step(lm))
    key = jax.random.PRNGKey(seed)
    toks = []
    for i in range(max_new_tokens):
        lg = logits[:, -1]
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, lg / temperature)[:, None]
        else:
            nxt = jnp.argmax(lg, axis=-1)[:, None]
        toks.append(nxt)
        logits, cache = step(params, nxt, cache, S + i)
    return jnp.concatenate(toks, axis=1)
