"""Elastic resilience: full-state snapshots, mesh-reshape resume, and an
OOM watchdog with DTR-style plan escalation.

An input-aware planner earns its keep on long, preemptible training
jobs — exactly the jobs that get killed, resized, and OOM-killed.  This
module makes the engine survive all three:

**Full-state snapshots** (``SnapshotManager``).  A snapshot is a
directory holding params, optimizer state, the *planner's* learned
state (estimator sample logs, plan cache, escalation levels), and a
meta record (step counter, data cursor, RNG seed).  Writes are
crash-consistent: everything lands in a tmp directory, a manifest with
per-file sha256 hashes is written last, and one ``os.replace`` makes
the snapshot visible.  Retention keeps the last *k*; restore walks
newest-to-oldest past any corrupt/partial snapshot.

**Mesh-reshape resume** (``planner_state`` / ``restore_planner_state``).
The planner's warmup state is shape-determined: collection is abstract
(``jax.eval_shape``), so the log of (input size, probe geometry) pairs
fully determines every estimator sample.  A snapshot therefore carries
that log, and restoring onto a *different* ``--mesh-shape`` replays it
abstractly under the new mesh — zero FLOPs, zero training steps of
re-warmup.  Plan-cache entries are re-keyed: plans whose stored mesh
signature matches the live mesh are restored verbatim, the rest are
dropped (their byte math was per-device under the old mesh).

**OOM watchdog** (``OOMWatchdog`` + ``FaultInjector``).  The trainer
wraps each jitted step; on a device OOM (real ``RESOURCE_EXHAUSTED``
or injected ``SimulatedOOM``) it books the failure against the bucket,
poisons the cached plan and compiled step, and asks the planner to
``escalate`` — the DTR-style ladder (more remat, then offload, then a
higher gradient-accumulation split) — before retrying, up to a bounded
number of attempts.  ``MIMOSE_INJECT_OOM`` drives deterministic fault
injection for tests and chaos drills.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import time
from collections.abc import MutableMapping
from typing import Any, Optional

import jax
import msgpack
import numpy as np

from repro.actions import Action
from repro.core.scheduler import Plan
from repro.obs import StatsView, Telemetry
from repro.train import checkpoint
from repro.train.checkpoint import CheckpointError

STATE_VERSION = 1


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------
class SimulatedOOM(RuntimeError):
    """Injected device OOM.  The message embeds RESOURCE_EXHAUSTED so the
    watchdog's matcher treats it exactly like the real XLA error."""

    def __init__(self, step: int, bucket: int):
        super().__init__(
            f"RESOURCE_EXHAUSTED: injected OOM (step={step}, "
            f"bucket={bucket}) [simulated by repro.train.resilience]")
        self.step = step
        self.bucket = bucket


class FaultInjector:
    """Deterministic OOM injection, driven by env or constructor.

    Spec formats (``MIMOSE_INJECT_OOM`` or the ``spec`` argument):

    * ``"3"`` (int string) — fail the first 3 step *executions*;
    * ``'{"bucket": {"1024": 2}, "step": {"5": 1}}'`` — fail the next 2
      executions of bucket 1024 and 1 execution of global step 5.

    Counters decrement on each injected failure, so a retried step that
    escalated past its quota succeeds — exactly the shape the watchdog
    tests need.
    """

    ENV = "MIMOSE_INJECT_OOM"

    def __init__(self, spec: Any = None):
        self._first_n = 0
        self._by_bucket: dict = {}
        self._by_step: dict = {}
        self.injected = 0
        if spec is None:
            return
        if isinstance(spec, str):
            spec = spec.strip()
            if not spec:
                return
            try:
                spec = int(spec)
            except ValueError:
                try:
                    spec = json.loads(spec)
                except json.JSONDecodeError as e:
                    raise ValueError(
                        f"{self.ENV}: expected an int or a JSON object, "
                        f"got {spec!r}") from e
        if isinstance(spec, int):
            self._first_n = max(int(spec), 0)
        elif isinstance(spec, dict):
            self._by_bucket = {int(k): int(v)
                               for k, v in (spec.get("bucket") or {}).items()}
            self._by_step = {int(k): int(v)
                             for k, v in (spec.get("step") or {}).items()}
        else:
            raise ValueError(f"{self.ENV}: unsupported spec {spec!r}")

    @classmethod
    def from_env(cls) -> Optional["FaultInjector"]:
        raw = os.environ.get(cls.ENV)
        if not raw:
            return None
        return cls(raw)

    @property
    def armed(self) -> bool:
        return (self._first_n > 0 or any(v > 0 for v in self._by_bucket.values())
                or any(v > 0 for v in self._by_step.values()))

    def should_fail(self, *, step: int, bucket: int) -> bool:
        if self._first_n > 0:
            self._first_n -= 1
            self.injected += 1
            return True
        if self._by_step.get(int(step), 0) > 0:
            self._by_step[int(step)] -= 1
            self.injected += 1
            return True
        if self._by_bucket.get(int(bucket), 0) > 0:
            self._by_bucket[int(bucket)] -= 1
            self.injected += 1
            return True
        return False


def _xla_oom_types() -> tuple:
    try:  # jaxlib's runtime error type (name has moved across versions)
        from jax.errors import JaxRuntimeError  # type: ignore
        return (JaxRuntimeError,)
    except Exception:
        pass
    try:
        from jaxlib.xla_extension import XlaRuntimeError  # type: ignore
        return (XlaRuntimeError,)
    except Exception:
        return ()


_XLA_ERRORS = _xla_oom_types()
_OOM_MARKERS = ("RESOURCE_EXHAUSTED", "Out of memory", "out of memory",
                "OOM when allocating")


class OOMWatchdog:
    """Classifies device OOMs and books them; the retry/escalate loop
    itself lives in ``Trainer.step`` (it owns the caches being poisoned).
    """

    def __init__(self, *, max_retries: int = 3,
                 injector: Optional[FaultInjector] = None,
                 telemetry: Optional[Telemetry] = None):
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.max_retries = int(max_retries)
        self.injector = injector if injector is not None \
            else FaultInjector.from_env()
        self.telemetry = telemetry if telemetry is not None \
            else Telemetry.disabled()
        # dict-shaped view over the shared registry: oom_events and
        # escalations are the SAME metrics the planner's stats read
        # once the trainer binds both to one registry — one counter,
        # two views, no double bookkeeping
        self.stats = StatsView(
            self.telemetry.metrics,
            scalars={"oom_events": "train_oom_events",
                     "escalations": "train_escalations",
                     "retry_successes": "train_retry_successes",
                     "retry_failures": "train_retry_failures"},
            labeled={"oom_by_bucket": ("train_oom_events", "bucket")})

    def bind_telemetry(self, telemetry: Telemetry) -> None:
        self.telemetry = telemetry
        self.stats.attach(telemetry.metrics)

    @staticmethod
    def is_oom(e: BaseException) -> bool:
        """True for real XLA RESOURCE_EXHAUSTED errors and injected ones.
        Matched on the message because jaxlib collapses all runtime
        failures into one exception type."""
        if isinstance(e, SimulatedOOM):
            return True
        if _XLA_ERRORS and not isinstance(e, _XLA_ERRORS):
            return False
        msg = str(e)
        return any(m in msg for m in _OOM_MARKERS)

    def maybe_inject(self, *, step: int, bucket: int) -> None:
        """Raise a SimulatedOOM when the injector says this execution
        fails.  Called by the trainer *before* launching the jit step,
        so no real work (or donated buffer) is consumed by the fault."""
        if self.injector is not None and self.injector.should_fail(
                step=step, bucket=bucket):
            raise SimulatedOOM(step, bucket)

    def on_oom(self, bucket: int) -> None:
        self.stats.inc("oom_events", bucket=int(bucket))

    def on_escalation(self) -> None:
        """Kept for standalone use; NOT called by the trainer — the
        planner's ``escalate`` bumps the shared ``train_escalations``
        counter already, and this view reads the same metric."""
        self.stats.inc("escalations")

    def on_retry_success(self) -> None:
        self.stats["retry_successes"] += 1

    def on_retry_failure(self) -> None:
        self.stats["retry_failures"] += 1


# ---------------------------------------------------------------------------
# planner state (de)serialization
# ---------------------------------------------------------------------------
def _plan_to_dict(plan: Plan) -> dict:
    return {"actions": [int(a) for a in plan.as_actions()],
            "excess_bytes": float(plan.excess_bytes),
            "covered_bytes": float(plan.covered_bytes),
            "est_activation_bytes": float(plan.est_activation_bytes),
            "recompute_flops": float(plan.recompute_flops),
            "offload_bytes": float(plan.offload_bytes),
            "microbatch": int(plan.microbatch),
            "source": str(getattr(plan, "source", "greedy"))}


def _plan_from_dict(d: dict) -> Plan:
    return Plan([], float(d["excess_bytes"]), float(d["covered_bytes"]),
                float(d["est_activation_bytes"]),
                recompute_flops=float(d.get("recompute_flops", 0.0)),
                actions=tuple(Action(int(a)) for a in d["actions"]),
                offload_bytes=float(d.get("offload_bytes", 0.0)),
                microbatch=int(d.get("microbatch", 1)),
                source=str(d.get("source", "greedy")))


def planner_state(planner) -> dict:
    """Serializable snapshot of everything the planner learned online:
    estimator sample sets, the (size, probe geometry) sample log that
    makes them replayable under a new mesh, the plan cache (keyed by
    stringified mesh signature), and escalation levels.  Planners
    without an estimator (baselines) serialize to a name-only stub."""
    state = {"version": STATE_VERSION, "name": getattr(planner, "name", "?")}
    if not hasattr(planner, "estimator"):
        return state
    state["mesh_sig"] = repr(planner.mesh_sig())
    state["estimators"] = {
        "activation": planner.estimator.state_dict(),
        "output": planner.est_output.state_dict(),
        "offload": planner.est_offload.state_dict(),
    }
    state["sample_log"] = list(getattr(planner, "_sample_log", []))
    plans = []
    esc = getattr(planner, "_escalation", {})
    for key in list(planner.cache.keys()):
        bucket, sig, max_mb, pcie, overlap = key
        plans.append({"bucket": int(bucket), "mesh_sig": repr(sig),
                      "max_microbatches": int(max_mb),
                      "pcie_gbps": float(pcie),
                      "offload_overlap": float(overlap),
                      "escalation": int(esc.get(key, 0)),
                      "plan": _plan_to_dict(planner.cache[key])})
    state["plans"] = plans
    return state


def _probe_struct(probe: dict) -> dict:
    """Rebuild an abstract batch from a logged probe geometry."""
    return {k: jax.ShapeDtypeStruct(tuple(int(d) for d in shape),
                                    np.dtype(dtype))
            for k, (shape, dtype) in probe.items()}


def restore_planner_state(planner, state: dict, params=None) -> dict:
    """Load a ``planner_state`` snapshot into a live planner.

    Same mesh signature: estimator sample sets load verbatim (and refit,
    ~1 ms).  Different mesh (elastic resume after a reshape): the stored
    per-device byte vectors are invalid, so the sample *log* is replayed
    abstractly through the live collector — each probe geometry goes
    through ``jax.eval_shape`` under the new mesh's divisors, zero FLOPs
    — and only plans whose stored signature matches the live mesh are
    restored.  ``params`` is required for replay (the collector traces
    the model).  Returns a small summary dict for reporting.
    """
    summary = {"mesh_changed": False, "restored_samples": 0,
               "restored_plans": 0, "dropped_plans": 0}
    if not hasattr(planner, "estimator") or "estimators" not in state:
        return summary
    live_sig = repr(planner.mesh_sig())
    stored_sig = state.get("mesh_sig", live_sig)
    sample_log = list(state.get("sample_log", []))
    if stored_sig == live_sig:
        ests = state["estimators"]
        planner.estimator.load_state(ests["activation"])
        planner.est_output.load_state(ests["output"])
        planner.est_offload.load_state(ests["offload"])
        planner._sample_log = sample_log
        summary["restored_samples"] = planner.estimator.num_samples
    else:
        summary["mesh_changed"] = True
        if params is None:
            raise ValueError(
                "restore_planner_state: mesh signature changed "
                f"({stored_sig} -> {live_sig}) — replaying the sample log "
                "needs params (pass the restored model params)")
        planner._sample_log = []
        for rec in sample_log:
            probe = _probe_struct(rec["probe"])
            res = planner.collector.collect(params, probe)
            planner._feed_estimators(int(rec["size"]), res, probe)
            summary["restored_samples"] += 1
        if planner.estimator.ready:
            planner.estimator.fit()
            planner.est_output.fit()
            planner.est_offload.fit()
    # plans: rebuild keys from the LIVE planner's signature; entries from
    # another mesh are per-device math for the wrong mesh — drop them.
    # Same for the roofline constants: a plan solved under different
    # PCIe bandwidth / overlap assumptions would resurrect a stale
    # cost model, so mismatches are dropped rather than re-keyed.
    # (Older snapshots lack the fields; default to the live values.)
    live_pcie = round(float(getattr(planner, "pcie_gbps", 0.0)), 6)
    live_overlap = round(float(getattr(planner, "offload_overlap", 0.0)), 6)
    for rec in state.get("plans", []):
        if rec.get("mesh_sig") != live_sig:
            summary["dropped_plans"] += 1
            continue
        rec_pcie = round(float(rec.get("pcie_gbps", live_pcie)), 6)
        rec_overlap = round(float(rec.get("offload_overlap",
                                          live_overlap)), 6)
        if rec_pcie != live_pcie or rec_overlap != live_overlap:
            summary["dropped_plans"] += 1
            continue
        key = (int(rec["bucket"]), planner.mesh_sig(),
               int(rec["max_microbatches"]), live_pcie, live_overlap)
        planner.cache[key] = _plan_from_dict(rec["plan"])
        if rec.get("escalation"):
            planner._escalation[key] = int(rec["escalation"])
        summary["restored_plans"] += 1
    st = getattr(planner, "stats", None)
    if isinstance(st, MutableMapping):
        st["restored_samples"] = st.get("restored_samples", 0) \
            + summary["restored_samples"]
        st["restored_plans"] = st.get("restored_plans", 0) \
            + summary["restored_plans"]
        st["dropped_plans"] = st.get("dropped_plans", 0) \
            + summary["dropped_plans"]
    return summary


# ---------------------------------------------------------------------------
# snapshots
# ---------------------------------------------------------------------------
class SnapshotError(RuntimeError):
    """A snapshot directory failed validation (missing/corrupt files)."""


@dataclasses.dataclass
class Restored:
    """Everything ``SnapshotManager.restore_latest`` hands back."""
    params: Any
    opt_state: Any
    step: int
    data_cursor: int
    planner_summary: dict
    path: str
    meta: dict


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class SnapshotManager:
    """Periodic, atomic, self-validating training snapshots.

    ``due(step)`` fires on a step cadence (``every_steps``) and/or a
    wall-clock cadence (``every_secs``) — preemption-safe jobs want the
    latter so a slow bucket cannot stretch the exposure window.  Each
    ``save`` writes params/opt/planner/meta into ``<dir>/.tmp-*``, then
    a ``manifest.json`` carrying the sha256 + byte count of every file
    (written LAST: a manifest's existence certifies a complete write),
    then atomically renames to ``snap-<step>``.  ``keep`` bounds disk:
    oldest snapshots beyond the last *k* are deleted after each save.
    """

    MANIFEST = "manifest.json"

    def __init__(self, directory: str, *, every_steps: int = 0,
                 every_secs: float = 0.0, keep: int = 3,
                 telemetry: Optional[Telemetry] = None):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.dir = directory
        self.every_steps = int(every_steps)
        self.every_secs = float(every_secs)
        self.keep = int(keep)
        self.written = 0
        self.telemetry = telemetry if telemetry is not None \
            else Telemetry.disabled()
        self._last_save = time.monotonic()
        os.makedirs(self.dir, exist_ok=True)

    def bind_telemetry(self, telemetry: Telemetry) -> None:
        self.telemetry = telemetry

    # -- cadence -------------------------------------------------------
    def due(self, step: int) -> bool:
        if self.every_steps > 0 and step > 0 \
                and step % self.every_steps == 0:
            return True
        if self.every_secs > 0 \
                and time.monotonic() - self._last_save >= self.every_secs:
            return True
        return False

    # -- write ---------------------------------------------------------
    def save(self, *, step: int, params, opt_state, planner=None,
             data_cursor: int = 0, extra: Optional[dict] = None) -> str:
        final = os.path.join(self.dir, f"snap-{step:08d}")
        tmp = os.path.join(self.dir, f".tmp-snap-{step:08d}")
        if os.path.isdir(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        checkpoint.save(os.path.join(tmp, "params.ckpt"), params)
        checkpoint.save(os.path.join(tmp, "opt.ckpt"), opt_state)
        if planner is not None:
            with open(os.path.join(tmp, "planner.msgpack"), "wb") as f:
                f.write(msgpack.packb(planner_state(planner),
                                      use_bin_type=True))
        meta = {"step": int(step), "data_cursor": int(data_cursor),
                "wall_time": time.time(), "extra": extra or {}}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f, indent=1)
        files = {name: {"sha256": _sha256(os.path.join(tmp, name)),
                        "bytes": os.path.getsize(os.path.join(tmp, name))}
                 for name in sorted(os.listdir(tmp))}
        # manifest last: its presence certifies every file above landed
        with open(os.path.join(tmp, self.MANIFEST), "w") as f:
            json.dump({"step": int(step), "files": files}, f, indent=1)
        if os.path.isdir(final):          # re-save of the same step
            shutil.rmtree(final)
        os.replace(tmp, final)
        self.written += 1
        self._last_save = time.monotonic()
        self.telemetry.metrics.counter(
            "snapshots_written", "atomic snapshot saves").inc()
        if self.telemetry.events_on:
            self.telemetry.events.emit(
                "snapshot_save", step=int(step), path=final,
                bytes=int(sum(rec["bytes"] for rec in files.values())))
        self._retain()
        return final

    def _retain(self) -> None:
        snaps = self.snapshots()
        for old in snaps[:-self.keep]:
            shutil.rmtree(old, ignore_errors=True)

    # -- read ----------------------------------------------------------
    def snapshots(self) -> list:
        """All snapshot dirs, oldest first (tmp dirs excluded)."""
        if not os.path.isdir(self.dir):
            return []
        return sorted(os.path.join(self.dir, d)
                      for d in os.listdir(self.dir)
                      if d.startswith("snap-"))

    def latest(self) -> Optional[str]:
        snaps = self.snapshots()
        return snaps[-1] if snaps else None

    def verify(self, path: str) -> dict:
        """Validate one snapshot dir against its manifest.  Returns the
        manifest; raises SnapshotError on any missing/corrupt file."""
        man_path = os.path.join(path, self.MANIFEST)
        if not os.path.isfile(man_path):
            raise SnapshotError(f"{path}: no manifest (partial write?)")
        try:
            with open(man_path) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise SnapshotError(f"{path}: unreadable manifest: {e}") from e
        for name, rec in manifest.get("files", {}).items():
            fp = os.path.join(path, name)
            if not os.path.isfile(fp):
                raise SnapshotError(f"{path}: missing file {name}")
            if os.path.getsize(fp) != rec["bytes"]:
                raise SnapshotError(
                    f"{path}: {name} is {os.path.getsize(fp)} bytes, "
                    f"manifest says {rec['bytes']}")
            if _sha256(fp) != rec["sha256"]:
                raise SnapshotError(f"{path}: {name} content hash mismatch")
        return manifest

    def restore_latest(self, *, params_like, opt_like, planner=None) -> Restored:
        """Restore the newest snapshot that validates, walking past any
        corrupt/partial one (a preempted save leaves either a manifest-
        less tmp dir — never listed — or an older complete snapshot)."""
        errors = []
        for path in reversed(self.snapshots()):
            try:
                self.verify(path)
                with open(os.path.join(path, "meta.json")) as f:
                    meta = json.load(f)
                params = checkpoint.load(os.path.join(path, "params.ckpt"),
                                         params_like)
                opt_state = checkpoint.load(os.path.join(path, "opt.ckpt"),
                                            opt_like)
                psummary = {}
                ppath = os.path.join(path, "planner.msgpack")
                if planner is not None and os.path.isfile(ppath):
                    with open(ppath, "rb") as f:
                        pstate = msgpack.unpackb(f.read(), raw=False,
                                                 strict_map_key=False)
                    psummary = restore_planner_state(planner, pstate,
                                                     params=params)
                self.telemetry.metrics.counter(
                    "snapshots_restored", "snapshot restores").inc()
                if self.telemetry.events_on:
                    self.telemetry.events.emit(
                        "snapshot_restore", step=int(meta["step"]),
                        path=path,
                        restored_plans=psummary.get("restored_plans", 0),
                        dropped_plans=psummary.get("dropped_plans", 0),
                        mesh_changed=psummary.get("mesh_changed", False))
                return Restored(params=params, opt_state=opt_state,
                                step=int(meta["step"]),
                                data_cursor=int(meta.get("data_cursor", 0)),
                                planner_summary=psummary, path=path,
                                meta=meta)
            except (SnapshotError, CheckpointError, OSError,
                    KeyError, ValueError) as e:
                errors.append(f"{path}: {e}")
                continue
        raise SnapshotError(
            "no restorable snapshot under " + self.dir
            + ("; tried:\n  " + "\n  ".join(errors) if errors else ""))
