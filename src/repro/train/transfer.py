"""Double-buffered device<->host transfer lane for real offload overlap.

The simulator prices an OFFLOAD action at ``2*bytes/pcie`` with a
``(1 - overlap)`` exposure factor; this module is the execution side
that makes the overlap real instead of aspirational:

* ``to_host`` moves an array to pinned host memory via
  ``jax.device_put`` with a ``pinned_host`` memory-kind sharding when
  the jaxlib build supports it, degrading to ``jax.device_get``
  (pageable numpy) otherwise — the same capability split as
  ``repro.models.lm.host_offload_policy``.
* ``TransferLane`` runs those copies on ONE dedicated worker thread
  with a bounded in-flight depth of two (classic double buffering: one
  copy draining while the next is queued).  Only time a caller spends
  *blocked* on the lane — waiting for a slot, or waiting on a fetch the
  copy hasn't finished — is charged to ``stats['exposed_s']``; copies
  that complete behind compute cost nothing, which is exactly the
  quantity the simulator calls exposed transfer time.
* ``measure_pcie_gbps`` times a round trip through the lane's copy
  path and ``write_calibration`` persists it, so planners price the
  link at the bandwidth this host actually has instead of the 16 GB/s
  roofline default (``MIMOSE_PCIE_GBPS`` overrides both).
"""
from __future__ import annotations

import json
import os
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.obs import Telemetry, TRACK_TRANSFER

# env overrides: bandwidth wins outright, path relocates the JSON
PCIE_ENV = "MIMOSE_PCIE_GBPS"
CALIBRATION_ENV = "MIMOSE_CALIBRATION"
DEFAULT_CALIBRATION_PATH = ".mimose_calibration.json"

# lane depth 2 == double buffering: one transfer in flight while the
# next is being produced; a third enqueue blocks (and the block is
# what gets charged as exposed time)
DEFAULT_DEPTH = 2

_pinned_supported: Optional[bool] = None
_pinned_lock = threading.Lock()


def host_memory_supported() -> bool:
    """True when this jaxlib can place arrays in pinned host memory
    (``memory_kind='pinned_host'``).  Probed once with a real 1-element
    transfer — constructing the sharding alone succeeds on builds that
    later fail at placement."""
    global _pinned_supported
    with _pinned_lock:
        if _pinned_supported is None:
            try:
                dev = jax.devices()[0]
                sh = jax.sharding.SingleDeviceSharding(
                    dev, memory_kind="pinned_host")
                y = jax.device_put(np.zeros((1,), np.float32), sh)
                jax.block_until_ready(y)
                _pinned_supported = True
            except Exception:
                _pinned_supported = False
        return bool(_pinned_supported)


def _host_sharding(x):
    """Pinned-host placement matching ``x``'s current sharding when the
    runtime offers one (keeps SPMD arrays shard-local on the host
    instead of gathering), else a single-device pinned sharding."""
    sh = getattr(x, "sharding", None)
    if sh is not None:
        try:
            return sh.with_memory_kind("pinned_host")
        except (AttributeError, TypeError, ValueError):
            pass
    return jax.sharding.SingleDeviceSharding(
        jax.devices()[0], memory_kind="pinned_host")


def to_host(x):
    """Move ``x`` to host memory: pinned (async-DMA-capable) when the
    build supports it, pageable numpy otherwise."""
    if host_memory_supported():
        return jax.device_put(x, _host_sharding(x), donate=True)
    return jax.device_get(x)


def to_device(x, like=None):
    """Move a host buffer back to the device, restoring ``like``'s
    sharding when given (the round trip of ``to_host``)."""
    if like is not None:
        sh = getattr(like, "sharding", None)
        if sh is not None:
            return jax.device_put(x, sh)
    if isinstance(x, jax.Array):
        sh = getattr(x, "sharding", None)
        try:
            if sh is not None and sh.memory_kind == "pinned_host":
                return jax.device_put(x, sh.with_memory_kind("device"))
        except (AttributeError, TypeError, ValueError):
            pass
    return jax.device_put(x, jax.devices()[0])


def _nbytes(x) -> int:
    try:
        return int(x.nbytes)
    except (AttributeError, TypeError):
        return int(np.asarray(x).nbytes)


class HostHandle:
    """Ticket for one offloaded array: resolve with
    ``TransferLane.fetch``.  ``key`` identifies the host-buffer class
    ((shape, dtype, mesh signature)) so shard-local buffers from
    different meshes never alias."""

    __slots__ = ("future", "key", "nbytes", "like")

    def __init__(self, future: Future, key, nbytes: int, like=None):
        self.future = future
        self.key = key
        self.nbytes = nbytes
        self.like = like


class TransferLane:
    """One dedicated worker thread moving arrays device<->host with a
    bounded in-flight depth (default 2 = double buffered).

    stats:
      bytes_out / bytes_in   total bytes moved each direction
      transfers              completed copies (both directions)
      copy_s                 wall time the worker spent inside copies —
                             the step's realised round-trip transfer
                             time (== bytes / the bandwidth this step
                             actually achieved, contention included)
      exposed_s              wall time callers spent BLOCKED on the
                             lane — the measured counterpart of the
                             simulator's exposed transfer seconds, and
                             bounded by ``copy_s`` when the accounting
                             is consistent (a caller can wait each copy
                             out at most once)
    """

    def __init__(self, depth: int = DEFAULT_DEPTH,
                 mesh_sig: Optional[tuple] = None,
                 telemetry: Optional[Telemetry] = None):
        self.depth = max(int(depth), 1)
        self.mesh_sig = mesh_sig
        # ``stats`` stays a plain per-step scratch dict (the trainer
        # zeroes it every step via reset_stats); the telemetry registry
        # accumulates the run totals and the tracer gets copy/exposed
        # spans on the dedicated transfer track
        self.telemetry = telemetry if telemetry is not None \
            else Telemetry.disabled()
        self._pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="mimose-xfer")
        self._in_flight: list = []          # oldest-first outbound futures
        self._lock = threading.Lock()
        self.stats: Dict[str, Any] = {"bytes_out": 0, "bytes_in": 0,
                                      "transfers": 0, "copy_s": 0.0,
                                      "exposed_s": 0.0}

    # -- internal ------------------------------------------------------
    def _charge(self, dt: float) -> None:
        with self._lock:
            self.stats["exposed_s"] += float(dt)
        self.telemetry.metrics.counter(
            "transfer_exposed_s",
            "wall time callers spent blocked on the lane").inc(float(dt))
        if dt > 0.0:
            # retroactive span: the caller was blocked for the interval
            # ending now — lands under the execute span that paid it
            self.telemetry.tracer.complete(
                "exposed", time.perf_counter() - dt, dt, TRACK_TRANSFER)

    def _reserve_slot(self) -> None:
        """Block until the lane has a free in-flight slot; the wait is
        exposed time (the producer stalled on the link)."""
        while True:
            with self._lock:
                self._in_flight = [f for f in self._in_flight
                                   if not f.done()]
                if len(self._in_flight) < self.depth:
                    return
                oldest = self._in_flight[0]
            t0 = time.perf_counter()
            oldest.result()
            self._charge(time.perf_counter() - t0)

    def _copy_out(self, x):
        t0 = time.perf_counter()
        y = to_host(x)
        jax.block_until_ready(y)
        dt = time.perf_counter() - t0
        with self._lock:
            self.stats["transfers"] += 1
            self.stats["copy_s"] += dt
        tel = self.telemetry
        tel.metrics.counter("transfer_copy_s").inc(dt)
        tel.metrics.counter("transfer_bytes_out").inc(_nbytes(x))
        tel.tracer.complete("copy_d2h", t0, dt, TRACK_TRANSFER,
                            args={"bytes": _nbytes(x)}
                            if tel.trace_on else None)
        return y

    def _copy_in(self, host, like):
        t0 = time.perf_counter()
        y = to_device(host, like)
        jax.block_until_ready(y)
        dt = time.perf_counter() - t0
        with self._lock:
            self.stats["transfers"] += 1
            self.stats["copy_s"] += dt
        tel = self.telemetry
        tel.metrics.counter("transfer_copy_s").inc(dt)
        tel.metrics.counter("transfer_bytes_in").inc(_nbytes(host))
        tel.tracer.complete("copy_h2d", t0, dt, TRACK_TRANSFER,
                            args={"bytes": _nbytes(host)}
                            if tel.trace_on else None)
        return y

    # -- API -----------------------------------------------------------
    def offload(self, x, *, like=None) -> HostHandle:
        """Start moving ``x`` to the host on the lane thread; returns
        immediately (unless both buffers are busy).  ``like`` pins the
        sharding ``fetch`` restores; defaults to ``x`` itself."""
        nbytes = _nbytes(x)
        key = (tuple(np.shape(x)), str(getattr(x, "dtype", "f32")),
               self.mesh_sig)
        self._reserve_slot()
        fut = self._pool.submit(self._copy_out, x)
        with self._lock:
            self._in_flight.append(fut)
            self.stats["bytes_out"] += nbytes
        return HostHandle(fut, key, nbytes, like=like if like is not None
                          else x)

    def upload(self, x, *, like=None) -> HostHandle:
        """Start moving a host buffer to the device on the lane thread
        (the H2D mirror of ``offload``); resolve with ``fetch``."""
        nbytes = _nbytes(x)
        key = (tuple(np.shape(x)), str(getattr(x, "dtype", "f32")),
               self.mesh_sig)
        self._reserve_slot()
        fut = self._pool.submit(self._copy_in, x, like)
        with self._lock:
            self._in_flight.append(fut)
            self.stats["bytes_in"] += nbytes
        return HostHandle(fut, key, nbytes, like=like)

    def host_value(self, handle: HostHandle):
        """Resolve a ``offload`` handle to its HOST buffer (no return
        trip).  Only the wait is exposed."""
        t0 = time.perf_counter()
        val = handle.future.result()
        self._charge(time.perf_counter() - t0)
        return val

    def prefetch(self, handle: HostHandle) -> HostHandle:
        """Start the return copy on the lane thread before the value is
        needed (the backward-pass half of double buffering).  Returns a
        new handle whose ``fetch`` yields the device array."""
        outbound = handle.future

        def back():
            return self._copy_in(outbound.result(), handle.like)

        self._reserve_slot()
        fut = self._pool.submit(back)
        with self._lock:
            self._in_flight.append(fut)
            self.stats["bytes_in"] += handle.nbytes
        h = HostHandle(fut, handle.key, handle.nbytes, like=handle.like)
        return h

    def fetch(self, handle: HostHandle):
        """Resolve a handle to a device array.  Only the time actually
        spent waiting (copy not yet finished) is exposed."""
        t0 = time.perf_counter()
        val = handle.future.result()
        self._charge(time.perf_counter() - t0)
        if isinstance(val, jax.Array):
            try:
                if val.sharding.memory_kind != "pinned_host":
                    return val              # prefetch already landed it
            except (AttributeError, TypeError):
                return val
            t0 = time.perf_counter()
            out = self._copy_in(val, handle.like)
            self._charge(time.perf_counter() - t0)
            with self._lock:
                self.stats["bytes_in"] += handle.nbytes
            return out
        # numpy fallback: the return trip is a plain device_put
        t0 = time.perf_counter()
        out = self._copy_in(val, handle.like)
        self._charge(time.perf_counter() - t0)
        with self._lock:
            self.stats["bytes_in"] += handle.nbytes
        return out

    def drain(self) -> None:
        """Wait for every in-flight copy (exposed: the step can't end
        with the link still busy)."""
        with self._lock:
            pending = list(self._in_flight)
            self._in_flight = []
        t0 = time.perf_counter()
        for f in pending:
            try:
                f.result()
            except Exception:
                pass
        self._charge(time.perf_counter() - t0)

    def reset_stats(self) -> Dict[str, Any]:
        """Return current stats and zero the counters (per-step use)."""
        with self._lock:
            out = dict(self.stats)
            self.stats = {"bytes_out": 0, "bytes_in": 0,
                          "transfers": 0, "copy_s": 0.0,
                          "exposed_s": 0.0}
        return out

    def close(self) -> None:
        self.drain()
        self._pool.shutdown(wait=True)


# ---------------------------------------------------------------------------
# bandwidth calibration
# ---------------------------------------------------------------------------

def calibration_path() -> str:
    return os.environ.get(CALIBRATION_ENV, DEFAULT_CALIBRATION_PATH)


def read_calibration(path: Optional[str] = None) -> Optional[dict]:
    p = path or calibration_path()
    try:
        with open(p) as f:
            cal = json.load(f)
        return cal if isinstance(cal, dict) else None
    except (OSError, ValueError):
        return None


def write_calibration(cal: dict, path: Optional[str] = None) -> str:
    p = path or calibration_path()
    with open(p, "w") as f:
        json.dump(cal, f, indent=2, sort_keys=True)
        f.write("\n")
    return p


def measure_pcie_gbps(size_mb: int = 64, repeats: int = 3) -> dict:
    """Time ``size_mb`` float32s through the lane's copy path, both
    directions; the reported figure is the round-trip-harmonic GB/s the
    simulator's ``2*bytes/pcie`` pricing wants.  Best-of-``repeats``
    (bandwidth is a capability, not an average).  On CPU-only builds
    this measures memcpy, which is still the honest cost of that
    build's 'offload'."""
    n = int(size_mb) * (1 << 20) // 4
    x = jax.device_put(np.ones((n,), np.float32))
    jax.block_until_ready(x)
    nbytes = float(n * 4)
    best_out = 0.0
    best_in = 0.0
    for _ in range(max(int(repeats), 1)):
        t0 = time.perf_counter()
        h = to_host(jax.device_put(np.ones((n,), np.float32)))
        jax.block_until_ready(h)
        dt = time.perf_counter() - t0
        best_out = max(best_out, nbytes / dt / 1e9)
        t0 = time.perf_counter()
        y = to_device(h, like=x)
        jax.block_until_ready(y)
        dt = time.perf_counter() - t0
        best_in = max(best_in, nbytes / dt / 1e9)
    # round-trip bandwidth: harmonic mean (the 2*bytes/pcie model moves
    # the same payload once each way)
    rt = 2.0 / (1.0 / best_out + 1.0 / best_in)
    return {"pcie_gbps": round(rt, 3),
            "device_to_host_gbps": round(best_out, 3),
            "host_to_device_gbps": round(best_in, 3),
            "pinned_host": host_memory_supported(),
            "backend": jax.default_backend(),
            "size_mb": int(size_mb), "repeats": int(repeats)}


def calibrated_pcie_gbps(default: float) -> float:
    """The link bandwidth planning should price: the ``MIMOSE_PCIE_GBPS``
    env wins, then this host's calibration file, then ``default``."""
    env = os.environ.get(PCIE_ENV)
    if env:
        try:
            return float(env)
        except ValueError:
            pass
    cal = read_calibration()
    if cal:
        try:
            v = float(cal.get("pcie_gbps", 0.0))
            if v > 0.0:
                return v
        except (TypeError, ValueError):
            pass
    return float(default)
