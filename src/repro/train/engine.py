"""Continuous-batching serve engine with input-aware admission (ROADMAP 1).

The training side of Mimose predicts per-bucket activation bytes to
choose remat plans; serving has the same input dynamics — prompt
lengths vary per request, so KV/SSM cache footprint is dynamic, and a
static batch size either wastes HBM or OOMs.  This engine makes the
prediction drive *admission* instead:

* **Quantum-keyed cache pools.**  Every request is bucketed by its
  padded total length (prompt + decode budget, rounded up to the engine
  quantum).  In-flight requests of a bucket share one pooled cache
  (``LM.init_cache(slots, bucket)``) whose batch rows are request
  slots; slot counts grow through a fixed power-of-two tier ladder.
  All device shapes — decode (slots, 1), prefill chunks (1, c) with c
  from a fixed power-of-two set, slot insert/evict — are therefore
  drawn from O(#buckets) geometries, so the compile-once property holds
  for serving exactly as it does for training.

* **Input-aware admission.**  A ``PolyEstimator`` (the paper's §4.3
  lightning estimator, reused verbatim) is fitted on per-cache-leaf
  bytes vs bucket length and predicts the HBM cost of admitting each
  queued request: its staging row, its pool slot (including any tier
  growth), and its prefill-chunk workspace.  The engine admits when
  ``predicted_bytes + cost <= hbm_bytes``, otherwise the request waits
  in a deferred queue — it never allocates first and OOMs later.
  Prefill chunk sizes are chosen the same way: the largest
  power-of-two chunk whose predicted workspace fits the current
  headroom.

* **Scheduler loop.**  Each iteration releases due arrivals, admits
  what fits (FIFO), advances every prefilling request by one chunk,
  then runs ``decode_steps`` batched decode steps over every active
  pool — one dispatch decodes a token for every slot in the pool
  (per-row cache positions via the vector ``cache_index`` path in
  ``models/lm.py``; empty slots park at index == bucket so their
  writes drop).  Greedy sampling is token-for-token identical to
  sequential ``train.serve.generate`` (``tests/test_serve.py``).

The wall clock fast-forwards over idle gaps (open-loop arrivals far
apart), so tests and benches never sleep; latency percentiles use the
same engine clock.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estimator import PolyEstimator
from repro.data.pipeline import bucket_length
from repro.data.trace import TraceRequest
from repro.models.lm import LM
from repro.obs import StatsView, Telemetry, TRACK_SERVE
from repro.train.serve import cached_serve_step


def tree_device_bytes(tree) -> int:
    """Total bytes of every array leaf of ``tree`` (live device state)."""
    return int(sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(tree)
                   if hasattr(l, "dtype")))


def cache_leaf_bytes(lm: LM, max_len: int) -> np.ndarray:
    """Exact per-leaf bytes of a one-slot cache at ``max_len`` — the
    ground truth the admission estimator is fitted on (and validated
    against: ``bench_engine`` gates predicted vs actual).  Abstract
    (``eval_shape``): nothing allocates."""
    shapes = jax.eval_shape(lambda: lm.init_cache(1, int(max_len)))
    return np.array([math.prod(l.shape) * jnp.dtype(l.dtype).itemsize
                     for l in jax.tree_util.tree_leaves(shapes)],
                    dtype=np.float64)


def _percentile(xs: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


def _make_decode_core(lm: LM):
    """Greedy batched decode step: next token per row + advanced cache.
    Argmax lives inside the jit so only (slots,) int32 leaves the device
    per step, not (slots, vocab) logits."""
    def decode_core(params, tokens, cache, index):
        logits, cache = lm.decode_step(params, tokens, cache, index)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache
    return decode_core


@dataclasses.dataclass
class _Live:
    """Engine-side state of one admitted request."""
    req: TraceRequest
    bucket: int
    arrival_s: float
    t_admit: float
    staging: Any = None            # (1, bucket) cache during prefill
    pos: int = 0                   # prompt tokens prefilled so far
    pool: Optional["BucketPool"] = None
    slot: int = -1
    tokens: List[int] = dataclasses.field(default_factory=list)
    token_times: List[float] = dataclasses.field(default_factory=list)
    t_done: float = 0.0


class BucketPool:
    """One bucket's pooled cache: batch rows are request slots."""

    def __init__(self, lm: LM, bucket: int, slots: int):
        self.bucket = bucket
        self.slots = slots
        self.cache = lm.init_cache(slots, bucket)
        # empty slots park one past the last cache row: decode writes
        # at their index drop (scatter mode="drop"), reads are masked
        self.index = np.full((slots,), bucket, np.int32)
        self.last_tok = np.zeros((slots,), np.int32)
        self.live: List[Optional[_Live]] = [None] * slots

    def n_active(self) -> int:
        """Rows actually decoding (a reserved row still prefilling has
        ``staging`` set and is skipped by the decode harvest)."""
        return sum(l is not None and l.staging is None for l in self.live)

    def free_slot(self) -> int:
        for i, l in enumerate(self.live):
            if l is None:
                return i
        return -1

    def cache_bytes(self) -> int:
        return tree_device_bytes(self.cache)


class ServeEngine:
    """Continuous-batching scheduler over bucketed cache pools.

    Parameters
    ----------
    hbm_bytes:       serve HBM budget (params + caches + workspace).
    quantum:         bucket granularity for padded total length.
    max_slots:       per-bucket slot ceiling (tier ladder 1,2,4,..).
    prefill_chunk:   largest prefill chunk (power of two).
    decode_steps:    decode iterations per scheduler loop (multi-token
                     decode amortises scheduler overhead).
    warmup_buckets:  how many seed lengths the admission estimator is
                     fitted on (exact eval_shape samples).
    """

    def __init__(self, lm: LM, params, *, hbm_bytes: float,
                 quantum: int = 64, max_slots: int = 4,
                 prefill_chunk: int = 32, decode_steps: int = 4,
                 warmup_buckets: int = 3, estimator_degree: int = 2,
                 telemetry: Optional[Telemetry] = None):
        if lm.kind == "dec":
            raise ValueError(
                "encoder/decoder serving needs encoder frames per request;"
                " the continuous-batching engine serves decoder-only "
                "families (dense/moe/ssm/hybrid)")
        self.lm = lm
        self.params = params
        self.hbm_bytes = float(hbm_bytes)
        self.quantum = max(int(quantum), 1)
        self.max_slots = max(int(max_slots), 1)
        self.prefill_chunk = max(int(prefill_chunk), 1)
        self.decode_steps = max(int(decode_steps), 1)
        self.tiers = self._slot_tiers(self.max_slots)
        cfg = lm.cfg
        self._token_ws = (4 * cfg.vocab_size
                          + 8 * cfg.d_model * jnp.dtype(lm.dtype).itemsize)
        self._chunks = [1 << i for i in
                        range(int(math.log2(self.prefill_chunk)) + 1)]

        # the paper's lightning estimator, re-aimed at cache bytes:
        # per-leaf bytes vs bucket length (linear for KV, constant for
        # SSM state — degree-2 covers both), fitted on a few exact
        # abstract samples and predicting every other bucket
        self.estimator = PolyEstimator(degree=estimator_degree)
        for i in range(max(warmup_buckets, estimator_degree + 1)):
            s = self.quantum * (1 + 2 * i)
            self.estimator.add_sample(s, cache_leaf_bytes(lm, s))
        self.estimator.fit()

        self.param_bytes = tree_device_bytes(params)
        if self.param_bytes >= self.hbm_bytes:
            raise ValueError(
                f"serve budget {self.hbm_bytes / 1e9:.3f} GB below the "
                f"model's parameter bytes ({self.param_bytes / 1e9:.3f} GB)")

        self.pools: Dict[int, BucketPool] = {}
        self.waiting: List[_Live] = []       # admitted = removed from here
        self.prefilling: List[_Live] = []
        self.done: List[_Live] = []
        self.rejected: List[_Live] = []

        # compiled entry points — ONE jitted callable each (executables
        # keyed by shape inside jit), cached ON THE LM exactly like
        # ``train.serve.cached_serve_step``: a second engine over the
        # same model reuses every compiled executable instead of
        # re-tracing.  ``compile_keys`` mirrors the shape geometries
        # seen so compile counts are auditable per kind.
        jits = getattr(lm, "_engine_jits", None)
        if jits is None:
            jits = {"decode": jax.jit(_make_decode_core(lm)),
                    "prefill": cached_serve_step(lm),
                    "insert": jax.jit(lm.cache_insert),
                    "evict": jax.jit(lm.cache_evict)}
            lm._engine_jits = jits
        self._decode_jit = jits["decode"]
        self._prefill_jit = jits["prefill"]
        self._insert_jit = jits["insert"]
        self._evict_jit = jits["evict"]
        self.compile_keys: set = set()

        self.telemetry = telemetry if telemetry is not None \
            else Telemetry.disabled()
        self.stats = StatsView(
            self.telemetry.metrics,
            scalars={
                "admitted": "serve_admitted",
                "deferrals": "serve_deferrals",
                "rejected": "serve_rejected",
                "completed": "serve_completed",
                "prefill_chunks": "serve_prefill_chunks",
                "decode_batches": "serve_decode_batches",
                "decode_tokens": "serve_decode_tokens",
                "pool_grows": "serve_pool_grows",
                "admission_checks": "serve_admission_checks",
                "peak_predicted_bytes": "serve_peak_predicted_bytes",
                "peak_actual_bytes": "serve_peak_actual_bytes",
            },
            float_keys=("peak_predicted_bytes",))
        self._t0 = time.perf_counter()
        self._clock_skip = 0.0

    # -- geometry / prediction --------------------------------------------
    @staticmethod
    def _slot_tiers(max_slots: int) -> List[int]:
        tiers, t = [], 1
        while t < max_slots:
            tiers.append(t)
            t *= 2
        tiers.append(max_slots)
        return tiers

    def bucket_of(self, req: TraceRequest) -> int:
        return bucket_length(len(req.prompt) + req.max_new_tokens,
                             self.quantum)

    def slot_bytes(self, bucket: int) -> float:
        """Predicted per-slot cache bytes at ``bucket`` (estimator)."""
        return float(self.estimator.predict_total(bucket))

    def predicted_bytes(self) -> float:
        """The admission ledger: params + every pool + every staging
        cache + in-flight workspace, all via the estimator's per-slot
        prediction (never the allocated arrays — admission must work
        *before* allocating)."""
        total = float(self.param_bytes)
        for pool in self.pools.values():
            total += pool.slots * (self.slot_bytes(pool.bucket)
                                   + self._token_ws)
        for lv in self.prefilling:
            total += self.slot_bytes(lv.bucket)
            total += self.prefill_chunk * self._token_ws
        return total

    def actual_bytes(self) -> int:
        """Ground truth: bytes of the device state the engine holds."""
        total = self.param_bytes
        for pool in self.pools.values():
            total += pool.cache_bytes()
        for lv in self.prefilling:
            if lv.staging is not None:
                total += tree_device_bytes(lv.staging)
        return total

    def _note_bytes(self) -> None:
        self.stats["peak_predicted_bytes"] = max(
            self.stats["peak_predicted_bytes"], self.predicted_bytes())
        self.stats["peak_actual_bytes"] = max(
            self.stats["peak_actual_bytes"], self.actual_bytes())

    # -- admission ---------------------------------------------------------
    def _admit_cost(self, bucket: int) -> Optional[float]:
        """Predicted extra bytes of admitting one request at ``bucket``:
        staging row + chunk workspace + pool slot (tier growth included).
        None when the bucket has no free capacity at ``max_slots``."""
        cost = self.slot_bytes(bucket) + self.prefill_chunk * self._token_ws
        pool = self.pools.get(bucket)
        if pool is None:
            cost += self.tiers[0] * (self.slot_bytes(bucket)
                                     + self._token_ws)
        elif pool.free_slot() < 0:
            if pool.slots >= self.max_slots:
                return None
            new = next(t for t in self.tiers if t > pool.slots)
            cost += (new - pool.slots) * (self.slot_bytes(bucket)
                                          + self._token_ws)
        return cost

    def _grow_pool(self, bucket: int) -> BucketPool:
        pool = self.pools.get(bucket)
        if pool is None:
            pool = BucketPool(self.lm, bucket, self.tiers[0])
            self.pools[bucket] = pool
            self.compile_keys.add(("pool", bucket, pool.slots))
            return pool
        if pool.free_slot() >= 0:
            return pool
        new_slots = next(t for t in self.tiers if t > pool.slots)
        grown = BucketPool(self.lm, bucket, new_slots)
        self.compile_keys.add(("insert", bucket, pool.slots, new_slots))
        grown.cache = self._insert_jit(grown.cache, pool.cache, 0)
        grown.index[:pool.slots] = pool.index
        grown.last_tok[:pool.slots] = pool.last_tok
        grown.live[:pool.slots] = pool.live
        for lv in grown.live:
            if lv is not None:
                lv.pool = grown
        self.pools[bucket] = grown
        self.stats.inc("pool_grows")
        if self.telemetry.events_on:
            self.telemetry.events.emit("pool_grow", bucket=bucket,
                                       slots=new_slots)
        self.compile_keys.add(("pool", bucket, new_slots))
        return grown

    def _try_admit(self, lv: _Live, now: float) -> bool:
        tel = self.telemetry
        self.stats.inc("admission_checks")
        cost = self._admit_cost(lv.bucket)
        if cost is None or self.predicted_bytes() + cost > self.hbm_bytes:
            return False
        pool = self._grow_pool(lv.bucket)
        slot = pool.free_slot()
        assert slot >= 0, "admission grew the pool for this request"
        lv.staging = self.lm.init_cache(1, lv.bucket)
        pool.live[slot] = lv              # claim the slot up front —
        lv.pool, lv.slot = pool, slot     # parked (index == bucket)
        lv.t_admit = now                  # until prefill completes
        self.prefilling.append(lv)
        self.stats.inc("admitted")
        if tel.events_on:
            tel.events.emit("admit", rid=lv.req.rid, bucket=lv.bucket,
                            cost_bytes=float(cost),
                            predicted_bytes=self.predicted_bytes(),
                            wait_s=max(now - lv.arrival_s, 0.0))
        if tel.trace_on:
            wait = max(now - lv.arrival_s, 0.0)
            if wait > 0:
                # retroactive: the span covers the engine-clock interval
                # the request spent queued (arrival -> admission)
                tel.tracer.complete(
                    "queue_wait", time.perf_counter() - wait, wait,
                    TRACK_SERVE,
                    args={"rid": lv.req.rid, "bucket": lv.bucket})
        return True

    # -- prefill -----------------------------------------------------------
    def _next_chunk(self, remaining: int) -> int:
        """Largest power-of-two chunk <= remaining whose predicted
        workspace fits the headroom (admission charged the base chunk,
        so the smallest candidate always fits)."""
        head = self.hbm_bytes - (self.predicted_bytes()
                                 - self.prefill_chunk * self._token_ws)
        for c in reversed(self._chunks):
            if c <= remaining and c * self._token_ws <= head:
                return c
        return 1

    def _advance_prefill(self, lv: _Live, now: float) -> None:
        tel = self.telemetry
        S = len(lv.req.prompt)
        c = self._next_chunk(S - lv.pos)
        tok = jnp.asarray(lv.req.prompt[lv.pos:lv.pos + c][None, :])
        self.compile_keys.add(("prefill", lv.bucket, int(tok.shape[1])))
        with tel.tracer.span(
                "prefill_chunk", TRACK_SERVE,
                args={"rid": lv.req.rid, "bucket": lv.bucket,
                      "chunk": int(tok.shape[1])} if tel.trace_on else None):
            logits, lv.staging = self._prefill_jit(self.params, tok,
                                                   lv.staging, lv.pos)
        lv.pos += int(tok.shape[1])
        self.stats.inc("prefill_chunks")
        if lv.pos < S:
            return
        # prefill complete: first token comes from the prompt's last
        # logits (greedy), then the slot joins the pool's decode batch
        first = int(jnp.argmax(logits[0, -1]))
        pool, slot = lv.pool, lv.slot     # claimed at admission (and
        self.compile_keys.add(("insert", lv.bucket, 1, pool.slots))
        pool.cache = self._insert_jit(pool.cache, lv.staging, slot)
        pool.index[slot] = S              # re-pointed by pool growth)
        pool.last_tok[slot] = first
        lv.staging = None                 # row is now decoding
        lv.tokens.append(first)
        lv.token_times.append(now)
        self.prefilling.remove(lv)
        self._finish_if_done(lv, now)

    # -- decode ------------------------------------------------------------
    def _finish_if_done(self, lv: _Live, now: float) -> None:
        if len(lv.tokens) < lv.req.max_new_tokens:
            return
        pool, slot = lv.pool, lv.slot
        self.compile_keys.add(("evict", pool.bucket, pool.slots))
        pool.cache = self._evict_jit(pool.cache, slot)
        pool.index[slot] = pool.bucket          # park: writes drop
        pool.live[slot] = None
        lv.pool, lv.slot = None, -1
        lv.t_done = now
        self.done.append(lv)
        self.stats.inc("completed")
        if self.telemetry.events_on:
            self.telemetry.events.emit(
                "serve_complete", rid=lv.req.rid, bucket=pool.bucket,
                tokens=len(lv.tokens),
                latency_s=max(now - lv.arrival_s, 0.0))
        if pool.n_active() == 0 and not any(
                w.bucket == pool.bucket
                for w in self.waiting + self.prefilling):
            del self.pools[pool.bucket]         # release the HBM

    def _decode_pools(self, now: float) -> None:
        for pool in list(self.pools.values()):
            if pool.n_active() == 0:
                continue
            self.compile_keys.add(("decode", pool.bucket, pool.slots))
            tel = self.telemetry
            for _ in range(self.decode_steps):
                if pool.n_active() == 0:
                    break
                toks = jnp.asarray(pool.last_tok[:, None])
                idx = jnp.asarray(pool.index)
                with tel.tracer.span(
                        "decode_batch", TRACK_SERVE,
                        args={"bucket": pool.bucket,
                              "active": pool.n_active()}
                        if tel.trace_on else None):
                    nxt, pool.cache = self._decode_jit(self.params, toks,
                                                       pool.cache, idx)
                    nxt = np.asarray(nxt)
                t_emit = self._now()
                self.stats.inc("decode_batches")
                for s, lv in enumerate(pool.live):
                    if lv is None or lv.staging is not None:
                        continue    # empty, or reserved + still prefilling
                    pool.index[s] += 1
                    pool.last_tok[s] = int(nxt[s])
                    lv.tokens.append(int(nxt[s]))
                    lv.token_times.append(t_emit)
                    self.stats.inc("decode_tokens")
                    self._finish_if_done(lv, t_emit)

    # -- scheduler loop ----------------------------------------------------
    def _now(self) -> float:
        return time.perf_counter() - self._t0 + self._clock_skip

    def run(self, trace: Sequence[TraceRequest]) -> "ServeResult":
        """Serve an open-loop trace to completion and report."""
        pending = sorted(trace, key=lambda r: (r.arrival_s, r.rid))
        self._t0 = time.perf_counter()
        self._clock_skip = 0.0
        wall0 = time.perf_counter()
        while pending or self.waiting or self.prefilling or any(
                p.n_active() for p in self.pools.values()):
            now = self._now()
            while pending and pending[0].arrival_s <= now:
                req = pending.pop(0)
                self.waiting.append(_Live(req=req,
                                          bucket=self.bucket_of(req),
                                          arrival_s=req.arrival_s,
                                          t_admit=0.0))
            # FIFO admission: defer what the prediction says won't fit
            still: List[_Live] = []
            for lv in self.waiting:
                if not self._try_admit(lv, now):
                    if lv.pool is None:
                        self.stats.inc("deferrals")
                        if self.telemetry.events_on:
                            self.telemetry.events.emit(
                                "defer", rid=lv.req.rid, bucket=lv.bucket,
                                predicted_bytes=self.predicted_bytes())
                    still.append(lv)
            self.waiting = still
            for lv in list(self.prefilling):
                self._advance_prefill(lv, self._now())
            self._decode_pools(self._now())
            self._note_bytes()
            if (not self.prefilling and not any(
                    p.n_active() for p in self.pools.values())):
                if self.waiting:
                    # nothing in flight and the head still doesn't fit:
                    # it never will — reject instead of spinning/OOMing
                    lv = self.waiting.pop(0)
                    self.rejected.append(lv)
                    self.stats.inc("rejected")
                    if self.telemetry.events_on:
                        self.telemetry.events.emit(
                            "reject", rid=lv.req.rid, bucket=lv.bucket,
                            predicted_bytes=self.predicted_bytes(),
                            hbm_bytes=self.hbm_bytes)
                elif pending:
                    # idle until the next arrival: fast-forward
                    gap = pending[0].arrival_s - self._now()
                    if gap > 0:
                        self._clock_skip += gap
        return ServeResult.collect(self, time.perf_counter() - wall0)


@dataclasses.dataclass
class ServeResult:
    """Summary of one ``ServeEngine.run``."""
    wall_s: float
    completed: int
    rejected: int
    total_tokens: int
    tokens_per_s: float
    ttft_p50_s: float
    ttft_p99_s: float
    itl_p50_s: float
    itl_p99_s: float
    stats: dict
    outputs: Dict[int, List[int]]
    compile_counts: Dict[str, int]

    @classmethod
    def collect(cls, eng: ServeEngine, wall: float) -> "ServeResult":
        ttft, itl, total = [], [], 0
        outputs: Dict[int, List[int]] = {}
        for lv in eng.done:
            outputs[lv.req.rid] = list(lv.tokens)
            total += len(lv.tokens)
            if lv.token_times:
                ttft.append(lv.token_times[0] - lv.arrival_s)
                itl.extend(np.diff(lv.token_times).tolist())
        kinds: Dict[str, int] = {}
        for key in eng.compile_keys:
            kinds[key[0]] = kinds.get(key[0], 0) + 1
        return cls(
            wall_s=wall, completed=len(eng.done), rejected=len(eng.rejected),
            total_tokens=total,
            tokens_per_s=total / wall if wall > 0 else 0.0,
            ttft_p50_s=_percentile(ttft, 50), ttft_p99_s=_percentile(ttft, 99),
            itl_p50_s=_percentile(itl, 50), itl_p99_s=_percentile(itl, 99),
            stats=dict(eng.stats), outputs=outputs, compile_counts=kinds)

    def summary(self) -> dict:
        return {
            "wall_s": round(self.wall_s, 4),
            "completed": self.completed,
            "rejected": self.rejected,
            "total_tokens": self.total_tokens,
            "tokens_per_s": round(self.tokens_per_s, 1),
            "ttft_p50_ms": round(self.ttft_p50_s * 1e3, 2),
            "ttft_p99_ms": round(self.ttft_p99_s * 1e3, 2),
            "itl_p50_ms": round(self.itl_p50_s * 1e3, 3),
            "itl_p99_ms": round(self.itl_p99_s * 1e3, 3),
            "admitted": self.stats["admitted"],
            "deferrals": self.stats["deferrals"],
            "pool_grows": self.stats["pool_grows"],
            "decode_batches": self.stats["decode_batches"],
            "peak_predicted_mb": round(
                self.stats["peak_predicted_bytes"] / 1e6, 3),
            "peak_actual_mb": round(
                self.stats["peak_actual_bytes"] / 1e6, 3),
            "compile_counts": dict(self.compile_counts),
        }
