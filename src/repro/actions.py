"""Typed per-unit plan actions — the planner/executor contract.

A Mimose plan historically was a boolean remat mask: every plan unit is
either KEPT (its residuals stay in HBM) or REMATERIALISED (residuals
dropped in the forward pass and recomputed in the backward).  Growing
the system past a single reclamation mechanism (MONeT/DTR: jointly
optimising *across* mechanisms beats any single one) needs a richer
vocabulary, so a plan is now a tuple of ``Action`` values:

* ``KEEP``    — save the unit's residuals on device (the old ``False``);
* ``REMAT``   — drop and recompute (the old ``True``), cost = the unit's
  forward FLOPs at the roofline compute bound;
* ``OFFLOAD`` — stream the unit's residuals to pinned host memory during
  the forward pass and fetch them back for the backward, cost = 2 x
  offloaded bytes over the PCIe link (partially overlappable with
  compute).
* ``OFFLOAD_OPT`` — park the unit's *optimizer moments* (fp32 AdamW
  m + v) in pinned host memory, ZeRO-Offload style.  Residual liveness
  is identical to KEEP; what shrinks is the FIXED footprint (the
  resident optimizer shard), so this action reaches budgets no
  residual-side action can.  Cost = one round trip of the moment bytes
  per step (the update reads and rewrites them), NOT scaled by the
  microbatch split — the optimizer runs once per step.

``Action`` is an ``IntEnum`` with ``KEEP == 0`` and ``REMAT == 1`` on
purpose: a plain bool mask converts value-exactly (``True -> REMAT``),
so every pre-action call site — and any serialized mask — keeps working
through ``as_actions``.  This module is intentionally dependency-free
(stdlib only): it is imported by both ``repro.core`` and
``repro.models``, which must not import each other at module scope.

Future actions (quantized save, recompute-from-offload) extend the enum
without another representation change.
"""
from __future__ import annotations

import enum
from typing import Iterable, Tuple


class Action(enum.IntEnum):
    """What to do with one plan unit's saved residuals (and, for
    ``OFFLOAD_OPT``, its optimizer-state shard)."""
    KEEP = 0
    REMAT = 1
    OFFLOAD = 2
    OFFLOAD_OPT = 3


def as_actions(mask: Iterable) -> Tuple[Action, ...]:
    """Normalise a plan to a tuple of ``Action``.

    Accepts the legacy boolean remat mask (``True -> REMAT``,
    ``False -> KEEP``), raw ints, or ``Action`` values — mixed freely.
    This is the single conversion every consumer (model, trainer,
    simulator, scheduler) delegates to, so bool and typed plans can
    never diverge in meaning.
    """
    return tuple(Action(int(m)) for m in mask)
