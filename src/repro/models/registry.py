"""Architecture registry: ``--arch <id>`` -> (ModelConfig, LM builder)."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.config import ModelConfig
from repro.models.lm import LM, build_model

ARCH_IDS = [
    "mamba2_1p3b",
    "seamless_m4t_large_v2",
    "granite_moe_1b_a400m",
    "gemma3_12b",
    "yi_9b",
    "stablelm_3b",
    "qwen2_vl_7b",
    "qwen3_1p7b",
    "hymba_1p5b",
    "kimi_k2_1t_a32b",
    # the paper's own evaluation model (Bert-base scale, encoder-style stack)
    "bert_base_paper",
]

# accept the dashed public names too
ALIASES = {
    "mamba2-1.3b": "mamba2_1p3b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "gemma3-12b": "gemma3_12b",
    "yi-9b": "yi_9b",
    "stablelm-3b": "stablelm_3b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "qwen3-1.7b": "qwen3_1p7b",
    "hymba-1.5b": "hymba_1p5b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
}


def canonical(arch: str) -> str:
    return ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))


def get_config(arch: str) -> ModelConfig:
    name = canonical(arch)
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def get_model(arch: str, attn_impl: str = "xla") -> LM:
    return build_model(get_config(arch), attn_impl=attn_impl)


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
