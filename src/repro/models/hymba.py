"""Hymba-style hybrid block: parallel attention + SSM heads  [arXiv:2411.13676].

The input projection feeds both an attention path and a Mamba2/SSD path in
parallel within the same layer; their (normalised) outputs are averaged
before the residual add.  We implement the two paths with the shared
attention / mamba2 modules and a learned per-path output scale, which is
the TPU-friendly simplification of Hymba's per-head fusion (noted in
DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M

Array = jax.Array


def hymba_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    ka, km = jax.random.split(key)
    return {
        "attn": L.attention_init(ka, cfg, dtype),
        "ssm": M.mamba2_init(km, cfg, dtype),
        "attn_scale": jnp.ones((), jnp.float32),
        "ssm_scale": jnp.ones((), jnp.float32),
    }


def hymba_apply(params: dict, cfg: ModelConfig, x: Array, *,
                positions: Array, layer_is_global=False,
                kv_cache=None, cache_index=None,
                ssm_state=None, conv_state=None,
                decode: bool = False, impl: str = "xla",
                seq_lens=None):
    """Returns (out, new_kv_cache, (new_ssm_state, new_conv_state)).

    ``seq_lens``: optional (B,) true lengths of a bucket-padded batch,
    threaded into both the attention (key mask) and SSD (state mask)
    paths."""
    attn_out, new_kv = L.attention_apply(
        params["attn"], cfg, x, positions=positions,
        layer_is_global=layer_is_global, kv_cache=kv_cache,
        cache_index=cache_index, impl=impl, kv_len=seq_lens)
    ssm_out, (new_ssm, new_conv) = M.mamba2_apply(
        params["ssm"], cfg, x, ssm_state=ssm_state, conv_state=conv_state,
        decode=decode, seq_lens=seq_lens)
    out = (params["attn_scale"] * attn_out.astype(jnp.float32)
           + params["ssm_scale"] * ssm_out.astype(jnp.float32)) * 0.5
    return out.astype(x.dtype), new_kv, (new_ssm, new_conv)
