from repro.models.lm import LM, build_model, PlanUnit, block_apply, block_init  # noqa: F401
