"""Model zoo: the LM shell plus per-family block implementations
(dense / MoE / SSM / hybrid / encoder-decoder)."""
from repro.models.lm import LM, build_model, PlanUnit, block_apply, block_init  # noqa: F401
