"""Token-choice top-k Mixture-of-Experts block (GShard-style dispatch).

Used by granite-moe-1b-a400m (32e top-8) and kimi-k2 (384e top-8).

The default implementation is the capacity-based one-hot dispatch/combine
einsum formulation: it is fully dense, shards cleanly with experts on the
'model' mesh axis and tokens on the 'data' axis, and lowers to all-to-all
free einsums that the XLA SPMD partitioner turns into the canonical
expert-parallel collective schedule.  A ragged-dot variant is provided as
a beyond-paper perf alternative (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init, mlp_init, mlp_apply

Array = jax.Array


def moe_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(kr, d, E, jnp.float32),
        # expert weights stacked on a leading expert axis
        "wi": (jax.random.normal(k1, (E, d, ff)) * scale).astype(dtype),
        "wg": (jax.random.normal(k2, (E, d, ff)) * scale).astype(dtype),
        "wo": (jax.random.normal(k3, (E, ff, d)) / math.sqrt(ff)).astype(dtype),
    }
    if cfg.shared_expert_d_ff:
        p["shared"] = mlp_init(ks, d, cfg.shared_expert_d_ff, cfg.mlp_act, dtype)
    return p


def _capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    cap = int(math.ceil(cfg.experts_per_token * tokens_per_group
                        / cfg.num_experts * cfg.moe_capacity_factor))
    return max(cap, cfg.experts_per_token)


def _group_size(cfg: ModelConfig, S: int) -> int:
    """Largest divisor of S not exceeding cfg.moe_group_size.

    Grouped dispatch keeps the (G, g, E, C) one-hot tensors linear in the
    token count (C scales with the *group* size, not the global batch) —
    without grouping the combine tensor is O(T^2) and blows past HBM at
    train_4k scale."""
    g = min(cfg.moe_group_size, S)
    while S % g:
        g -= 1
    return g


def moe_apply(params: dict, cfg: ModelConfig, x: Array) -> Tuple[Array, Array]:
    """x: (B, S, d) -> (out, aux_loss).  GShard grouped top-k dispatch."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    g = _group_size(cfg, S)
    G = B * (S // g)                         # dispatch groups
    xg = x.reshape(G, g, d)

    logits = jnp.einsum("Ggd,de->Gge", xg.astype(jnp.float32),
                        params["router"])                           # (G, g, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)                 # (G, g, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balance auxiliary loss (Switch-style), over all tokens
    me = jnp.mean(probs, axis=(0, 1))                               # (E,)
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)       # (G, g, K, E)
    ce = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))             # (E,)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # ---- capacity-based dispatch within each group -----------------------
    C = _capacity(cfg, g)
    # position of each (token, k) within its expert's per-group buffer
    flat = onehot.reshape(G, g * K, E)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(G, g, K, E)
    pos = jnp.einsum("GgkE,GgkE->Ggk", pos, onehot)                 # (G, g, K)
    keep = pos < C
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)
    pos = jnp.minimum(pos, C - 1).astype(jnp.int32)

    combine = (gate_vals[..., None, None]
               * onehot[..., None]
               * jax.nn.one_hot(pos, C, dtype=jnp.float32)[..., None, :])
    combine = jnp.sum(combine, axis=2)                              # (G, g, E, C)
    dispatch = (combine > 0).astype(x.dtype)

    expert_in = jnp.einsum("GgEC,Ggd->EGCd", dispatch, xg)          # (E, G, C, d)
    h = (jax.nn.silu(jnp.einsum("EGCd,Edf->EGCf", expert_in, params["wg"]))
         * jnp.einsum("EGCd,Edf->EGCf", expert_in, params["wi"]))
    expert_out = jnp.einsum("EGCf,Efd->EGCd", h, params["wo"])      # (E, G, C, d)
    out = jnp.einsum("GgEC,EGCd->Ggd", combine.astype(x.dtype), expert_out)

    if "shared" in params:
        out = out + mlp_apply(params["shared"], xg, cfg.mlp_act)
    return out.reshape(B, S, d), aux
