"""Core neural-net layers shared by all model families.

Pure-JAX (no flax): parameters are nested dicts of ``jnp.ndarray``;
every layer is an ``init(key, cfg, ...) -> params`` plus a pure
``apply(params, x, ...) -> y`` pair.  All shapes follow
``(batch, seq, d_model)``.

Attention supports:
  * grouped-query attention (num_kv_heads <= num_heads)
  * RoPE and multimodal M-RoPE (qwen2-vl style 3-section rotary)
  * causal, sliding-window, and per-layer local/global masks (gemma3)
  * qk-norm (qwen3)
  * an optional Pallas flash-attention implementation (``impl='flash'``)
    whose custom VJP saves only O(seq) residuals -- this is what the
    Mimose collector observes as a linear memory curve.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# initialisation helpers
# ---------------------------------------------------------------------------

def dense_init(key: Array, d_in: int, d_out: int, dtype) -> Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key: Array, vocab: int, d: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm_apply(params: dict, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions: Array, theta: float,
                sections: tuple) -> Array:
    """Multimodal RoPE (qwen2-vl).  positions: (3, B, S) for (t, h, w).

    The head_dim/2 frequency slots are split into ``sections`` groups,
    each rotated by its own positional stream.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    # build per-slot position: slot j uses stream according to its section
    sec = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)
    ])                                                   # (hd/2,)
    sec = sec[: hd // 2]
    # pos_per_slot: (B, S, hd/2)
    pos = jnp.take_along_axis(
        positions.transpose(1, 2, 0).astype(jnp.float32),       # (B, S, 3)
        jnp.broadcast_to(sec[None, None, :], positions.shape[1:] + (hd // 2,)).astype(jnp.int32) % 3,
        axis=-1,
    )
    angles = pos * freqs                                 # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim()
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, d, cfg.num_heads * hd, dtype),
        "wk": dense_init(kk, d, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(kv, d, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(ko, cfg.num_heads * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _build_mask(q_pos: Array, k_pos: Array, window, is_global) -> Array:
    """(..., Sq, Sk) boolean mask.  window: python int or traced scalar;
    is_global: bool scalar (python or traced) -- global layers ignore window."""
    causal = q_pos[..., :, None] >= k_pos[..., None, :]
    if window is None or (isinstance(window, int) and window <= 0):
        return causal
    in_window = (q_pos[..., :, None] - k_pos[..., None, :]) < window
    if isinstance(is_global, bool):
        return causal if is_global else (causal & in_window)
    # traced per-layer flag (scan over gemma3 local/global pattern)
    return causal & (is_global | in_window)


def sdpa_banded_local(q: Array, k: Array, v: Array, window: int) -> Array:
    """Sliding-window attention with O(S * 2W) score tiles (vs O(S^2)).

    q, k, v: (B, S, H|Hkv, hd) with S % window == 0 and S >= 2 * window.
    Each query block of W tokens attends to its own block and the previous
    one — exactly the causal sliding-window mask, but the masked-out
    far-past columns are never materialised.  This is the XLA-native
    counterpart of the Pallas flash kernel's banding (EXPERIMENTS.md §Perf).
    """
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    W = window
    nb = S // W
    qb = q.reshape(B, nb, W, Hkv, group, hd)
    kb = k.reshape(B, nb, W, Hkv, hd)
    vb = v.reshape(B, nb, W, Hkv, hd)
    # previous block (zeros before block 0)
    kprev = jnp.pad(kb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :nb]
    vprev = jnp.pad(vb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :nb]
    k2 = jnp.concatenate([kprev, kb], axis=2)          # (B, nb, 2W, Hkv, hd)
    v2 = jnp.concatenate([vprev, vb], axis=2)
    logits = jnp.einsum("bnqkgh,bnskh->bnkgqs", qb, k2,
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    # in-band mask: query pos a (0..W), key pos b-W relative to block start
    a = jnp.arange(W)[:, None]
    b = jnp.arange(2 * W)[None, :] - W
    mask = (a >= b) & ((a - b) < W)                    # causal + window
    first = jnp.arange(2 * W)[None, :] >= W            # block 0: no prev
    mask0 = mask & first
    m = jnp.where(jnp.arange(nb)[:, None, None] == 0, mask0[None], mask[None])
    logits = jnp.where(m[None, :, None, None], logits,
                       jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bnkgqs,bnskh->bnqkgh", probs.astype(v.dtype), v2)
    return out.reshape(B, S, H, hd)


def sdpa_reference(q: Array, k: Array, v: Array, mask: Array) -> Array:
    """Plain XLA attention with GQA. q:(B,Sq,H,hd) k,v:(B,Sk,Hkv,hd)
    mask: broadcastable to (B,1,Sq,Sk) boolean."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    q_ = q.reshape(B, Sq, Hkv, group, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q_, k,
                        preferred_element_type=jnp.float32)
    logits = logits / math.sqrt(hd)
    m = mask[:, None, None, :, :] if mask.ndim == 3 else mask[None, None, None, :, :]
    logits = jnp.where(m, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, hd)


def attention_apply(params: dict, cfg: ModelConfig, x: Array, *,
                    positions: Array,
                    layer_is_global=True,
                    kv_cache: Optional[dict] = None,
                    cache_index: Optional[Array] = None,
                    impl: str = "xla",
                    mrope_positions: Optional[Array] = None,
                    cross_kv: Optional[tuple] = None,
                    causal: bool = True,
                    kv_len: Optional[Array] = None):
    """Returns (out, new_kv_cache).

    * training / prefill: kv_cache is None -> full self attention.
    * decode: kv_cache = {'k': (B,Smax,Hkv,hd), 'v': ...}, cache_index is the
      current length; x has Sq==1.
    * cross attention: cross_kv = (k, v) precomputed from the encoder.
    * ragged training: kv_len = (B,) int32 true lengths of a bucket-padded
      batch — padded keys are masked out of self attention (and skipped
      blockwise by the flash kernel), so per-sequence work tracks the
      effective tokens while shapes stay bucket-static.
    """
    B, Sq, _ = x.shape
    hd = cfg.resolved_head_dim()
    q = (x @ params["wq"]).reshape(B, Sq, cfg.num_heads, hd)
    if cross_kv is None:
        k = (x @ params["wk"]).reshape(B, Sq, cfg.num_kv_heads, hd)
        v = (x @ params["wv"]).reshape(B, Sq, cfg.num_kv_heads, hd)
    else:
        k, v = cross_kv

    if cfg.qk_norm:
        q = rmsnorm_apply(params["q_norm"], q, cfg.norm_eps)
        if cross_kv is None:
            k = rmsnorm_apply(params["k_norm"], k, cfg.norm_eps)

    if cross_kv is None:
        if cfg.mrope and mrope_positions is not None:
            q = apply_mrope(q, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        # decode / chunked prefill: insert the Sq new k/v rows at
        # cache_index (Sq == 1 for token decode, a whole block for
        # chunked prefill — same compiled shape family either way).
        # A (B,) cache_index is the continuous-batching serve path:
        # every batch row is a different request at its own position,
        # inserted by one scatter at static shapes.  Out-of-range
        # indices drop the write — the engine parks empty slots at
        # index == cache length so they never touch the cache.
        ck, cv = kv_cache["k"], kv_cache["v"]
        if getattr(cache_index, "ndim", 0) >= 1:
            rows = jnp.arange(B)[:, None]
            cols = cache_index[:, None] + jnp.arange(Sq)[None, :]
            ck = ck.at[rows, cols].set(k.astype(ck.dtype), mode="drop")
            cv = cv.at[rows, cols].set(v.astype(cv.dtype), mode="drop")
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_index, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_index, axis=1)
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        Sk = k.shape[1]
        k_pos = jnp.arange(Sk)[None, :]
        q_pos = positions                                  # (B, Sq)
        # per-query "keys written so far": cache slots past each query's
        # own position hold garbage (future chunk rows / zeros)
        valid = k_pos[None, :, :] <= q_pos[..., :, None]    # (B, Sq, Sk)
        mask = _build_mask(q_pos, jnp.broadcast_to(k_pos, (B, Sk)),
                           cfg.sliding_window, layer_is_global) & valid
    elif cross_kv is not None or not causal:
        Sk = k.shape[1]
        mask = jnp.ones((B, Sq, Sk), dtype=bool)
        if kv_len is not None and cross_kv is None:
            # bidirectional self attention: padded keys pollute every
            # valid query, so the length mask is load-bearing here
            mask = mask & (jnp.arange(Sk)[None, :] < kv_len[:, None])[:, None, :]
    else:
        mask = _build_mask(positions, positions, cfg.sliding_window, layer_is_global)
        if kv_len is not None:
            Sk = k.shape[1]
            key_valid = (jnp.arange(Sk)[None, :] < kv_len[:, None])  # (B, Sk)
            if mask.ndim == 2:
                mask = mask[None]
            mask = mask & key_valid[:, None, :]

    W = cfg.sliding_window
    is_local = (isinstance(layer_is_global, bool) and not layer_is_global
                and W > 0)
    if impl == "flash" and kv_cache is None and cross_kv is None and causal:
        from repro.kernels import ops as kernel_ops
        out = kernel_ops.flash_attention(q, k, v, kv_len, causal=True,
                                         window=W if is_local else 0)
    elif (is_local and kv_cache is None and cross_kv is None and causal
          and Sq % W == 0 and Sq >= 2 * W):
        # taken with or without kv_len: the band is causal, so a valid
        # query (pos < length) only ever attends keys at its own or
        # earlier positions — all valid, because padding is a suffix.
        # Padded-position outputs are garbage either way and carry zero
        # loss weight (and zero incoming gradient), so the length mask
        # adds nothing here and the O(S*2W) path stays live.
        out = sdpa_banded_local(q, k, v, W)    # O(S*2W) instead of O(S^2)
    else:
        out = sdpa_reference(q, k, v, mask)

    out = out.reshape(B, Sq, cfg.num_heads * hd) @ params["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key: Array, d: int, ff: int, act: str, dtype) -> dict:
    if act == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {"wi": dense_init(k1, d, ff, dtype),
                "wg": dense_init(k2, d, ff, dtype),
                "wo": dense_init(k3, ff, d, dtype)}
    k1, k2 = jax.random.split(key, 2)
    return {"wi": dense_init(k1, d, ff, dtype),
            "wo": dense_init(k2, ff, d, dtype)}


def mlp_apply(params: dict, x: Array, act: str) -> Array:
    if act == "swiglu":
        h = jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])
    elif act == "gelu":
        h = jax.nn.gelu(x @ params["wi"])
    else:
        h = jax.nn.relu(x @ params["wi"])
    return h @ params["wo"]
