"""Mamba2 / SSD (state-space duality) mixer  [arXiv:2405.21060].

Chunked "SSD" algorithm in pure JAX for the model forward (training /
prefill) plus a constant-memory single-token ``ssd_step`` for decode.
A Pallas TPU kernel for the chunk scan lives in ``repro.kernels.ssd_scan``
and is validated against ``repro.kernels.ref.ssd_reference``.

Layout conventions:
    x   : (B, S, H, P)   per-head channels
    dt  : (B, S, H)      softplus-discretised step sizes
    A   : (H,)           negative decay rates
    B,C : (B, S, N)      shared across heads (G = 1 group)
    state: (B, H, P, N)
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init, rmsnorm_init, rmsnorm_apply

Array = jax.Array


# ---------------------------------------------------------------------------
# SSD chunked scan (pure jnp)
# ---------------------------------------------------------------------------

def ssd_chunked(x: Array, dt: Array, A: Array, B: Array, C: Array,
                chunk: int, initial_state: Array | None = None
                ) -> Tuple[Array, Array]:
    """Returns (y, final_state).  Shapes as in the module docstring."""
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    if S % chunk:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    Nc, Q = Sp // chunk, chunk

    xc = x.reshape(Bt, Nc, Q, H, P)
    dtc = dt.reshape(Bt, Nc, Q, H).astype(jnp.float32)
    Bc = B.reshape(Bt, Nc, Q, N).astype(jnp.float32)
    Cc = C.reshape(Bt, Nc, Q, N).astype(jnp.float32)

    dA = dtc * A.astype(jnp.float32)                     # (B,Nc,Q,H) log-decay
    la = jnp.cumsum(dA, axis=2)                          # within-chunk cumlog

    # intra-chunk (diagonal) term:
    #   L[i,j] = exp(la_i - la_j) for i >= j
    rel = la[:, :, :, None, :] - la[:, :, None, :, :]    # (B,Nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), dtype=bool))
    # mask BEFORE exp: exp of the (large positive) future entries would be
    # inf and poison the where() gradient with NaNs.
    L = jnp.exp(jnp.where(causal[None, None, :, :, None], rel, -jnp.inf))
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)           # (B,Nc,Q,Q)
    w = cb[..., None] * L * dtc[:, :, None, :, :]        # (B,Nc,Q,Q,H)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", w, xc.astype(jnp.float32))

    # chunk summary states: state contribution of each chunk
    decay_to_end = jnp.exp(la[:, :, -1:, :] - la)        # (B,Nc,Q,H)
    bx = jnp.einsum("bcjn,bcjh,bcjhp->bchpn",
                    Bc, decay_to_end * dtc, xc.astype(jnp.float32))

    chunk_decay = jnp.exp(la[:, :, -1, :])               # (B,Nc,H)

    def scan_fn(state, inp):
        cdecay, cstate = inp                              # (B,H), (B,H,P,N)
        new = state * cdecay[:, :, None, None] + cstate
        return new, state                                 # emit state *before* chunk

    init = (jnp.zeros((Bt, H, P, N), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))
    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (chunk_decay.transpose(1, 0, 2), bx.transpose(1, 0, 2, 3, 4)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (B,Nc,H,P,N)

    # inter-chunk (off-diagonal) term
    decay_from_start = jnp.exp(la)                        # (B,Nc,Q,H)
    y_off = jnp.einsum("bcin,bcih,bchpn->bcihp",
                       Cc, decay_from_start, prev_states)

    y = (y_diag + y_off).reshape(Bt, Sp, H, P)[:, :S]
    return y.astype(x.dtype), final_state


def ssd_step(state: Array, x_t: Array, dt_t: Array, A: Array,
             B_t: Array, C_t: Array) -> Tuple[Array, Array]:
    """One decode step.  state:(B,H,P,N) x_t:(B,H,P) dt_t:(B,H) B_t,C_t:(B,N)."""
    dA = jnp.exp(dt_t.astype(jnp.float32) * A.astype(jnp.float32))   # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt_t.astype(jnp.float32),
                     x_t.astype(jnp.float32), B_t.astype(jnp.float32))
    new_state = state * dA[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C_t.astype(jnp.float32))
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# full mixer (in_proj -> conv -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------

def mamba2_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * N
    return d_inner, H, N, conv_dim


def mamba2_init(key: Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    d_inner, H, N, conv_dim = mamba2_dims(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    proj_out = 2 * d_inner + 2 * N + H           # z, x, B, C, dt
    return {
        "in_proj": dense_init(k1, d, proj_out, dtype),
        "conv_w": (jax.random.normal(k2, (cfg.conv_kernel, conv_dim)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": rmsnorm_init(d_inner, dtype),
        "out_proj": dense_init(k4, d_inner, d, dtype),
    }


def _causal_conv(seq: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv.  seq:(B,S,C) w:(K,C)."""
    K = w.shape[0]
    pad = jnp.pad(seq, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + seq.shape[1], :] * w[i] for i in range(K))
    return out + b


def mamba2_apply(params: dict, cfg: ModelConfig, u: Array,
                 ssm_state: Array | None = None,
                 conv_state: Array | None = None,
                 decode: bool = False,
                 seq_lens: Array | None = None):
    """u: (B, S, d_model).  Returns (out, (ssm_state, conv_state)).

    ``seq_lens``: optional (B,) int32 true lengths of a bucket-padded
    batch — dt is zeroed past each sequence's length, so padding never
    enters the recurrent state (decay exp(0)=1, update dt*x*B = 0).
    """
    Bt, S, d = u.shape
    d_inner, H, N, conv_dim = mamba2_dims(cfg)

    zxbcdt = u @ params["in_proj"]
    z, xBC, dt_raw = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)

    if decode:
        # conv_state: (B, K-1, conv_dim) rolling buffer of past inputs.
        # Works for any S >= 1 (S == 1: token decode; S > 1: chunked
        # prefill advancing the cache a block at a time): the causal
        # conv windows slide over [conv_state, new inputs] and the
        # buffer keeps the last K-1 rows.
        full = jnp.concatenate([conv_state, xBC], axis=1)   # (B, K-1+S, C)
        new_conv_state = full[:, S:]
        K = cfg.conv_kernel
        xBC = sum(full[:, i:i + S, :] * params["conv_w"][i]
                  for i in range(K)) + params["conv_b"]
    else:
        new_conv_state = None
        xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"])
    xBC = jax.nn.silu(xBC)

    x, B_, C_ = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    x = x.reshape(Bt, -1, H, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    if seq_lens is not None and not decode:
        valid = jnp.arange(S)[None, :, None] < seq_lens[:, None, None]
        dt = jnp.where(valid, dt, 0.0)
    A = -jnp.exp(params["A_log"])

    if decode:
        if x.shape[1] == 1:
            y, new_ssm = ssd_step(ssm_state, x[:, 0], dt[:, 0], A,
                                  B_[:, 0], C_[:, 0])
            y = y[:, None]
        else:
            # chunked prefill: run the chunked scan from the cached
            # state (bitwise state semantics match repeated ssd_step)
            y, new_ssm = ssd_chunked(x, dt, A, B_, C_, cfg.ssm_chunk,
                                     initial_state=ssm_state)
    else:
        y, new_ssm = ssd_chunked(x, dt, A, B_, C_, cfg.ssm_chunk,
                                 initial_state=ssm_state)

    y = y + params["D"].astype(y.dtype)[None, None, :, None] * x
    y = y.reshape(Bt, -1, d_inner)
    y = rmsnorm_apply(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"]
    return out, (new_ssm, new_conv_state)
