"""The shared language-model shell for every assigned architecture.

A model is: embedding -> N plannable blocks -> final norm -> lm head.
Families differ only in what a block contains (attention+MLP, MoE, SSD
mixer, hybrid, encoder/decoder).  The Mimose planner sees the model as an
ordered list of *plan units* (= blocks in ``unrolled`` mode, layer-chunks
in ``scan`` mode) and decides which units to rematerialise.

Public surface:
    lm = LM(cfg, attn_impl="xla")
    params = lm.init(key)
    logits, aux = lm.forward(params, batch, remat_mask)
    loss, metrics = lm.loss(params, batch, remat_mask)
    cache = lm.init_cache(batch_size, max_len, dtype)
    logits, cache = lm.decode_step(params, tokens, cache, index)
    units = lm.plan_units(params, batch)   # for the Mimose collector
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.actions import Action, as_actions
from repro.config import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import moe as MOE
from repro.models import hymba as HY

Array = jax.Array

# the checkpoint_name tag the OFFLOAD action pins to host memory: the
# unit's residual-stream input (its recompute checkpoint).  Applying
# OFFLOAD moves this named tensor to pinned_host instead of keeping it
# in HBM — the jax-realisable form of activation offload (the planner's
# cost model prices the residual traffic; see docs/ARCHITECTURE.md
# "Hybrid remat+offload plans").
OFFLOAD_RESIDUAL_NAME = "mimose_offload_resid"


def host_offload_policy():
    """``jax.checkpoint`` policy offloading the named residual-stream
    checkpoint to pinned host memory.  Returns ``None`` (plain
    save-nothing remat) on jaxlib builds without offload support, so an
    OFFLOAD plan still executes correctly everywhere."""
    try:
        return jax.checkpoint_policies.save_and_offload_only_these_names(
            names_which_can_be_saved=[],
            names_which_can_be_offloaded=[OFFLOAD_RESIDUAL_NAME],
            offload_src="device", offload_dst="pinned_host")
    except (AttributeError, TypeError):
        return None


def _offload_unit(fn):
    """Wrap a pure ``fn(params, x, ...)`` unit so its input checkpoint is
    tagged for host offload, then checkpoint it under the offload
    policy.  Under an outer jit (the trainer's step) the checkpoint is
    used as-is; in eager execution it is additionally jit-wrapped,
    because the host transfer (``TransferToMemoryKind``) is only legal
    under jit — eager OFFLOAD replays therefore pay a per-call trace,
    which is fine for the tests/debugging that path serves."""
    def tagged(p, x, *rest):
        return fn(p, checkpoint_name(x, OFFLOAD_RESIDUAL_NAME), *rest)
    ckpt = jax.checkpoint(tagged, policy=host_offload_policy())
    if jax.core.trace_state_clean():
        return jax.jit(ckpt)
    return ckpt


# ---------------------------------------------------------------------------
# SPMD offload capability probe
#
# Older launch paths degraded EVERY multi-device mesh to offload_exec =
# False because some XLA builds cannot shard the host-offload
# custom-calls.  That threw the offload axis away on runtimes that CAN
# shard them.  The probe below compiles a minimal offloaded grad under
# the actual mesh once (cached per mesh signature) and only falls back
# where the compile genuinely fails — with a single warning per mesh so
# the degradation is never silent (the planner keeps emitting typed
# OFFLOAD actions either way; execution just prices them as remat).
# ---------------------------------------------------------------------------

_spmd_offload_cache: Dict[tuple, bool] = {}
_spmd_offload_warned: set = set()


def _mesh_probe_sig(mesh) -> tuple:
    d = mesh.devices
    return (tuple(mesh.axis_names), tuple(int(s) for s in d.shape),
            str(getattr(d.flat[0], "platform", "cpu")))


def spmd_offload_supported(mesh=None) -> bool:
    """True when OFFLOAD actions can execute as real host offload under
    ``mesh``.  Single device (or no mesh): just needs the offload
    policy.  SPMD: try-compiling a tiny offloaded grad under the mesh
    answers for this exact (jaxlib, backend, mesh-shape) combination."""
    if host_offload_policy() is None:
        return False
    if mesh is None or int(mesh.devices.size) <= 1:
        return True
    sig = _mesh_probe_sig(mesh)
    hit = _spmd_offload_cache.get(sig)
    if hit is not None:
        return hit
    try:
        from jax.sharding import NamedSharding, PartitionSpec

        def unit(y):
            y = checkpoint_name(y, OFFLOAD_RESIDUAL_NAME)
            return (jnp.sin(y) * y).sum()

        ckpt = jax.checkpoint(unit, policy=host_offload_policy())
        sh = NamedSharding(mesh, PartitionSpec(tuple(mesh.axis_names)))
        x = jnp.zeros((int(mesh.devices.size), 8), jnp.float32)
        jax.jit(jax.grad(ckpt), in_shardings=sh,
                out_shardings=sh).lower(x).compile()
        ok = True
    except Exception:
        ok = False
    _spmd_offload_cache[sig] = ok
    return ok


def configure_offload(lm: "LM", mesh=None) -> bool:
    """Set ``lm.offload_exec`` from the probe.  Returns True when the
    mesh lost real offload execution (OFFLOAD will degrade to remat) —
    callers count that as an offload fallback; the warning fires once
    per mesh signature."""
    ok = spmd_offload_supported(mesh)
    lm.offload_exec = ok
    if not ok:
        sig = (_mesh_probe_sig(mesh) if mesh is not None
               else ("<no-mesh>",))
        if sig not in _spmd_offload_warned:
            _spmd_offload_warned.add(sig)
            import warnings
            warnings.warn(
                f"host offload unavailable under mesh {sig}: OFFLOAD "
                f"actions will execute as plain remat (plans keep their "
                f"typed actions; step time loses the offload axis)",
                RuntimeWarning, stacklevel=2)
    return not ok


# ---------------------------------------------------------------------------
# per-family block init / apply
# ---------------------------------------------------------------------------

def _block_kind(cfg: ModelConfig, decoder: bool = True) -> str:
    if not decoder:
        return "enc"
    if cfg.family == "moe":
        return "moe"
    if cfg.family == "ssm":
        return "ssm"
    if cfg.family == "hybrid":
        return "hybrid"
    if cfg.family == "encdec":
        return "dec"
    return "dense"


def block_init(key: Array, cfg: ModelConfig, kind: str, dtype) -> dict:
    d = cfg.d_model
    keys = jax.random.split(key, 6)
    p: dict = {"norm1": L.rmsnorm_init(d, dtype)}
    if kind == "ssm":
        p["ssm"] = M.mamba2_init(keys[0], cfg, dtype)
        if cfg.d_ff:
            p["norm2"] = L.rmsnorm_init(d, dtype)
            p["mlp"] = L.mlp_init(keys[1], d, cfg.d_ff, cfg.mlp_act, dtype)
        return p
    if kind == "hybrid":
        p["mixer"] = HY.hymba_init(keys[0], cfg, dtype)
        p["norm2"] = L.rmsnorm_init(d, dtype)
        p["mlp"] = L.mlp_init(keys[1], d, cfg.d_ff, cfg.mlp_act, dtype)
        return p
    # attention-bearing kinds
    p["attn"] = L.attention_init(keys[0], cfg, dtype)
    if kind == "dec":
        p["norm_cross"] = L.rmsnorm_init(d, dtype)
        p["cross"] = L.attention_init(keys[2], cfg, dtype)
    p["norm2"] = L.rmsnorm_init(d, dtype)
    if kind == "moe":
        p["moe"] = MOE.moe_init(keys[1], cfg, dtype)
    else:
        p["mlp"] = L.mlp_init(keys[1], d, cfg.d_ff, cfg.mlp_act, dtype)
    return p


def block_apply(params: dict, cfg: ModelConfig, x: Array, kind: str, *,
                positions: Array,
                layer_is_global=True,
                cache: Optional[dict] = None,
                cache_index: Optional[Array] = None,
                decode: bool = False,
                enc_out: Optional[Array] = None,
                mrope_positions: Optional[Array] = None,
                impl: str = "xla",
                seq_lens: Optional[Array] = None,
                ) -> Tuple[Array, Optional[dict], Array]:
    """Returns (x, new_cache, aux_loss).

    ``seq_lens``: optional (B,) true sequence lengths of a bucket-padded
    batch — threaded into the attention key masks and the SSD state
    masks so padded positions do no work and leak nothing.
    """
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Array] = {}
    eps = cfg.norm_eps

    if kind == "ssm":
        h, (new_ssm, new_conv) = M.mamba2_apply(
            params["ssm"], cfg, L.rmsnorm_apply(params["norm1"], x, eps),
            ssm_state=None if cache is None else cache["ssm"],
            conv_state=None if cache is None else cache["conv"],
            decode=decode, seq_lens=seq_lens)
        x = x + h
        if cache is not None:
            new_cache.update(ssm=new_ssm, conv=new_conv)
        if cfg.d_ff:
            x = x + L.mlp_apply(params["mlp"],
                                L.rmsnorm_apply(params["norm2"], x, eps),
                                cfg.mlp_act)
        return x, (new_cache or None), aux

    if kind == "hybrid":
        h, new_kv, (new_ssm, new_conv) = HY.hymba_apply(
            params["mixer"], cfg, L.rmsnorm_apply(params["norm1"], x, eps),
            positions=positions, layer_is_global=layer_is_global,
            kv_cache=None if cache is None else {"k": cache["k"], "v": cache["v"]},
            cache_index=cache_index,
            ssm_state=None if cache is None else cache["ssm"],
            conv_state=None if cache is None else cache["conv"],
            decode=decode, impl=impl, seq_lens=seq_lens)
        x = x + h
        if cache is not None:
            new_cache.update(k=new_kv["k"], v=new_kv["v"], ssm=new_ssm, conv=new_conv)
        x = x + L.mlp_apply(params["mlp"],
                            L.rmsnorm_apply(params["norm2"], x, eps), cfg.mlp_act)
        return x, (new_cache or None), aux

    # attention-bearing blocks -------------------------------------------
    h, new_kv = L.attention_apply(
        params["attn"], cfg, L.rmsnorm_apply(params["norm1"], x, eps),
        positions=positions, layer_is_global=layer_is_global,
        kv_cache=None if cache is None else {"k": cache["k"], "v": cache["v"]},
        cache_index=cache_index, impl=impl,
        mrope_positions=mrope_positions,
        causal=(kind != "enc"), kv_len=seq_lens)
    x = x + h
    if new_kv is not None:
        new_cache.update(k=new_kv["k"], v=new_kv["v"])

    if kind == "dec":
        # cross attention over encoder output (k/v projected here, or cached)
        hx = L.rmsnorm_apply(params["norm_cross"], x, eps)
        if cache is not None and "ck" in cache:
            ck, cv = cache["ck"], cache["cv"]
            new_cache.update(ck=ck, cv=cv)
        else:
            B, F = enc_out.shape[0], enc_out.shape[1]
            hd = cfg.resolved_head_dim()
            ck = (enc_out @ params["cross"]["wk"]).reshape(B, F, cfg.num_kv_heads, hd)
            cv = (enc_out @ params["cross"]["wv"]).reshape(B, F, cfg.num_kv_heads, hd)
            if cache is not None:
                new_cache.update(ck=ck, cv=cv)
        hc, _ = L.attention_apply(params["cross"], cfg, hx,
                                  positions=positions, cross_kv=(ck, cv))
        x = x + hc

    h2 = L.rmsnorm_apply(params["norm2"], x, eps)
    if kind == "moe":
        mo, aux = MOE.moe_apply(params["moe"], cfg, h2)
        x = x + mo
    else:
        x = x + L.mlp_apply(params["mlp"], h2, cfg.mlp_act)
    return x, (new_cache or None), aux


# ---------------------------------------------------------------------------
# plan units
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PlanUnit:
    """One schedulable unit: a block (unrolled) or a layer chunk (scan)."""
    name: str
    index: int                     # forward timestamp order
    params: Any
    apply: Callable[[Any, Array], Array]   # pure fn(params, x) -> x
    flops: float = 0.0             # analytic forward flops (filled by collector)
    # behavioural statics baked into ``apply`` (block kind, local/global
    # attention flag, chunk width...).  Two units with equal signature AND
    # equal param/input shapes trace to identical residual footprints, so
    # the collector measures only one of them (O(#unique units) traces).
    # None disables deduplication for this unit.
    signature: Optional[tuple] = None


# ---------------------------------------------------------------------------
# the LM shell
# ---------------------------------------------------------------------------

class LM:
    def __init__(self, cfg: ModelConfig, attn_impl: str = "xla"):
        self.cfg = cfg
        self.attn_impl = attn_impl
        self.kind = _block_kind(cfg)
        self.dtype = jnp.dtype(cfg.dtype)
        # perf knobs (set by the launcher; see EXPERIMENTS.md §Perf):
        # Megatron-style sequence-parallel residual stream — shard the
        # seq axis of the inter-block activations over the model axis.
        self.act_sharding = None          # NamedSharding or None
        # keep logits in bf16 (CE reductions still accumulate in f32)
        self.logits_f32 = True
        # prefill: emit logits for the last position only (serving needs
        # nothing else; full-sequence logits dominate prefill memory)
        self.last_logits_only = False
        # execute OFFLOAD actions as real host offload (jax.checkpoint
        # offload policy).  False degrades OFFLOAD to plain remat at
        # execution time while keeping the typed plan — needed under
        # SPMD lowering, where current XLA cannot shard the host-offload
        # custom-calls (launch/steps.py flips this for >1-device meshes)
        self.offload_exec = True

    def _constrain(self, x: Array) -> Array:
        if self.act_sharding is not None:
            x = jax.lax.with_sharding_constraint(x, self.act_sharding)
        return x

    # -- init -------------------------------------------------------------
    def init(self, key: Array) -> dict:
        cfg, dt = self.cfg, self.dtype
        keys = jax.random.split(key, cfg.num_layers + cfg.encoder_layers + 3)
        params: dict = {
            "embed": L.embed_init(keys[-1], cfg.vocab_size, cfg.d_model, dt),
            "final_norm": L.rmsnorm_init(cfg.d_model, dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(keys[-2], cfg.d_model,
                                             cfg.vocab_size, dt)
        blocks = [block_init(keys[i], cfg, self.kind, dt)
                  for i in range(cfg.num_layers)]
        if cfg.remat_mode == "scan":
            params["blocks"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *blocks)
        else:
            params["blocks"] = blocks
        if cfg.encoder_layers:
            enc = [block_init(keys[cfg.num_layers + i], cfg, "enc", dt)
                   for i in range(cfg.encoder_layers)]
            params["encoder"] = {
                "blocks": enc,
                "final_norm": L.rmsnorm_init(cfg.d_model, dt),
            }
        return params

    # -- per-layer local/global flags (gemma3 pattern) ----------------------
    def _is_global(self, i: int) -> bool:
        g = self.cfg.global_interval
        if not self.cfg.sliding_window:
            return True
        if not g:
            return False              # uniform sliding window
        return (i + 1) % g == 0

    def _global_flags(self) -> Array:
        return jnp.array([self._is_global(i) for i in range(self.cfg.num_layers)])

    # -- embedding / positions -------------------------------------------
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, St = tokens.shape
        x = params["embed"][tokens]
        mrope_positions = None
        if cfg.family == "vlm" and cfg.vision_tokens:
            ve = batch["vision_embeds"].astype(x.dtype)      # (B, vt, d)
            x = jnp.concatenate([ve, x], axis=1)
            vt = cfg.vision_tokens
            side = max(int(math.sqrt(vt)), 1)
            S = vt + St
            if cfg.mrope:
                idx = jnp.arange(vt)
                tpos = jnp.zeros((vt,), jnp.int32)
                hpos = (idx // side).astype(jnp.int32)
                wpos = (idx % side).astype(jnp.int32)
                text = jnp.arange(St, dtype=jnp.int32) + side
                three = jnp.stack([
                    jnp.concatenate([tpos, text]),
                    jnp.concatenate([hpos, text]),
                    jnp.concatenate([wpos, text]),
                ])                                            # (3, S)
                mrope_positions = jnp.broadcast_to(three[:, None, :], (3, B, S))
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        else:
            positions = batch.get("positions")
            if positions is None:
                positions = jnp.broadcast_to(
                    jnp.arange(St, dtype=jnp.int32), (B, St))
        return x, positions, mrope_positions

    def _encode(self, params, batch, remat_enc=None):
        """Run the (bidirectional) encoder over stub frame embeddings."""
        cfg = self.cfg
        frames = batch["frames"].astype(self.dtype)          # (B, F, d)
        B, F, _ = frames.shape
        pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F))
        x = frames
        enc_actions = (as_actions(remat_enc) if remat_enc is not None
                       else None)
        for i, bp in enumerate(params["encoder"]["blocks"]):
            def one(p, xx):
                y, _, _ = block_apply(p, cfg, xx, "enc", positions=pos,
                                      impl=self.attn_impl)
                return y
            if enc_actions is not None:
                if enc_actions[i] is Action.REMAT:
                    one = jax.checkpoint(one)
                elif enc_actions[i] is Action.OFFLOAD:
                    one = (_offload_unit(one) if self.offload_exec
                           else jax.checkpoint(one))
            x = one(bp, x)
        return L.rmsnorm_apply(params["encoder"]["final_norm"], x, cfg.norm_eps)

    # -- forward -----------------------------------------------------------
    def forward(self, params, batch, remat_mask=None,
                remat_policy=None) -> Tuple[Array, Array]:
        """remat_mask: per-unit plan over plan units (blocks or chunks) —
        either the legacy bool sequence (True = rematerialise) or a
        typed ``repro.actions.Action`` sequence; ``OFFLOAD`` units pin
        their residual-stream checkpoint to host memory via the
        ``host_offload_policy`` instead of keeping it in HBM.

        When the batch carries ``lengths`` ((B,) true sequence lengths of
        a bucket-padded batch), they are threaded into every block so the
        kernels mask — and, where blockwise, skip — the padded tail.
        """
        cfg = self.cfg
        x, positions, mrope_positions = self._embed_inputs(params, batch)
        aux = jnp.zeros((), jnp.float32)
        seq_lens = batch.get("lengths")
        if seq_lens is not None:
            seq_lens = jnp.asarray(seq_lens, jnp.int32)
            if cfg.family == "vlm" and cfg.vision_tokens:
                # vision patches are prepended and always real tokens
                seq_lens = seq_lens + cfg.vision_tokens

        n_units = self.num_plan_units()
        actions = (as_actions(remat_mask) if remat_mask is not None
                   else (Action.KEEP,) * n_units)
        assert len(actions) == n_units, (len(actions), n_units)

        enc_out = None
        enc_units = self._num_enc_units()
        if cfg.encoder_layers:
            enc_out = self._encode(params, batch,
                                   remat_enc=actions[:enc_units])
        dec_actions = actions[enc_units:]

        if cfg.remat_mode == "scan":
            x, aux = self._forward_scan(params, x, positions, dec_actions,
                                        enc_out, mrope_positions,
                                        remat_policy, seq_lens)
        else:
            for i, bp in enumerate(params["blocks"]):
                def one(p, xx):
                    y, _, a = block_apply(
                        p, cfg, xx, self.kind, positions=positions,
                        layer_is_global=self._is_global(i),
                        enc_out=enc_out, mrope_positions=mrope_positions,
                        impl=self.attn_impl, seq_lens=seq_lens)
                    return y, a
                if dec_actions[i] is Action.REMAT:
                    one = jax.checkpoint(one, policy=remat_policy)
                elif dec_actions[i] is Action.OFFLOAD:
                    one = (_offload_unit(one) if self.offload_exec
                           else jax.checkpoint(one, policy=remat_policy))
                x, a = one(bp, x)
                x = self._constrain(x)
                aux = aux + a

        if self.last_logits_only:
            x = x[:, -1:]
        x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = x @ head
        if self.logits_f32:
            logits = logits.astype(jnp.float32)
        return logits, aux

    def _forward_scan(self, params, x, positions, chunk_actions, enc_out,
                      mrope_positions, remat_policy, seq_lens=None):
        cfg = self.cfg
        bounds = self._chunk_bounds()
        aux = jnp.zeros((), jnp.float32)
        chunk_actions = as_actions(chunk_actions)

        def make_body(flag):
            # ``flag`` is a STATIC python bool (chunks are type-homogeneous)
            # so local chunks take the banded sliding-window path.
            def body(carry, p_i):
                xx, ax = carry
                y, _, a = block_apply(p_i, cfg, xx, self.kind,
                                      positions=positions,
                                      layer_is_global=flag,
                                      enc_out=enc_out,
                                      mrope_positions=mrope_positions,
                                      impl=self.attn_impl,
                                      seq_lens=seq_lens)
                y = self._constrain(y)
                return (y, ax + a), None
            return body

        for c, (s, e) in enumerate(bounds):
            p_chunk = jax.tree_util.tree_map(lambda a: a[s:e], params["blocks"])
            body = make_body(self._chunk_flag(s, e))
            if chunk_actions[c] is Action.REMAT:
                bfn = jax.checkpoint(body, policy=remat_policy)
            elif chunk_actions[c] is Action.OFFLOAD:
                if self.offload_exec:
                    def off_body(carry, p_i, _b=body):
                        xx, ax = carry
                        return _b((checkpoint_name(xx,
                                                   OFFLOAD_RESIDUAL_NAME),
                                   ax), p_i)
                    bfn = jax.checkpoint(off_body,
                                         policy=host_offload_policy())
                else:
                    bfn = jax.checkpoint(body, policy=remat_policy)
            else:
                bfn = body
            (x, aux), _ = jax.lax.scan(bfn, (x, aux), p_chunk)
        return x, aux

    def _chunk_bounds(self) -> List[Tuple[int, int]]:
        L_ = self.cfg.num_layers
        if self.cfg.sliding_window and self.cfg.global_interval:
            # type-homogeneous chunks (runs of local layers + global
            # singletons) so the local/global flag is STATIC per chunk and
            # local chunks can take the banded-attention path.
            bounds, s = [], 0
            for i in range(L_):
                if self._is_global(i):
                    if i > s:
                        bounds.append((s, i))
                    bounds.append((i, i + 1))
                    s = i + 1
            if s < L_:
                bounds.append((s, L_))
            return bounds
        K = max(1, min(self.cfg.scan_chunks, L_))
        step = math.ceil(L_ / K)
        return [(s, min(s + step, L_)) for s in range(0, L_, step)]

    def _chunk_flag(self, s: int, e: int) -> bool:
        """Static local/global flag for a type-homogeneous chunk."""
        flags = {self._is_global(i) for i in range(s, e)}
        if len(flags) == 1:
            return flags.pop()
        return True        # mixed chunk (no banding): treat as global/full

    def _num_enc_units(self) -> int:
        return self.cfg.encoder_layers

    # -- static per-unit facts for the analytic cost model -------------------
    def plan_unit_meta(self, batch) -> List[Dict[str, Any]]:
        """One dict per plan unit, timestamp order: the static facts the
        ``launch/roofline.py`` cost model needs to price a unit's forward
        (= its recompute cost) at this batch's geometry.  Works on arrays
        and ``ShapeDtypeStruct`` batches alike — no tracing, so the
        planner can call it per bucket for free."""
        cfg = self.cfg
        B, St = batch["tokens"].shape
        S = St + (cfg.vision_tokens
                  if cfg.family == "vlm" and cfg.vision_tokens else 0)
        F = batch["frames"].shape[1] if "frames" in batch else 0
        metas: List[Dict[str, Any]] = []
        for i in range(cfg.encoder_layers):
            metas.append({"kind": "enc", "layers": 1, "batch": B, "seq": F,
                          "is_global": True})
        if cfg.remat_mode == "scan":
            for s, e in self._chunk_bounds():
                metas.append({"kind": self.kind, "layers": e - s, "batch": B,
                              "seq": S, "is_global": self._chunk_flag(s, e),
                              "enc_frames": F})
        else:
            for i in range(cfg.num_layers):
                metas.append({"kind": self.kind, "layers": 1, "batch": B,
                              "seq": S, "is_global": self._is_global(i),
                              "enc_frames": F})
        return metas

    def num_plan_units(self) -> int:
        if self.cfg.remat_mode == "scan":
            return self._num_enc_units() + len(self._chunk_bounds())
        return self._num_enc_units() + self.cfg.num_layers

    # -- loss ---------------------------------------------------------------
    def loss(self, params, batch, remat_mask=None, remat_policy=None):
        cfg = self.cfg
        logits, aux = self.forward(params, batch, remat_mask, remat_policy)
        labels = batch["labels"]
        if cfg.family == "vlm" and cfg.vision_tokens:
            logits = logits[:, cfg.vision_tokens:]           # text positions only
        weights = batch.get("weights")
        if weights is None:
            weights = jnp.ones(labels.shape, jnp.float32)
        # sharding-friendly cross entropy: the vocab axis of ``logits`` is
        # model-sharded, so avoid take_along_axis (which would all-gather
        # the full logits).  one_hot contracts the vocab axis locally and
        # reduces across the model axis instead.
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
        label_logit = jnp.einsum("bsv,bsv->bs", logits, onehot,
                                 preferred_element_type=jnp.float32)
        nll = lse - label_logit
        total_w = jnp.maximum(jnp.sum(weights), 1.0)
        ce = jnp.sum(nll * weights) / total_w
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux, "tokens": total_w}

    # -- decode -------------------------------------------------------------
    def init_cache(self, batch_size: int, max_len: int) -> Any:
        cfg, dt = self.cfg, self.dtype
        hd = cfg.resolved_head_dim()
        d_inner, H, N, conv_dim = (M.mamba2_dims(cfg) if cfg.ssm_state
                                   else (0, 0, 0, 0))

        def one_cache():
            c: dict = {}
            if self.kind in ("dense", "moe", "dec", "hybrid"):
                c["k"] = jnp.zeros((batch_size, max_len, cfg.num_kv_heads, hd), dt)
                c["v"] = jnp.zeros((batch_size, max_len, cfg.num_kv_heads, hd), dt)
            if self.kind in ("ssm", "hybrid"):
                c["ssm"] = jnp.zeros((batch_size, H, cfg.ssm_head_dim, N),
                                     jnp.float32)
                c["conv"] = jnp.zeros((batch_size, cfg.conv_kernel - 1, conv_dim), dt)
            if self.kind == "dec":
                F = cfg.encoder_frames or max_len
                c["ck"] = jnp.zeros((batch_size, F, cfg.num_kv_heads, hd), dt)
                c["cv"] = jnp.zeros((batch_size, F, cfg.num_kv_heads, hd), dt)
            return c

        caches = [one_cache() for _ in range(cfg.num_layers)]
        if cfg.remat_mode == "scan":
            return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches)
        return caches

    # -- batched cache slots (continuous-batching serve engine) -------------
    # A pool cache is just ``init_cache(slots, max_len)``: batch rows are
    # request slots.  The three operations below move whole rows between
    # a staging cache (one prefilling request) and a pool at STATIC
    # shapes — ``slot`` is a traced scalar, so the engine compiles one
    # executable per (bucket, slots) geometry, never per slot index.

    def cache_batch_axis(self) -> int:
        """Axis of the request/batch dimension in every cache leaf
        (scan mode stacks a leading layer axis)."""
        return 1 if self.cfg.remat_mode == "scan" else 0

    def cache_insert(self, pool: Any, rows: Any, slot) -> Any:
        """Write ``rows`` (a cache whose batch dim holds >= 1 request
        rows, e.g. a prefill staging cache) into ``pool`` starting at
        batch row ``slot``.  Shapes must match outside the batch axis."""
        ax = self.cache_batch_axis()
        return jax.tree_util.tree_map(
            lambda p, r: jax.lax.dynamic_update_slice_in_dim(
                p, r.astype(p.dtype), slot, axis=ax), pool, rows)

    def cache_extract(self, pool: Any, slot) -> Any:
        """Read one request row out of ``pool`` as a batch-1 cache."""
        ax = self.cache_batch_axis()
        return jax.tree_util.tree_map(
            lambda p: jax.lax.dynamic_slice_in_dim(p, slot, 1, axis=ax),
            pool)

    def cache_evict(self, pool: Any, slot) -> Any:
        """Zero one request row of ``pool`` (slot freed: no stale state
        survives into the next tenant — insert overwrites the row anyway,
        this keeps freed slots inert and debuggable)."""
        ax = self.cache_batch_axis()
        return jax.tree_util.tree_map(
            lambda p: jax.lax.dynamic_update_slice_in_dim(
                p, jnp.zeros_like(
                    jax.lax.dynamic_slice_in_dim(p, slot, 1, axis=ax)),
                slot, axis=ax), pool)

    def decode_step(self, params, tokens, cache, index):
        """tokens: (B, C) int32 — C == 1 for token-by-token decode, a
        whole block for chunked prefill (``train.serve``); index: scalar
        position of the first token, or a (B,) int32 vector of per-row
        positions — the continuous-batching engine's form, where every
        batch row is a different request at its own decode position
        (rows parked at index == cache length write nothing).  Returns
        (logits (B,C,V), new_cache) — the cache advances by C positions."""
        cfg = self.cfg
        B, C = tokens.shape
        x = params["embed"][tokens]
        idx = jnp.asarray(index, jnp.int32)
        if idx.ndim >= 1:
            positions = idx[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
            index = idx
        else:
            positions = index + jnp.broadcast_to(
                jnp.arange(C, dtype=jnp.int32), (B, C))
        mrope_positions = None
        if cfg.mrope:
            mrope_positions = jnp.broadcast_to(positions[None], (3, B, C))

        if cfg.remat_mode == "scan":
            flags = self._global_flags()

            def body(xx, inp):
                p_i, cache_i, flag_i = inp
                y, nc, _ = block_apply(p_i, cfg, xx, self.kind,
                                       positions=positions,
                                       layer_is_global=flag_i,
                                       cache=cache_i, cache_index=index,
                                       decode=True, impl="xla",
                                       mrope_positions=mrope_positions)
                return y, nc
            x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache, flags))
        else:
            new_cache = []
            for i, bp in enumerate(params["blocks"]):
                x, nc, _ = block_apply(bp, cfg, x, self.kind,
                                       positions=positions,
                                       layer_is_global=self._is_global(i),
                                       cache=cache[i], cache_index=index,
                                       decode=True, impl="xla",
                                       mrope_positions=mrope_positions)
                new_cache.append(nc)

        x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = (x @ head).astype(jnp.float32)
        return logits, new_cache

    # -- plan units for the Mimose collector --------------------------------
    def plan_units(self, params, batch) -> List[PlanUnit]:
        """Ordered plannable units.  Each unit's ``apply`` is a pure
        fn(unit_params, x) -> x at the *current* batch geometry, which the
        shuttling collector inspects abstractly (eval_shape + vjp)."""
        cfg = self.cfg
        units: List[PlanUnit] = []
        x, positions, mrope_positions = jax.eval_shape(
            lambda p, b: self._embed_inputs(p, b), params, batch)[0], None, None
        # recompute positions cheaply (concrete, shapes only matter)
        tokens = batch["tokens"]
        B, St = tokens.shape
        S = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        if cfg.mrope:
            mrope_positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None, None, :], (3, B, S))

        idx = 0
        if cfg.encoder_layers:
            F = batch["frames"].shape[1]
            enc_pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F))
            for i, bp in enumerate(params["encoder"]["blocks"]):
                def enc_fn(p, xx, _pos=enc_pos):
                    y, _, _ = block_apply(p, cfg, xx, "enc", positions=_pos,
                                          impl=self.attn_impl)
                    return y
                units.append(PlanUnit(f"enc{i}", idx, bp, enc_fn,
                                      signature=("enc",)))
                idx += 1

        enc_out_struct = None
        if cfg.encoder_layers:
            enc_out_struct = jnp.zeros(
                (B, batch["frames"].shape[1], cfg.d_model), self.dtype)
        # decoder units close over the encoder output: its geometry must be
        # part of the dedup signature or cross-attention residuals cached at
        # one frame count would be replayed at another
        enc_sig = (tuple(enc_out_struct.shape)
                   if enc_out_struct is not None else None)

        def _slice(a, s, e):
            # works for arrays and ShapeDtypeStructs (abstract dry-run)
            if isinstance(a, jax.ShapeDtypeStruct):
                return jax.ShapeDtypeStruct((e - s,) + a.shape[1:], a.dtype)
            return a[s:e]

        if cfg.remat_mode == "scan":
            for c, (s, e) in enumerate(self._chunk_bounds()):
                p_chunk = jax.tree_util.tree_map(
                    lambda a, _s=s, _e=e: _slice(a, _s, _e), params["blocks"])

                def chunk_fn(p, xx, _flag=self._chunk_flag(s, e)):
                    def body(carry, pi):
                        y, _, _ = block_apply(pi, cfg, carry, self.kind,
                                              positions=positions,
                                              layer_is_global=_flag,
                                              enc_out=enc_out_struct,
                                              mrope_positions=mrope_positions,
                                              impl=self.attn_impl)
                        return y, None
                    out, _ = jax.lax.scan(body, xx, p)
                    return out
                units.append(PlanUnit(
                    f"chunk{c}[{s}:{e}]", idx, p_chunk, chunk_fn,
                    signature=("chunk", self._chunk_flag(s, e), e - s,
                               enc_sig)))
                idx += 1
        else:
            for i, bp in enumerate(params["blocks"]):
                def blk_fn(p, xx, _i=i):
                    y, _, _ = block_apply(p, cfg, xx, self.kind,
                                          positions=positions,
                                          layer_is_global=self._is_global(_i),
                                          enc_out=enc_out_struct,
                                          mrope_positions=mrope_positions,
                                          impl=self.attn_impl)
                    return y
                units.append(PlanUnit(f"block{i}", idx, bp, blk_fn,
                                      signature=("block", self._is_global(i),
                                                 enc_sig)))
                idx += 1
        return units


def build_model(cfg: ModelConfig, attn_impl: str = "xla") -> LM:
    return LM(cfg, attn_impl=attn_impl)
