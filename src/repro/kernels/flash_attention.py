"""Blockwise flash attention for TPU (Pallas, explicit VMEM BlockSpecs).

TPU adaptation of FlashAttention: rather than the CUDA shared-memory /
warp formulation, tiles are chosen for the MXU (128-aligned q/k blocks)
and staged HBM->VMEM by ``pl.pallas_call`` BlockSpecs.  The online
softmax runs in fp32 on the VPU; the (q_block, k_block) score tile never
leaves VMEM, so per-layer residual memory is O(S) — this is the kernel
whose effect the Mimose estimator observes as the quadratic coefficient
of its fitted memory curve collapsing to ~0 (see EXPERIMENTS.md §Perf).

Layout: q (B, H, S, hd); k, v (B, Hkv, S, hd) — GQA is expressed in the
kv index_map (query head h reads kv head h // group), so no repeat is
materialised.

Grid: (B, H, S // block_q); the k loop runs inside the kernel over
block_k-sized VMEM slices.

Ragged execution: every kernel takes a per-sequence ``kv_len`` operand
(true lengths of a bucket-padded batch).  Padded keys are masked out of
the online softmax, and the inner fori_loop trip counts are clamped so
k-blocks entirely past the true length — and q-blocks entirely inside
the padding — are never executed.  Shapes stay bucket-static (the
compile-once property is untouched); only runtime trip counts and masks
depend on the lengths, so one executable serves every raggedness.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = float(jnp.finfo(jnp.float32).min)

_LEAD = (pl.dslice(0, 1), pl.dslice(0, 1))   # (batch, head) block coords


def _load_seq(ref, start, size):
    """Load a (size, hd) tile at seq offset ``start`` from a (1,1,S,hd) ref.

    The leading unit dims are addressed with size-1 dslices rather than
    raw ints: integer indices inside ``pl.load`` break the interpret-mode
    discharge rule on this jax version, and the dslice form lowers to the
    same VMEM access on TPU.
    """
    return pl.load(ref, _LEAD + (pl.dslice(start, size), slice(None)))[0, 0]


def _load_row(ref, start, size):
    """Load a (size,) row vector at seq offset ``start`` from a (1,1,S) ref."""
    return pl.load(ref, _LEAD + (pl.dslice(start, size),))[0, 0]


def _flash_kernel(q_ref, k_ref, v_ref, kvl_ref, o_ref, lse_ref, *,
                  block_k: int, causal: bool, window: int, sm_scale: float):
    bq, hd = q_ref.shape[-2], q_ref.shape[-1]
    Sk = k_ref.shape[-2]
    qi = pl.program_id(2)
    kvl = kvl_ref[0]                                         # true length

    q = q_ref[0, 0].astype(jnp.float32) * sm_scale           # (bq, hd)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)

    nkb = pl.cdiv(Sk, block_k)

    def body(j, carry):
        acc, m_prev, l_prev = carry
        k = _load_seq(k_ref, j * block_k, block_k).astype(jnp.float32)  # (bk, hd)
        v = _load_seq(v_ref, j * block_k, block_k).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        k_pos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (bq, block_k), 1)
        mask = k_pos < kvl
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))      # (bq,)
        correction = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * correction + jnp.sum(p, axis=-1)
        acc = acc * correction[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc, m_cur, l_cur

    # with causal masking, key blocks past this query block contribute
    # nothing; key blocks entirely past the true length likewise, and a
    # query block entirely inside the padding skips the loop outright
    upper = nkb if not causal else jnp.minimum(
        nkb, pl.cdiv((qi + 1) * bq, block_k))
    upper = jnp.minimum(upper, pl.cdiv(kvl, block_k))
    upper = jnp.where(qi * bq >= kvl, 0, upper)
    acc0 = jnp.zeros((bq, hd), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, upper, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)
    lse_ref[0, 0] = m + jnp.log(jnp.maximum(l, 1e-30))


def _resolve_kv_len(kv_len, B: int, S: int):
    """Normalise ``kv_len`` to a clamped (B,) int32 vector (None -> S)."""
    if kv_len is None:
        return jnp.full((B,), S, jnp.int32)
    return jnp.clip(jnp.asarray(kv_len, jnp.int32), 0, S)


def flash_attention_fwd(q, k, v, kv_len=None, *, causal: bool = True,
                        window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False, return_lse: bool = False):
    """q: (B, H, S, hd); k, v: (B, Hkv, S, hd) -> (B, H, S, hd) [, lse].

    ``kv_len``: optional (B,) int32 true sequence lengths — positions at
    or past a sequence's length are masked out and skipped blockwise.
    """
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    sm_scale = 1.0 / math.sqrt(hd)
    grid = (B, H, pl.cdiv(S, block_q))
    kvl = _resolve_kv_len(kv_len, B, S)

    o, lse = pl.pallas_call(
        functools.partial(_flash_kernel, block_k=block_k, causal=causal,
                          window=window, sm_scale=sm_scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, S, hd), lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((1, 1, S, hd), lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((1,), lambda b, h, i: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
            jax.ShapeDtypeStruct((B, H, S), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, kvl)
    return (o, lse) if return_lse else o


# ---------------------------------------------------------------------------
# backward kernels: blockwise dq and dk/dv with the score tile recomputed
# in VMEM from the saved (q, k, v, lse) — the FlashAttention-2 backward,
# adapted to TPU grid semantics.  GQA: dk/dv are produced per *query*
# head and reduced over the group outside the kernel.
# ---------------------------------------------------------------------------

def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         kvl_ref, dq_ref, *, block_k: int, causal: bool,
                         window: int, sm_scale: float):
    bq, hd = q_ref.shape[-2], q_ref.shape[-1]
    Sk = k_ref.shape[-2]
    qi = pl.program_id(2)
    kvl = kvl_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)                       # (bq, hd)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]                                       # (bq,)
    delta = delta_ref[0, 0]                                   # (bq,)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
    nkb = pl.cdiv(Sk, block_k)
    upper = nkb if not causal else jnp.minimum(
        nkb, pl.cdiv((qi + 1) * bq, block_k))
    upper = jnp.minimum(upper, pl.cdiv(kvl, block_k))
    upper = jnp.where(qi * bq >= kvl, 0, upper)

    def body(j, dq):
        k = _load_seq(k_ref, j * block_k, block_k).astype(jnp.float32)
        v = _load_seq(v_ref, j * block_k, block_k).astype(jnp.float32)
        s = sm_scale * jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        k_pos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (bq, block_k), 1)
        mask = k_pos < kvl
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)   # (bq, bk)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * sm_scale
        return dq + jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    dq = jax.lax.fori_loop(0, upper, body, jnp.zeros((bq, hd), jnp.float32))
    dq_ref[0, 0] = dq.astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          kvl_ref, dk_ref, dv_ref, *, block_q: int,
                          causal: bool, window: int, sm_scale: float):
    bk, hd = k_ref.shape[-2], k_ref.shape[-1]
    Sq = q_ref.shape[-2]
    ki = pl.program_id(2)
    kvl = kvl_ref[0]
    k = k_ref[0, 0].astype(jnp.float32)                       # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (block_q, bk), 1)
    nqb = pl.cdiv(Sq, block_q)
    lower = 0 if not causal else ki * bk // block_q
    # query blocks past the true length contribute nothing to dk/dv; a
    # key block entirely inside the padding skips the loop outright
    upper = jnp.minimum(nqb, pl.cdiv(kvl, block_q))
    upper = jnp.where(ki * bk >= kvl, 0, upper)

    def body(i, carry):
        dk, dv = carry
        q = _load_seq(q_ref, i * block_q, block_q).astype(jnp.float32)
        do = _load_seq(do_ref, i * block_q, block_q).astype(jnp.float32)
        lse = _load_row(lse_ref, i * block_q, block_q)
        delta = _load_row(delta_ref, i * block_q, block_q)
        s = sm_scale * jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)               # (bq, bk)
        q_pos = i * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, bk), 0)
        mask = (q_pos < kvl) & (k_pos < kvl)
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dv = dv + jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * sm_scale
        dk = dk + jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
        return dk, dv

    dk0 = jnp.zeros((bk, hd), jnp.float32)
    dk, dv = jax.lax.fori_loop(lower, upper, body, (dk0, dk0))
    dk_ref[0, 0] = dk.astype(dk_ref.dtype)
    dv_ref[0, 0] = dv.astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, kv_len=None, *, causal: bool,
                        window: int, block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """Blockwise backward.  Returns (dq, dk, dv) with dk/dv group-reduced."""
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    sm_scale = 1.0 / math.sqrt(hd)
    kvl = _resolve_kv_len(kv_len, B, S)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)                                   # (B, H, S)

    kv_spec = pl.BlockSpec((1, 1, S, hd), lambda b, h, i: (b, h // group, 0, 0))
    q_full = pl.BlockSpec((1, 1, S, hd), lambda b, h, i: (b, h, 0, 0))
    row_full = pl.BlockSpec((1, 1, S), lambda b, h, i: (b, h, 0))
    len_spec = pl.BlockSpec((1,), lambda b, h, i: (b,))

    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, block_k=block_k,
                          causal=causal, window=window, sm_scale=sm_scale),
        grid=(B, H, pl.cdiv(S, block_q)),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i: (b, h, i, 0)),
            kv_spec, kv_spec,
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i: (b, h, i)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i: (b, h, i)),
            len_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        interpret=interpret,
    )(q, k, v, do, lse, delta, kvl)

    # dk/dv per query head, reduced over the GQA group afterwards
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, block_q=block_q,
                          causal=causal, window=window, sm_scale=sm_scale),
        grid=(B, H, pl.cdiv(S, block_k)),
        in_specs=[
            q_full, kv_spec, kv_spec, q_full, row_full, row_full, len_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i: (b, h, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, S, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta, kvl)
    dk = dk_h.reshape(B, Hkv, group, S, hd).sum(axis=2).astype(k.dtype)
    dv = dv_h.reshape(B, Hkv, group, S, hd).sum(axis=2).astype(v.dtype)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom VJP: residuals are O(S) (q, k, v, o, lse) — the flash memory
# signature.  Backward recomputes the score tiles blockwise in VMEM
# (FlashAttention-2 backward, Pallas kernels above).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def flash_attention(q, k, v, kv_len=None, causal: bool = True,
                    window: int = 0, interpret: bool = False):
    return flash_attention_fwd(q, k, v, kv_len, causal=causal, window=window,
                               interpret=interpret)


def _fwd(q, k, v, kv_len, causal, window, interpret):
    o, lse = flash_attention_fwd(q, k, v, kv_len, causal=causal,
                                 window=window, interpret=interpret,
                                 return_lse=True)
    return o, (q, k, v, o, lse, kv_len)


def _bwd(causal, window, interpret, res, do):
    q, k, v, o, lse, kv_len = res
    dq, dk, dv = flash_attention_bwd(q, k, v, o, lse, do, kv_len,
                                     causal=causal, window=window,
                                     interpret=interpret)
    # int32 lengths are non-differentiable: their cotangent type is float0
    dlen = (None if kv_len is None
            else np.zeros(np.shape(kv_len), jax.dtypes.float0))
    return dq, dk, dv, dlen


flash_attention.defvjp(_fwd, _bwd)
