"""Double-buffered async-copy (DMA) kernel for residual-stream staging.

XLA's ``save_and_offload_only_these_names`` policy leaves the residual
checkpoint's device->host copy on the main compute stream when it can't
prove overlap; this kernel is the manual path: the array is walked in
chunks through a two-slot VMEM scratch with explicit ``make_async_copy``
DMAs, so the fetch of chunk ``i+1`` is in flight while chunk ``i``
drains to its destination — the on-chip half of the double buffering
``repro.train.transfer.TransferLane`` does across the host link.

The kernel is a *copy* (source and destination live in compiler-chosen
``ANY`` memory space); its value is the DMA schedule, not the data
movement itself.  On TPU the two in-flight DMAs overlap in hardware; in
interpret mode (CPU tests) the same schedule executes with jnp
semantics, so correctness sweeps validate the real kernel logic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# 2 slots = double buffering: one DMA landing while the other drains
_SLOTS = 2


def _dma_copy_kernel(src_ref, dst_ref):
    n = src_ref.shape[0]                                # chunks
    chunk = src_ref.shape[1]

    def body(scratch, in_sems, out_sems):
        def copy_in(i, slot):
            return pltpu.make_async_copy(src_ref.at[i], scratch.at[slot],
                                         in_sems.at[slot])

        def copy_out(i, slot):
            return pltpu.make_async_copy(scratch.at[slot], dst_ref.at[i],
                                         out_sems.at[slot])

        # warm-up: start the first fetch before entering the loop
        copy_in(0, 0).start()

        def step(i, _):
            slot = jax.lax.rem(i, _SLOTS)
            nxt = 1 - slot

            # overlap: the next chunk's fetch rides behind this chunk's
            # drain — the whole point of the two-slot scratch
            @pl.when(i + 1 < n)
            def _():
                copy_in(i + 1, nxt).start()

            copy_in(i, slot).wait()
            copy_out(i, slot).start()
            copy_out(i, slot).wait()
            return 0

        jax.lax.fori_loop(0, n, step, 0)

    pl.run_scoped(body,
                  pltpu.VMEM((_SLOTS, chunk), src_ref.dtype),
                  pltpu.SemaphoreType.DMA((_SLOTS,)),
                  pltpu.SemaphoreType.DMA((_SLOTS,)))


def dma_copy(x, *, chunk_elems: int = 1 << 15, interpret: bool = False):
    """Copy ``x`` through the double-buffered DMA pipeline.

    Flattens to ``(n_chunks, chunk_elems)`` (zero-padded tail), runs the
    kernel, and restores the original shape.  Returns an array equal to
    ``x``; on TPU the copy is a pipelined pair of DMA streams instead of
    one blocking transfer.
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    chunk = int(min(chunk_elems, max(n, 1)))
    pad = (-n) % chunk
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(-1, chunk)
    out = pl.pallas_call(
        _dma_copy_kernel,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct(chunks.shape, chunks.dtype),
        interpret=interpret,
    )(chunks)
    return out.reshape(-1)[:n].reshape(x.shape)
