"""jit'd public wrappers around the Pallas kernels.

On TPU the kernels run compiled; everywhere else (this CPU container,
unit tests) they run in ``interpret=True`` mode, which executes the
kernel body with jnp semantics — bit-identical control flow, so the
allclose sweeps against ``ref.py`` validate the real kernel logic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import offload_dma as _dma
from repro.kernels import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, kv_len=None, *, causal: bool = True,
                    window: int = 0):
    """q: (B, S, H, hd); k, v: (B, S, Hkv, hd) -> (B, S, H, hd).

    (Model layout; transposed to the kernel's (B, H, S, hd) internally.)
    ``kv_len``: optional (B,) int32 true lengths of a bucket-padded batch
    — padded keys are masked and fully-padded blocks skipped, so the
    kernel does work proportional to the *effective* tokens while the
    compiled shape stays the bucket shape.
    """
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = _fa.flash_attention(qt, kt, vt, kv_len, causal, window, not _on_tpu())
    return o.transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("chunk_elems",))
def residual_dma_copy(x, *, chunk_elems: int = 1 << 15):
    """Stage a residual checkpoint through the double-buffered DMA
    pipeline (``offload_dma``): chunk ``i+1``'s fetch overlaps chunk
    ``i``'s drain.  Value-identical to ``x`` — the schedule, not the
    data, is the product."""
    return _dma.dma_copy(x, chunk_elems=chunk_elems,
                         interpret=not _on_tpu())


@partial(jax.jit, static_argnames=("chunk", "chunks_per_block"))
def ssd_scan(x, dt, A, Bm, Cm, kv_len=None, *, chunk: int = 64,
             chunks_per_block: int = 1):
    """Pads S to a ``chunk * chunks_per_block`` multiple and runs the
    Pallas SSD scan.

    ``kv_len``: optional (B,) int32 true lengths — contributions past a
    sequence's length never enter the recurrent state, and chunks fully
    inside the padding are never executed.  ``chunks_per_block``
    amortises grid dispatch over several chunks per cell.
    """
    B, S, H, P = x.shape
    span = chunk * chunks_per_block
    pad = (-S) % span
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    if kv_len is None and pad:
        kv_len = jnp.full((B,), S, jnp.int32)
    y = _ssd.ssd_scan(x, dt, A, Bm, Cm, kv_len=kv_len, chunk=chunk,
                      chunks_per_block=chunks_per_block,
                      interpret=not _on_tpu())
    return y[:, :S]
