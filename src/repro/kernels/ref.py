"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the ground truth the kernels are validated against with
``np.testing.assert_allclose`` across shape/dtype sweeps (see
tests/test_kernels.py).  They are deliberately the simplest possible
formulations — no chunking, no online softmax.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_reference(q, k, v, *, causal: bool = True,
                              window: int = 0, kv_len=None):
    """q: (B, H, Sq, hd); k, v: (B, Hkv, Sk, hd).  GQA via head grouping.

    Returns (B, H, Sq, hd).  window > 0 limits attention to the last
    ``window`` positions (sliding window); causal masks the future.
    ``kv_len``: optional (B,) int32 true lengths — keys at or past a
    sequence's length are masked out (ragged-batch oracle).
    """
    B, H, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    group = H // Hkv
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kq.astype(jnp.float32)) / math.sqrt(hd)
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)     # align ends (decode-style)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    mask = jnp.broadcast_to(mask[None], (B, Sq, Sk))
    if kv_len is not None:
        mask = mask & (kpos[None] < jnp.asarray(kv_len)[:, None, None])
    logits = jnp.where(mask[:, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vq.astype(jnp.float32))
    # rows with no visible key (padded queries under kv_len) are 0/0:
    # return exact zeros there instead of NaN
    out = jnp.where(jnp.any(mask, axis=-1)[:, None, :, None], out, 0.0)
    return out.astype(q.dtype)


def ssd_reference(x, dt, A, B, C, initial_state=None, kv_len=None):
    """Naive O(S) sequential SSD recurrence (the definition).

    x: (Bt, S, H, P); dt: (Bt, S, H); A: (H,); B, C: (Bt, S, N).
    Returns (y (Bt,S,H,P), final_state (Bt,H,P,N)).

      state_t = exp(dt_t * A) * state_{t-1} + dt_t * B_t x_t
      y_t     = C_t . state_t

    ``kv_len``: optional (Bt,) true lengths — dt is zeroed past a
    sequence's length, so padding never enters the state (ragged oracle).
    """
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    if kv_len is not None:
        valid = jnp.arange(S)[None, :, None] < jnp.asarray(kv_len)[:, None, None]
        dt = jnp.where(valid, dt, 0.0).astype(dt.dtype)
    state = (jnp.zeros((Bt, H, P, N), jnp.float32) if initial_state is None
             else initial_state.astype(jnp.float32))
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t].astype(jnp.float32) * A.astype(jnp.float32))
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, t].astype(jnp.float32),
                         x[:, t].astype(jnp.float32),
                         B[:, t].astype(jnp.float32))
        state = state * dA[:, :, None, None] + upd
        ys.append(jnp.einsum("bhpn,bn->bhp", state,
                             C[:, t].astype(jnp.float32)))
    y = jnp.stack(ys, axis=1)
    return y.astype(x.dtype), state
