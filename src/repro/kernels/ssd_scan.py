"""Chunked Mamba2/SSD scan for TPU (Pallas, sequential-grid state carry).

TPU adaptation of the SSD algorithm [arXiv:2405.21060]: the chunk loop is
the *last* grid dimension with ``arbitrary`` semantics, so the recurrent
(P, N) state lives in a VMEM scratch buffer that persists across grid
steps — the TPU-idiomatic replacement for the CUDA warp-level scan.  The
intra-chunk work is two (Q, Q)-tile matmuls on the MXU; the inter-chunk
recurrence touches only the (P, N) state.

Layout: x (B, H, NC, Q, P); dt (B, H, NC, Q); Bm/Cm (B, NC, Q, N);
A (H,).  Grid: (B, H, NC) with NC sequential.

Ragged execution: a per-sequence ``kv_len`` operand marks the true
length of a bucket-padded batch.  Positions past the length contribute
nothing to the recurrent state (their dt is zeroed, so decay is exp(0)
and the update term vanishes), and chunks that lie entirely inside the
padding are never executed: each grid cell owns ``chunks_per_block``
chunks and walks them with a ``fori_loop`` whose trip count is the
number of *valid* chunks in the cell — shapes stay bucket-static, only
runtime trip counts depend on the lengths.  ``chunks_per_block > 1``
also amortises grid dispatch over several chunks (fewer, fatter cells),
at the price of a K*Q-position VMEM block per operand.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, kvl_ref, y_ref,
                state_ref, *, chunk: int, chunks_per_block: int):
    g_idx = pl.program_id(2)
    Q = chunk
    K = chunks_per_block
    P = x_ref.shape[-1]
    N = b_ref.shape[-1]
    kvl = kvl_ref[0]                                        # true length
    base = g_idx * K                                        # first chunk here

    @pl.when(g_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    # chunks at or past the true length are skipped by trip count (their
    # outputs are padding); their y rows are pre-zeroed here
    valid = jnp.clip(pl.cdiv(kvl - base * Q, Q), 0, K)
    y_ref[...] = jnp.zeros_like(y_ref)

    A = a_ref[0]                                            # scalar decay rate

    def body(j, state):
        cs = (pl.dslice(0, 1), pl.dslice(0, 1), pl.dslice(j, 1))
        x = pl.load(x_ref, cs + (slice(None), slice(None)))[0, 0, 0]
        x = x.astype(jnp.float32)                           # (Q, P)
        dt = pl.load(dt_ref, cs + (slice(None),))[0, 0, 0]
        dt = dt.astype(jnp.float32)                         # (Q,)
        bc = (pl.dslice(0, 1), pl.dslice(j, 1))
        Bm = pl.load(b_ref, bc + (slice(None), slice(None)))[0, 0]
        Bm = Bm.astype(jnp.float32)                         # (Q, N)
        Cm = pl.load(c_ref, bc + (slice(None), slice(None)))[0, 0]
        Cm = Cm.astype(jnp.float32)                         # (Q, N)

        # zero the padded tail's dt: decay becomes exp(0)=1 and the state
        # update term dt*x*B vanishes, so padding never enters the state
        pos = ((base + j) * Q
               + jax.lax.broadcasted_iota(jnp.int32, (Q, 1), 0)[:, 0])
        dt = jnp.where(pos < kvl, dt, 0.0)

        dA = dt * A                                         # (Q,) log decay
        la = jnp.cumsum(dA)                                 # (Q,)

        # intra-chunk: L[i,j] = exp(la_i - la_j) * [i >= j]
        rel = la[:, None] - la[None, :]
        ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
        jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
        L = jnp.exp(jnp.where(ii >= jj, rel, -jnp.inf))     # (Q, Q)
        cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q, Q)
        w = cb * L * dt[None, :]
        y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)   # (Q, P)

        # inter-chunk: contribution of the carried state
        y += jnp.exp(la)[:, None] * jax.lax.dot_general(
            Cm, state, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # (Q, P)

        pl.store(y_ref, cs + (slice(None), slice(None)),
                 y.astype(y_ref.dtype)[None, None, None])

        # state update: S' = exp(sum dA) S + sum_j exp(la_Q - la_j) dt_j x_j B_j^T
        decay_to_end = jnp.exp(la[-1] - la)                 # (Q,)
        xb = jax.lax.dot_general(x * (decay_to_end * dt)[:, None], Bm,
                                 (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (P, N)
        return jnp.exp(la[-1]) * state + xb

    state0 = state_ref[...].astype(jnp.float32)             # (P, N)
    state_ref[...] = jax.lax.fori_loop(0, valid, body, state0)


def ssd_scan(x, dt, A, Bm, Cm, *, kv_len=None, chunk: int = 64,
             chunks_per_block: int = 1, interpret: bool = False):
    """x: (B, S, H, P); dt: (B, S, H); A: (H,); Bm, Cm: (B, S, N).

    Returns y: (B, S, H, P).  S must be a multiple of ``chunk *
    chunks_per_block`` (the ops wrapper pads to a chunk multiple and
    keeps ``chunks_per_block=1`` unless told otherwise).  ``kv_len``:
    optional (B,) int32 true lengths — state contributions past a
    sequence's length are zeroed and fully-padded chunks are never
    executed (dynamic trip counts).
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    K = int(chunks_per_block)
    assert S % (chunk * K) == 0, (S, chunk, K)
    NC = S // chunk
    if kv_len is None:
        kvl = jnp.full((B,), S, jnp.int32)
    else:
        kvl = jnp.clip(jnp.asarray(kv_len, jnp.int32), 0, S)

    xg = x.transpose(0, 2, 1, 3).reshape(B, H, NC, chunk, P)
    dtg = dt.transpose(0, 2, 1).reshape(B, H, NC, chunk)
    bg = Bm.reshape(B, NC, chunk, N)
    cg = Cm.reshape(B, NC, chunk, N)

    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk, chunks_per_block=K),
        grid=(B, H, NC // K),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, K, chunk, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, K, chunk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, K, chunk, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, K, chunk, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, K, chunk, P),
                               lambda b, h, c: (b, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, NC, chunk, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(A, xg, dtg, bg, cg, kvl)
    return y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
