"""Chunked Mamba2/SSD scan for TPU (Pallas, sequential-grid state carry).

TPU adaptation of the SSD algorithm [arXiv:2405.21060]: the chunk loop is
the *last* grid dimension with ``arbitrary`` semantics, so the recurrent
(P, N) state lives in a VMEM scratch buffer that persists across grid
steps — the TPU-idiomatic replacement for the CUDA warp-level scan.  The
intra-chunk work is two (Q, Q)-tile matmuls on the MXU; the inter-chunk
recurrence touches only the (P, N) state.

Layout: x (B, H, NC, Q, P); dt (B, H, NC, Q); Bm/Cm (B, NC, Q, N);
A (H,).  Grid: (B, H, NC) with NC sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk: int):
    c_idx = pl.program_id(2)
    Q = chunk
    P = x_ref.shape[-1]
    N = b_ref.shape[-1]

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    A = a_ref[0]                                            # scalar decay rate
    x = x_ref[0, 0, 0].astype(jnp.float32)                  # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)                # (Q,)
    Bm = b_ref[0, 0].astype(jnp.float32)                    # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)                    # (Q, N)

    dA = dt * A                                             # (Q,) log decay
    la = jnp.cumsum(dA)                                     # (Q,)

    # intra-chunk: L[i,j] = exp(la_i - la_j) * [i >= j]
    rel = la[:, None] - la[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.exp(jnp.where(ii >= jj, rel, -jnp.inf))         # (Q, Q)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    w = cb * L * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)

    # inter-chunk: contribution of the carried state
    state = state_ref[...].astype(jnp.float32)              # (P, N)
    y += jnp.exp(la)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (Q, P)

    # state update: S' = exp(sum dA) * S + sum_j exp(la_Q - la_j) dt_j x_j B_j^T
    decay_to_end = jnp.exp(la[-1] - la)                     # (Q,)
    xb = jax.lax.dot_general(x * (decay_to_end * dt)[:, None], Bm,
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (P, N)
    state_ref[...] = jnp.exp(la[-1]) * state + xb

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 64, interpret: bool = False):
    """x: (B, S, H, P); dt: (B, S, H); A: (H,); Bm, Cm: (B, S, N).

    Returns y: (B, S, H, P).  S must be a multiple of ``chunk`` (the ops
    wrapper pads).
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    NC = S // chunk

    xg = x.transpose(0, 2, 1, 3).reshape(B, H, NC, chunk, P)
    dtg = dt.transpose(0, 2, 1).reshape(B, H, NC, chunk)
    bg = Bm.reshape(B, NC, chunk, N)
    cg = Cm.reshape(B, NC, chunk, N)

    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(B, H, NC),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, 1, chunk, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, P),
                               lambda b, h, c: (b, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, NC, chunk, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(A, xg, dtg, bg, cg)
    return y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
