"""Deterministic synthetic open-loop serve traces (request arrivals).

An *open-loop* trace fixes every request's arrival timestamp up front —
arrivals do not wait for the server (the load a public endpoint sees),
so admission pressure is real: when the engine falls behind, the queue
grows.  Prompt lengths come from the same empirical length
distributions the training pipeline reproduces (``repro.data.pipeline``
— the paper's Fig. 3 input dynamics govern serving too: cache footprint
is dynamic per request), inter-arrival gaps are exponential (Poisson
arrivals), and everything derives from one seed, so bench and tests
share byte-identical traces.  ``tools/gen_trace.py`` is the CLI wrapper
that writes a trace as JSON.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.data.pipeline import DISTRIBUTIONS


@dataclasses.dataclass
class TraceRequest:
    """One serve request of an open-loop trace."""
    rid: int
    arrival_s: float
    prompt: np.ndarray           # (S,) int32 token ids, no padding
    max_new_tokens: int

    def to_json(self) -> dict:
        return {"rid": self.rid, "arrival_s": round(self.arrival_s, 6),
                "prompt": [int(t) for t in self.prompt],
                "max_new_tokens": int(self.max_new_tokens)}

    @classmethod
    def from_json(cls, rec: dict) -> "TraceRequest":
        return cls(rid=int(rec["rid"]), arrival_s=float(rec["arrival_s"]),
                   prompt=np.asarray(rec["prompt"], np.int32),
                   max_new_tokens=int(rec["max_new_tokens"]))


def gen_trace(*, num_requests: int, vocab_size: int,
              dataset: str = "swag", rate_rps: float = 8.0,
              max_new_tokens: int = 32, min_new_tokens: int = 0,
              prompt_scale: float = 1.0, seed: int = 0,
              ) -> List[TraceRequest]:
    """Deterministic open-loop trace.

    * prompt lengths ~ ``DISTRIBUTIONS[dataset]`` scaled by
      ``prompt_scale`` (CPU-sized runs shrink the paper distributions
      without losing their shape), floor 1 token;
    * arrivals: exponential inter-arrival at ``rate_rps`` requests/s
      (``rate_rps <= 0``: everything arrives at t=0 — a burst);
    * decode lengths: uniform in [min_new, max_new] when ``min_new_tokens``
      is set, else exactly ``max_new_tokens``;
    * tokens: uniform ids in [1, vocab) from the same generator.

    One ``seed`` determines the whole trace.
    """
    dist = DISTRIBUTIONS[dataset]
    rng = np.random.default_rng(seed)
    lens = dist.sample(rng, num_requests)
    lens = np.maximum((lens * float(prompt_scale)).astype(np.int64), 1)
    if rate_rps > 0:
        arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, num_requests))
    else:
        arrivals = np.zeros(num_requests)
    out: List[TraceRequest] = []
    for i in range(num_requests):
        new = (int(rng.integers(min_new_tokens, max_new_tokens + 1))
               if min_new_tokens else int(max_new_tokens))
        prompt = rng.integers(1, vocab_size, int(lens[i]),
                              dtype=np.int64).astype(np.int32)
        out.append(TraceRequest(rid=i, arrival_s=float(arrivals[i]),
                                prompt=prompt, max_new_tokens=max(new, 1)))
    return out
