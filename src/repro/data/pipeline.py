"""Synthetic data pipeline with *input-size dynamics* (paper §2.1, Fig. 3).

The whole point of Mimose is that real datasets produce mini-batches of
varying token counts.  We reproduce the three length distributions the
paper measures (Fig. 3) and the standard pad-to-bucket collation:

  * ``swag``  — multiple choice, lengths ~ N(88, 18) clipped to [35, 141]
  * ``squad`` — question answering, lengths ~ N(330, 60) clipped to [153, 512]
  * ``qqp``   — text classification, power-law in [30, 332]

Batches are padded up to a multiple of ``quantum`` tokens so that the
number of distinct compiled shapes (and Mimose plan-cache entries) stays
bounded, mirroring the paper's "similar sizes share plans" observation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# bucketing — the data-layer half of the compile-once execution engine
# ---------------------------------------------------------------------------

def bucket_length(max_len: int, quantum: int) -> int:
    """Smallest quantum multiple >= max_len (the batch's bucket seq-len)."""
    q = max(int(quantum), 1)
    return ((int(max_len) + q - 1) // q) * q


def bucket_edges(dist: "LengthDistribution", quantum: int) -> List[int]:
    """Every padded sequence length the distribution can produce.

    This is the engine's compile-count bound: batch geometry is always
    drawn from this fixed set, so the number of distinct (shape, plan)
    pairs — and therefore XLA compiles — is O(len(bucket_edges)), not
    O(#distinct raw lengths).
    """
    lo = bucket_length(dist.lo, quantum)
    hi = bucket_length(dist.hi, quantum)
    return list(range(lo, hi + 1, max(int(quantum), 1)))


def top_buckets(dataset: str, *, batch_size: int, quantum: int, k: int,
                seed: int = 0, samples: int = 256) -> List[Tuple[int, float]]:
    """The k most likely bucket seq-lens, with their empirical frequency.

    Used to pre-warm plan + jit caches off the critical path: compile the
    buckets that will actually occur before step 0 instead of eating the
    compile stall mid-training.
    """
    dist = DISTRIBUTIONS[dataset]
    rng = np.random.default_rng(seed)
    counts: Dict[int, int] = {}
    for _ in range(samples):
        lens = dist.sample(rng, batch_size)
        S = bucket_length(int(lens.max()), quantum)
        counts[S] = counts.get(S, 0) + 1
    ranked = sorted(counts.items(), key=lambda kv: (-kv[1], kv[0]))[:k]
    return [(S, c / samples) for S, c in ranked]


def pad_batch(batch: dict, quantum: int) -> dict:
    """Pad a ragged batch's sequence axis up to its bucket length.

    tokens/labels pad with 0 (the pad id), weights with 0.0 so the loss
    mask stays exact — the true ``lengths`` ride along untouched.  If
    ``weights`` is absent but ``lengths`` is present, exact weights are
    rebuilt from the true lengths.  Already-bucketed batches pass through
    unchanged.
    """
    q = max(int(quantum), 1)
    tokens = np.asarray(batch["tokens"])
    B, S = tokens.shape
    Sp = bucket_length(S, q)
    out = dict(batch)
    if "weights" not in out:
        if "lengths" in out:
            lens = np.asarray(out["lengths"])
            out["weights"] = (np.arange(S)[None, :]
                              < lens[:, None]).astype(np.float32)
        elif Sp != S:
            # weight-less batch about to grow a padded tail: materialise
            # the implicit all-ones mask over the REAL positions first,
            # otherwise the padding would enter the loss with weight 1
            out["weights"] = np.ones((B, S), np.float32)
    if Sp == S:
        return out
    pad = Sp - S
    for key in ("tokens", "labels", "weights"):
        if key in out:
            a = np.asarray(out[key])
            out[key] = np.pad(a, ((0, 0), (0, pad)))
    return out


@dataclasses.dataclass(frozen=True)
class LengthDistribution:
    name: str
    lo: int
    hi: int
    kind: str            # "normal" | "powerlaw" | "uniform"
    mean: float = 0.0
    std: float = 1.0
    alpha: float = 2.0   # power-law exponent

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        if self.kind == "normal":
            x = rng.normal(self.mean, self.std, n)
        elif self.kind == "powerlaw":
            u = rng.random(n)
            x = self.lo * (1 - u) ** (-1.0 / (self.alpha - 1.0))
        else:
            x = rng.uniform(self.lo, self.hi, n)
        return np.clip(np.round(x), self.lo, self.hi).astype(np.int32)


DISTRIBUTIONS: Dict[str, LengthDistribution] = {
    "swag": LengthDistribution("swag", 35, 141, "normal", mean=88, std=18),
    "squad": LengthDistribution("squad", 153, 512, "normal", mean=330, std=60),
    "qqp": LengthDistribution("qqp", 30, 332, "powerlaw", alpha=2.5),
    "fixed": LengthDistribution("fixed", 128, 128, "uniform"),
}


def make_batches(dataset: str, *, batch_size: int, vocab_size: int,
                 num_batches: int, quantum: int = 32,
                 seed: int = 0,
                 extra: Optional[dict] = None) -> Iterator[dict]:
    """Yield padded mini-batches with dynamic sequence lengths.

    Each batch dict has ``tokens`` (B, S), ``labels`` (B, S) (next-token),
    and ``weights`` (B, S) zeroing the padding — S varies across batches.
    """
    dist = DISTRIBUTIONS[dataset]
    rng = np.random.default_rng(seed)
    for _ in range(num_batches):
        lens = dist.sample(rng, batch_size)
        S = bucket_length(int(lens.max()), quantum)
        # learnable synthetic language: deterministic bigram successor
        # (token_{t+1} = a*token_t + c mod V) from a random start, so the
        # convergence benchmarks (paper Fig. 15) measure real learning.
        start = rng.integers(1, vocab_size, (batch_size, 1), dtype=np.int64)
        mult = 31 % (vocab_size - 1) or 1
        tokens = np.empty((batch_size, S), dtype=np.int64)
        tokens[:, 0] = start[:, 0]
        for t in range(1, S):
            tokens[:, t] = (tokens[:, t - 1] * mult + 7) % (vocab_size - 1) + 1
        tokens = tokens.astype(np.int32)
        weights = (np.arange(S)[None, :] < lens[:, None]).astype(np.float32)
        tokens = tokens * weights.astype(np.int32)          # pad id 0
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = 0
        batch = {"tokens": tokens, "labels": labels, "weights": weights,
                 "lengths": lens}
        if extra:
            batch.update({k: v(batch_size, S) for k, v in extra.items()})
        yield batch


def epoch_sizes(dataset: str, batch_size: int, num_batches: int,
                quantum: int = 32, seed: int = 0) -> np.ndarray:
    """Just the padded input sizes of an epoch (for distribution plots)."""
    return np.array([b["tokens"].size
                     for b in make_batches(dataset, batch_size=batch_size,
                                           vocab_size=100,
                                           num_batches=num_batches,
                                           quantum=quantum, seed=seed)])
