"""Bounded LRU cache for compiled executables and cached plans.

The trainer's jit-step cache and the planners' plan caches were both
unbounded dicts: under a long-tailed bucket distribution (qqp's
power-law lengths, or a multi-tenant server seeing many quanta) every
rare bucket pins a compiled XLA executable forever — a slow leak of
host *and* device memory.  ``LRUCache`` is the drop-in replacement:
dict-compatible for the operations those call sites use (``in``,
``[]``, ``.get``, ``len``, ``.clear``, iteration), evicting the least
recently *used* entry once ``maxsize`` is exceeded and counting
evictions so ``Trainer.cache_stats`` / ``planner.stats`` can report
churn.  Reads refresh recency (a hot bucket is never the victim).

Not thread-safe — the training loop is single-threaded, matching every
other cache in the engine.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Iterator


class LRUCache:
    """A dict with bounded size and least-recently-used eviction."""

    def __init__(self, maxsize: int):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = int(maxsize)
        self.evictions = 0
        self._data: "OrderedDict[Any, Any]" = OrderedDict()

    # -- dict protocol (the subset the engine's call sites use) ---------
    def __contains__(self, key) -> bool:
        return key in self._data

    def __getitem__(self, key):
        self._data.move_to_end(key)          # touch: reads refresh recency
        return self._data[key]

    def __setitem__(self, key, value):
        if key in self._data:
            self._data.move_to_end(key)
        self._data[key] = value
        while len(self._data) > self.maxsize:
            self._data.popitem(last=False)   # least recently used
            self.evictions += 1

    def __len__(self) -> int:
        return len(self._data)

    def __iter__(self) -> Iterator:
        return iter(self._data)

    def get(self, key, default=None):
        if key in self._data:
            return self[key]
        return default

    def keys(self):
        return self._data.keys()

    def pop(self, key, default=None):
        """Remove one entry (plan poisoning after an OOM): explicit
        invalidation, like ``clear``, does not count as an eviction."""
        return self._data.pop(key, default)

    def clear(self) -> None:
        """Drop every entry (stale-plan flush); evictions keep counting
        only capacity-driven removals, not explicit invalidation."""
        self._data.clear()
