"""Responsive memory scheduler — Algorithm 1 of the paper, plus the
cost-aware selection the heterogeneous-chains line of work (Beaumont et
al.; MONeT, Shah et al.) shows is needed to close the recompute gap.

Byte-only greedy (Algorithm 1) selects which units to rematerialise:

  1. Sort units by estimated activation bytes, descending.
  2. Group units whose estimate is within -10% of the bucket head into a
     bucket; sort each bucket by forward timestamp, ascending (earlier
     blocks are cheaper to recompute at the tail of the backward pass —
     paper Fig. 11).
  3. excess = sum(est) + fixed - budget.
  4. While excess > 0: among buckets whose max member covers the excess,
     pick the one nearest the excess and take its earliest layer;
     otherwise take the earliest layer of the largest bucket.

Cost-aware selection (the production default when a ``flops`` vector is
supplied) scores each unit by *bytes freed per recompute-FLOP* and picks
high-density units first, then trims picks the coverage does not need —
so a cheap MLP unit is rematerialised before a flash-attention unit that
frees the same bytes at many times the recompute FLOPs.  The result is
compared against the byte-only plan on total recompute FLOPs and the
better plan wins, so cost-aware selection is *never* worse than the
byte-only oracle at equal budget (the property
``tests/test_ragged.py::test_cost_aware_never_slower_than_byte_only``
locks in).

Implementations:

* ``greedy_plan`` — the production path.  Dispatches to cost-aware
  selection when ``flops`` is given (``byte_only=True`` keeps the
  Algorithm 1 oracle); the byte-only path keeps the vectorised
  flat-array bucket selection (one argsort + searchsorted jumps,
  per-bucket maxima via head pointers — O(n log n + picks * #buckets)).
* ``greedy_plan_reference`` — the seed's verbatim python-list
  implementation, kept as the equivalence oracle for tests and the
  baseline for ``benchmarks/bench_engine.py``.

Byte-only ``greedy_plan`` and the reference return bit-identical plans
(tie-breaks included); see
``tests/test_engine.py::test_fast_scheduler_matches_reference``.

Hybrid remat+offload selection: with ``offload_bytes`` (plus
``output_bytes`` and ``flops``) the plan grows a second reclamation
action — stream a unit's residuals to pinned host memory instead of
recomputing them.  Each (unit, action) candidate is scored by bytes
freed per cost-second, where remat cost = forward FLOPs / PEAK_FLOPS
and offload cost = the non-overlapped share of 2 x bytes / PCIe
bandwidth (``launch/roofline.py`` transfer model).  Candidate plans are
validated with the liveness simulator and the winner is the feasible
plan with the lowest simulated step overhead — the remat-only plan is
always among the candidates, so the hybrid result is *never worse at
equal budget* (and can fit budgets remat-only cannot: REMAT must keep
every unit's boundary tensor on device, OFFLOAD does not).

Adaptive microbatching: ``greedy_plan_adaptive`` extends the candidate
search to ``(k, action-plan)`` pairs — split the mini-batch into ``k``
microbatches with gradient accumulation, shrinking the batch-linear
activation terms by ~1/k while ``(k - 1) x accum_overhead_s`` of fixed
accumulation cost lands on the critical path.  Every candidate is
scored by simulated step overhead; ``k = 1`` always competes, so
enabling microbatching never loses at equal budget — and it fits
budgets below the global-minimum footprint of the bucket, which NO
``k = 1`` action plan (not even all-OFFLOAD) can reach.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.actions import Action, as_actions
from repro.launch.roofline import MICROBATCH_OVERHEAD_S, PCIE_BW, PEAK_FLOPS


@dataclasses.dataclass
class Plan:
    remat: List[bool]                 # bool view: True == REMAT (legacy)
    excess_bytes: float               # predicted overshoot before planning
    covered_bytes: float              # bytes the plan frees
    est_activation_bytes: float       # predicted total activation bytes
    n_remat: int = 0
    # total forward FLOPs the plan re-executes in the backward pass
    # (0.0 when planned without a cost model)
    recompute_flops: float = 0.0
    # typed per-unit plan; derived from ``remat`` when not given, and
    # the source of truth when it is (``remat`` then becomes the bool
    # view with OFFLOAD units reading False — they are not recomputed)
    actions: Optional[Tuple[Action, ...]] = None
    # one-way bytes the plan streams to host (0.0 without OFFLOAD units)
    offload_bytes: float = 0.0
    n_offload: int = 0
    # optimizer-moment bytes OFFLOAD_OPT units park on the host (ZeRO-
    # Offload style; reduces the FIXED footprint, not the residual side)
    opt_offload_bytes: float = 0.0
    n_opt: int = 0
    # gradient-accumulation split factor: execute the step as this many
    # sequential microbatches (1 = the plain full-batch step).  Chosen
    # jointly with the action plan by ``greedy_plan_adaptive``; when
    # > 1, the per-unit byte quantities above are PER-MICROBATCH while
    # ``recompute_flops`` / ``offload_bytes`` stay full-step totals.
    microbatch: int = 1
    # which tier produced the plan: "greedy" (density heuristic),
    # "escalated" (OOM-watchdog repair), or "dp" (background solver).
    # Rides snapshots so a restored cache keeps its provenance.
    source: str = "greedy"

    def __post_init__(self):
        if self.actions is None:
            self.actions = tuple(Action.REMAT if r else Action.KEEP
                                 for r in self.remat)
        else:
            self.actions = as_actions(self.actions)
            self.remat = [a is Action.REMAT for a in self.actions]
        self.n_remat = sum(1 for a in self.actions if a is Action.REMAT)
        self.n_offload = sum(1 for a in self.actions if a is Action.OFFLOAD)
        self.n_opt = sum(1 for a in self.actions
                         if a is Action.OFFLOAD_OPT)

    def as_tuple(self) -> Tuple[bool, ...]:
        """Legacy bool view (True == REMAT).  Equals the old boolean
        semantics exactly when the plan has no OFFLOAD unit."""
        return tuple(self.remat)

    def as_actions(self) -> Tuple[Action, ...]:
        """The typed plan — what planners hand to ``lm.loss`` now."""
        return self.actions

    def with_flops(self, flops) -> "Plan":
        """Fill ``recompute_flops`` from a per-unit FLOPs vector."""
        f = np.asarray(flops, dtype=np.float64)
        self.recompute_flops = float(f[np.asarray(self.remat, bool)].sum())
        return self


def _bucket_bounds(desc: np.ndarray, tol: float) -> np.ndarray:
    """Bucket boundaries over a descending estimate array.

    Values below a head's tolerance band form a suffix of the sorted
    array, so each boundary is one ``searchsorted`` jump — O(#buckets
    log n) instead of the per-member python walk.
    """
    n = desc.size
    asc = -desc                              # ascending view for searchsorted
    bounds = [0]
    i = 0
    while i < n:
        # first j with desc[j] <= head * (1 - tol): strict '>' keeps a unit
        # in the bucket, matching the reference comparison
        j = int(np.searchsorted(asc, -desc[i] * (1.0 - tol), side="left"))
        j = max(j, i + 1)
        bounds.append(j)
        i = j
    return np.asarray(bounds, dtype=np.int64)


def build_buckets(est_mem: Sequence[float], tol: float = 0.10
                  ) -> List[List[int]]:
    """Bucket unit indices by similar estimated memory (paper lines 2-14)."""
    est = np.asarray(est_mem, dtype=np.float64)
    if est.size == 0:
        return []
    order = np.argsort(-est, kind="stable")
    bounds = _bucket_bounds(est[order], tol)
    return [np.sort(order[s:e]).tolist()            # timestamp ascending
            for s, e in zip(bounds[:-1], bounds[1:])]


@dataclasses.dataclass(frozen=True)
class ActionTables:
    """Per-unit quantities every action-aware planner tier works from.

    One construction shared by the density greedy (``_hybrid_plan``),
    the DTR-style escalation ladder (``escalate_plan``) and the exact
    DP solver (``repro.core.solver``), so the three tiers price
    KEEP/REMAT/OFFLOAD identically: remat cost = forward FLOPs /
    ``PEAK_FLOPS``, offload cost = the non-overlapped share of the
    round-trip PCIe transfer, freed bytes per the simulator's liveness
    model (REMAT keeps the boundary tensor, OFFLOAD evicts the
    offloadable bytes outright).  ``off`` is pre-clipped to
    ``[0, est]`` exactly as ``simulate`` clips it.
    """
    est: np.ndarray        # per-unit activation bytes
    out: np.ndarray        # per-unit boundary-tensor bytes
    off: np.ndarray        # per-unit offloadable bytes, clipped to [0, est]
    fl: np.ndarray         # per-unit forward FLOPs
    t_re: np.ndarray       # per-unit recompute seconds (REMAT cost)
    t_off: np.ndarray      # per-unit exposed transfer seconds (OFFLOAD cost)
    freed_re: np.ndarray   # bytes REMAT frees: max(est - out, 0)
    freed_off: np.ndarray  # bytes OFFLOAD frees: off
    # OFFLOAD_OPT tables (appended with defaults for back-compat with
    # positional 3-action constructions; ``action_tables`` always fills
    # them).  ``t_opt`` is per STEP — the optimizer runs once per step,
    # so unlike ``t_off`` it never scales with the microbatch split.
    opt: np.ndarray = None        # per-unit optimizer-moment bytes
    t_opt: np.ndarray = None      # per-unit exposed opt round-trip seconds
    freed_opt: np.ndarray = None  # fixed bytes OFFLOAD_OPT frees: opt


def action_tables(est_mem, output_bytes=None, offload_bytes=None,
                  flops=None, *, opt_bytes=None,
                  pcie_bytes_per_s: float = PCIE_BW,
                  offload_overlap: float = 0.5) -> ActionTables:
    """Build the shared per-unit cost/freed tables (missing vectors
    default to zeros, which disables the corresponding action)."""
    est = np.asarray(est_mem, dtype=np.float64)
    n = est.size
    out = (np.asarray(output_bytes, dtype=np.float64)
           if output_bytes is not None else np.zeros(n))
    fl = (np.asarray(flops, dtype=np.float64)
          if flops is not None else np.zeros(n))
    off = (np.clip(np.asarray(offload_bytes, dtype=np.float64), 0.0, est)
           if offload_bytes is not None else np.zeros(n))
    opt = (np.maximum(np.asarray(opt_bytes, dtype=np.float64), 0.0)
           if opt_bytes is not None else np.zeros(n))
    assert est.shape == out.shape == off.shape == fl.shape == opt.shape, \
        (est.shape, out.shape, off.shape, fl.shape, opt.shape)
    t_re = fl / PEAK_FLOPS
    hidden = max(0.0, min(1.0, 1.0 - offload_overlap))
    t_off = 2.0 * off / float(pcie_bytes_per_s) * hidden
    t_opt = 2.0 * opt / float(pcie_bytes_per_s) * hidden
    return ActionTables(est=est, out=out, off=off, fl=fl, t_re=t_re,
                        t_off=t_off,
                        freed_re=np.maximum(est - out, 0.0),
                        freed_off=off,
                        opt=opt, t_opt=t_opt, freed_opt=opt)


def action_candidates(tables: ActionTables,
                      allow_offload: bool = True) -> List[tuple]:
    """(density, unit, action-code) triples, best density first; ties
    break to earlier timestamps (the paper's earlier-is-cheaper
    preference), then REMAT before OFFLOAD.  The same enumeration
    orders the greedy walk, the escalation ladder, and the solver's
    DP transitions."""
    cand = []
    for i in range(tables.est.size):
        if tables.freed_re[i] > 0:
            cand.append((tables.freed_re[i] / max(tables.t_re[i], 1e-12),
                         i, 1))
        if allow_offload and tables.freed_off[i] > 0:
            cand.append((tables.freed_off[i] / max(tables.t_off[i], 1e-12),
                         i, 2))
        if (allow_offload and tables.freed_opt is not None
                and tables.freed_opt[i] > 0):
            cand.append((tables.freed_opt[i] / max(tables.t_opt[i], 1e-12),
                         i, 3))
    cand.sort(key=lambda c: (-c[0], c[1], c[2]))
    return cand


def greedy_plan(est_mem: Sequence[float], budget_bytes: float,
                fixed_bytes: float = 0.0, tol: float = 0.10, *,
                flops: Sequence[float] | None = None,
                byte_only: bool = False,
                output_bytes: Sequence[float] | None = None,
                offload_bytes: Sequence[float] | None = None,
                opt_bytes: Sequence[float] | None = None,
                pcie_bytes_per_s: float = PCIE_BW,
                offload_overlap: float = 0.5) -> Plan:
    """Plan which units to rematerialise/offload under ``budget_bytes``.

    est_mem[i] = predicted activation bytes of unit i.  With ``flops``
    (per-unit forward FLOPs, e.g. ``roofline.plan_unit_flops``) the
    selection is cost-aware — maximise bytes freed per recompute-FLOP —
    and provably no worse than Algorithm 1 on recompute FLOPs at equal
    budget.  ``byte_only=True`` (or ``flops=None``) runs the paper's
    byte-only Algorithm 1 unchanged (the oracle the benchmark compares
    against); when ``flops`` is also given the oracle plan's
    ``recompute_flops`` is still filled in for comparison.

    With ``offload_bytes`` (per-unit offloadable residual bytes, e.g.
    ``CollectionResult.offloadable_vector``) and ``output_bytes``
    (per-unit boundary-tensor bytes) the plan additionally considers
    OFFLOAD-to-host per unit, priced at the PCIe link
    (``pcie_bytes_per_s``, with ``offload_overlap`` of the traffic
    hidden under compute).  The returned plan is the candidate with the
    lowest simulated step overhead among those that fit the budget —
    the remat-only plan always competes, so hybrid is never worse at
    equal budget.  Requires ``flops`` (and is skipped by
    ``byte_only=True``).

    ``opt_bytes`` (per-unit optimizer-moment bytes, e.g.
    ``CollectionResult.opt_vector``) additionally enables OFFLOAD_OPT —
    parking a unit's moments on the host, which shrinks the fixed
    footprint at one per-step round trip of the moment bytes.
    """
    if (offload_bytes is not None and flops is not None
            and not byte_only):
        return _hybrid_plan(est_mem, output_bytes, offload_bytes, flops,
                            budget_bytes, fixed_bytes, tol,
                            pcie_bytes_per_s, offload_overlap,
                            opt_bytes=opt_bytes)
    if flops is not None and not byte_only:
        return _cost_aware_plan(est_mem, flops, budget_bytes, fixed_bytes,
                                tol)
    plan = _byte_greedy_plan(est_mem, budget_bytes, fixed_bytes, tol)
    return plan.with_flops(flops) if flops is not None else plan


def _hybrid_plan(est_mem, output_bytes, offload_bytes, flops,
                 budget_bytes: float, fixed_bytes: float, tol: float,
                 pcie: float, overlap: float, *,
                 opt_bytes=None) -> Plan:
    """Action-aware density greedy: score every (unit, action) candidate
    by bytes freed per cost-second, validate the resulting plans with
    the liveness simulator, and return the feasible plan with the
    lowest simulated step overhead (min peak when nothing fits).

    Freed-byte accounting follows the simulator's liveness model: REMAT
    frees ``est - out`` (the boundary tensor must stay on device as the
    recompute checkpoint), OFFLOAD frees the offloadable bytes outright
    (the residue ``est - off`` stays).  That asymmetry is what lets a
    hybrid plan fit budgets below the all-remat floor.
    """
    from repro.core.simulator import simulate

    tabs = action_tables(est_mem, output_bytes, offload_bytes, flops,
                         opt_bytes=opt_bytes,
                         pcie_bytes_per_s=pcie, offload_overlap=overlap)
    est, out, off, fl = tabs.est, tabs.out, tabs.off, tabs.fl
    freed_re, freed_off = tabs.freed_re, tabs.freed_off
    opt, freed_opt = tabs.opt, tabs.freed_opt
    n = est.size
    total = float(est.sum())
    excess = total + float(fixed_bytes) - float(budget_bytes)
    if n == 0:
        return Plan([], excess, 0.0, total)
    freed_of_code = {1: freed_re, 2: freed_off, 3: freed_opt}

    def density_greedy(allow_offload: bool) -> Plan:
        actions = [Action.KEEP] * n
        freed_by = [0.0] * n
        covered = 0.0
        picks: List[int] = []
        for _, i, code in action_candidates(tabs, allow_offload):
            if covered >= excess:
                break
            if actions[i] is not Action.KEEP:
                continue
            actions[i] = Action(code)
            freed_by[i] = freed_of_code[code][i]
            covered += freed_by[i]
            picks.append(i)
        # trim: drop the worst-density picks the coverage does not need
        for i in reversed(picks):
            if covered - freed_by[i] >= excess:
                covered -= freed_by[i]
                actions[i] = Action.KEEP
                freed_by[i] = 0.0
        return finish(actions)

    def finish(actions) -> Plan:
        arr = np.array([int(a) for a in actions])
        covered = float(freed_re[arr == 1].sum()
                        + freed_off[arr == 2].sum()
                        + freed_opt[arr == 3].sum())
        plan = Plan([], excess, covered, total, actions=tuple(actions))
        plan.recompute_flops = float(fl[arr == 1].sum())
        plan.offload_bytes = float(off[arr == 2].sum())
        plan.opt_offload_bytes = float(opt[arr == 3].sum())
        return plan

    def replay(plan: Plan):
        return simulate(est, plan.actions, fixed_bytes, out, fl,
                        offload_bytes=off, opt_bytes=opt,
                        pcie_bytes_per_s=pcie, overlap=overlap)

    def escalate(plan: Plan) -> Plan:
        """Repair against the liveness replay: the byte bookkeeping
        ignores transient working sets, and nothing below the all-remat
        floor is reachable without OFFLOAD evicting the boundary
        checkpoints.  Delegates to the module-level ``escalate_plan``
        (shared with the OOM watchdog's DTR-style recovery ladder)."""
        return escalate_plan(plan.actions, est, fl, budget_bytes,
                             fixed_bytes, output_bytes=out,
                             offload_bytes=off, opt_bytes=opt,
                             pcie_bytes_per_s=pcie,
                             offload_overlap=overlap)

    # candidates: hybrid density greedy (plus its replay-repaired
    # escalation), remat-only under the same liveness accounting, and
    # the legacy cost-aware remat plan (itself floored by the byte-only
    # oracle).  The winner is the feasible candidate with the lowest
    # simulated step overhead — remat-only always competes, so hybrid
    # is never worse at equal budget; ties prefer fewer offloads.
    hyb = density_greedy(True)
    cands = [hyb, escalate(hyb), density_greedy(False),
             _cost_aware_plan(est, fl, budget_bytes, fixed_bytes, tol)]
    sims = [replay(p) for p in cands]
    fits = [s.peak_bytes <= budget_bytes + 1e-6 for s in sims]
    if any(fits):
        best = min((i for i in range(len(cands)) if fits[i]),
                   key=lambda i: (sims[i].step_overhead_s,
                                  cands[i].n_offload + cands[i].n_opt))
    else:
        best = min(range(len(cands)), key=lambda i: sims[i].peak_bytes)
    return cands[best]


def escalate_plan(actions, est_mem, flops, budget_bytes: float,
                  fixed_bytes: float = 0.0, *,
                  output_bytes: Sequence[float] | None = None,
                  offload_bytes: Sequence[float] | None = None,
                  opt_bytes: Sequence[float] | None = None,
                  pcie_bytes_per_s: float = PCIE_BW,
                  offload_overlap: float = 0.5) -> Plan:
    """DTR-style escalation of an existing action plan.

    Starting from ``actions`` (a typed tuple, bool mask, or ``None`` for
    all-KEEP), walk every (unit, action) candidate in bytes-freed-per-
    cost-second density order and upgrade one rung at a time — KEEP ->
    REMAT (or OFFLOAD when that is the denser move), REMAT -> OFFLOAD —
    until the liveness replay of the plan fits ``budget_bytes``.  The
    walk is the recovery policy Dynamic Tensor Rematerialization applies
    when reality contradicts the plan: evict more, cheapest first,
    rather than die.  Used in two places: ``_hybrid_plan`` repairs its
    density-greedy candidate with it, and the OOM watchdog
    (``repro.train.resilience``) escalates a bucket's cached plan after
    a RESOURCE_EXHAUSTED step.  Returns the (possibly still infeasible —
    callers decide what to do when even all-OFFLOAD cannot fit) plan
    with full byte/FLOP accounting stamped.
    """
    from repro.core.simulator import simulate

    tabs = action_tables(est_mem, output_bytes, offload_bytes, flops,
                         opt_bytes=opt_bytes,
                         pcie_bytes_per_s=pcie_bytes_per_s,
                         offload_overlap=offload_overlap)
    est, out, off, fl = tabs.est, tabs.out, tabs.off, tabs.fl
    freed_re, freed_off = tabs.freed_re, tabs.freed_off
    opt, freed_opt = tabs.opt, tabs.freed_opt
    n = est.size
    total = float(est.sum())
    excess = total + float(fixed_bytes) - float(budget_bytes)
    cand = action_candidates(tabs, allow_offload=True)

    def finish(acts) -> Plan:
        arr = np.array([int(a) for a in acts], dtype=np.int64)
        covered = float(freed_re[arr == 1].sum() + freed_off[arr == 2].sum()
                        + freed_opt[arr == 3].sum())
        plan = Plan([], excess, covered, total, actions=tuple(acts))
        plan.recompute_flops = float(fl[arr == 1].sum())
        plan.offload_bytes = float(off[arr == 2].sum())
        plan.opt_offload_bytes = float(opt[arr == 3].sum())
        return plan

    acts = (list(as_actions(actions)) if actions is not None
            else [Action.KEEP] * n)
    assert len(acts) == n, (len(acts), n)
    for _, i, code in cand:
        peak = simulate(est, tuple(acts), fixed_bytes, out, fl,
                        offload_bytes=off, opt_bytes=opt,
                        pcie_bytes_per_s=pcie_bytes_per_s,
                        overlap=offload_overlap).peak_bytes
        if peak <= budget_bytes:
            break
        if code == 1 and acts[i] is Action.KEEP:
            acts[i] = Action.REMAT
        elif code == 2 and acts[i] in (Action.KEEP, Action.REMAT):
            # upgrade rung — but never downgrade an OFFLOAD_OPT unit:
            # its freed fixed bytes would come back, raising the peak
            acts[i] = Action.OFFLOAD
        elif code == 3 and acts[i] is Action.KEEP:
            acts[i] = Action.OFFLOAD_OPT
    return finish(acts)


def _cost_aware_plan(est_mem: Sequence[float], flops: Sequence[float],
                     budget_bytes: float, fixed_bytes: float,
                     tol: float) -> Plan:
    """Bytes-per-recompute-FLOP greedy with a trim pass, floored by the
    byte-only oracle (whichever plan recomputes fewer FLOPs wins)."""
    est = np.asarray(est_mem, dtype=np.float64)
    fl = np.asarray(flops, dtype=np.float64)
    assert est.shape == fl.shape, (est.shape, fl.shape)
    n = est.size
    total = float(est.sum())
    excess = total + float(fixed_bytes) - float(budget_bytes)
    if excess <= 0 or n == 0:
        return Plan([False] * n, excess, 0.0, total)

    # 1. pick in descending bytes-per-FLOP density until the excess is
    # covered (ties: earlier timestamp first, matching the paper's
    # earlier-is-cheaper-at-backward-tail preference)
    density = est / np.maximum(fl, 1.0)
    order = np.argsort(-density, kind="stable")
    csum = np.cumsum(est[order])
    k = int(np.searchsorted(csum, excess, side="left")) + 1
    k = min(k, n)
    picked = order[:k]
    covered = float(csum[k - 1])

    # 2. trim: coverage is often overshot — drop the worst-density picks
    # whose bytes the plan does not need, cheapest-to-keep last
    keep = np.ones(k, dtype=bool)
    for j in range(k - 1, -1, -1):          # order[:k] is best->worst
        b = est[picked[j]]
        if covered - b >= excess:
            keep[j] = False
            covered -= b
    picked = picked[keep]

    plan = [False] * n
    for i in picked:
        plan[int(i)] = True
    cost = Plan(plan, excess, covered, total)
    cost.recompute_flops = float(fl[picked].sum())

    # 3. the byte-only oracle floor: never return a plan that recomputes
    # more FLOPs than Algorithm 1 would at the same budget
    byte = _byte_greedy_plan(est, budget_bytes, fixed_bytes,
                             tol).with_flops(fl)
    if (byte.covered_bytes >= excess) == (cost.covered_bytes >= excess) \
            and byte.recompute_flops < cost.recompute_flops:
        return byte
    return cost


def _byte_greedy_plan(est_mem: Sequence[float], budget_bytes: float,
                      fixed_bytes: float = 0.0, tol: float = 0.10) -> Plan:
    """Algorithm 1 (byte-only).  est_mem[i] = predicted bytes of unit i."""
    est = np.asarray(est_mem, dtype=np.float64)
    n = est.size
    total = float(est.sum())
    excess = total + float(fixed_bytes) - float(budget_bytes)
    plan = [False] * n
    if excess <= 0 or n == 0:
        return Plan(plan, excess, 0.0, total)

    order = np.argsort(-est, kind="stable")
    desc = est[order]
    bounds = _bucket_bounds(desc, tol)
    nb = bounds.size - 1
    starts, ends = bounds[:-1], bounds[1:]
    # All bucket state lives in flat arrays indexed by *sorted position*
    # (no per-bucket python objects — with near-unique estimates most
    # buckets are singletons and per-bucket allocation dominates):
    #   ts_flat  — unit ids grouped by bucket, timestamp-ascending within
    #   ts_ptr   — per bucket, next timestamp pick (pop-front cursor)
    #   alive    — per sorted position, unit not yet rematerialised
    #   heads    — per bucket, sorted position of its current max
    bid = np.repeat(np.arange(nb), np.diff(bounds))
    ts_flat = order[np.lexsort((order, bid))]
    ts_ptr = starts.copy()
    pos_of = np.empty(n, dtype=np.int64)
    pos_of[order] = np.arange(n)
    alive = np.ones(n, dtype=bool)
    heads = starts.copy()
    bmax = desc[starts].copy()

    remaining = excess
    covered = 0.0
    n_alive = n
    while remaining > 0 and n_alive > 0:
        cand = bmax > remaining
        if cand.any():
            # nearest above the excess (paper line 21: candidates.top());
            # argmin over the +inf-masked array keeps the reference
            # tie-break (first bucket in construction order wins)
            b = int(np.argmin(np.where(cand, bmax, np.inf)))
        else:
            # largest activation as soon as possible (paper line 19)
            b = int(np.argmax(bmax))
        pick = int(ts_flat[ts_ptr[b]])
        ts_ptr[b] += 1
        plan[pick] = True
        remaining -= est[pick]
        covered += est[pick]
        n_alive -= 1
        # retire the pick and advance the bucket's max pointer past dead
        # slots (amortised O(n) over the whole plan)
        alive[pos_of[pick]] = False
        h, e = int(heads[b]), int(ends[b])
        while h < e and not alive[h]:
            h += 1
        heads[b] = h
        bmax[b] = desc[h] if h < e else -np.inf
    return Plan(plan, excess, covered, total)


def greedy_plan_sharded(device_est_mem: Sequence[float], mesh_budget,
                        fixed_device_bytes: float = 0.0,
                        tol: float = 0.10, *,
                        flops: Sequence[float] | None = None,
                        byte_only: bool = False,
                        output_bytes: Sequence[float] | None = None,
                        offload_bytes: Sequence[float] | None = None,
                        opt_bytes: Sequence[float] | None = None,
                        pcie_bytes_per_s: float = PCIE_BW,
                        offload_overlap: float = 0.5) -> Plan:
    """``greedy_plan`` against a *per-device* budget.

    ``device_est_mem[i]`` must be the bytes unit i lands on ONE device
    (``CollectionResult.device_activation_vector`` or a per-device
    estimator fit) and ``fixed_device_bytes`` the param/grad/optimizer
    *shard* bytes (``budget.fixed_train_bytes_per_device``).  The budget
    is ``mesh_budget.hbm_per_device_bytes`` — under SPMD every device
    runs the same plan over its shard, so one per-device schedule covers
    the whole mesh.  ``mesh_budget`` is duck-typed (anything with an
    ``hbm_per_device_bytes`` attribute) to keep this module numpy-only.
    ``flops`` may stay the *global* per-unit FLOPs vector: SPMD divides
    every unit's recompute by the same device count, so relative
    densities — and therefore the selection — are unchanged.
    ``output_bytes`` / ``offload_bytes`` must be per-device vectors
    (each chip streams its own shard over its own host link).
    """
    return greedy_plan(device_est_mem, mesh_budget.hbm_per_device_bytes,
                       fixed_device_bytes, tol=tol, flops=flops,
                       byte_only=byte_only, output_bytes=output_bytes,
                       offload_bytes=offload_bytes, opt_bytes=opt_bytes,
                       pcie_bytes_per_s=pcie_bytes_per_s,
                       offload_overlap=offload_overlap)


def greedy_plan_adaptive(vectors_of_k, budget_bytes: float,
                         fixed_bytes: float = 0.0, *,
                         max_microbatches: int = 1,
                         candidate_ks: Optional[Sequence[int]] = None,
                         tol: float = 0.10,
                         byte_only: bool = False,
                         pcie_bytes_per_s: float = PCIE_BW,
                         offload_overlap: float = 0.5,
                         accum_overhead_s: float = MICROBATCH_OVERHEAD_S
                         ) -> Plan:
    """Joint (microbatch factor, action plan) selection.

    ``vectors_of_k(k)`` must return the *per-microbatch* planning
    vectors at split factor ``k`` as a dict with ``est_mem`` (required)
    and optional ``flops`` / ``output_bytes`` / ``offload_bytes`` —
    typically the PolyEstimator predictions at input size ``s/k`` (the
    per-unit fits capture the non-batch-linear terms plain division
    would miss) — plus an optional ``pad_overhead_s`` scalar: extra
    per-step time the split wastes outside the simulator's model (the
    planner charges the batch-axis pad rows a non-divisor ``k``
    computes over, ``ceil(B/k)*k - B`` extra full rows).  For each
    candidate ``k`` (``candidate_ks`` or ``1..max_microbatches``) the
    per-unit action plan is chosen by ``greedy_plan`` against the same
    budget (fixed bytes are resident regardless of the split), then
    replayed by the liveness simulator with ``microbatch=k``; the
    winner is the feasible candidate with the lowest simulated step
    overhead (recompute + exposed transfer + ``(k - 1) *
    accum_overhead_s`` + ``pad_overhead_s``), ties preferring smaller
    ``k``.
    When nothing fits, the candidate with the lowest replayed peak
    wins.  ``k = 1`` always competes, so the adaptive plan is *never
    worse at equal budget* than the plain planner — and it can fit
    budgets below the bucket's global-minimum ``k = 1`` footprint.
    """
    from repro.core.simulator import simulate

    ks = sorted(set(int(k) for k in
                    (candidate_ks if candidate_ks is not None
                     else range(1, max(int(max_microbatches), 1) + 1))))
    assert ks and ks[0] >= 1, ks

    def plan_at(k: int):
        v = vectors_of_k(k)
        plan = greedy_plan(v["est_mem"], budget_bytes, fixed_bytes,
                           tol=tol, flops=v.get("flops"),
                           byte_only=byte_only,
                           output_bytes=v.get("output_bytes"),
                           offload_bytes=v.get("offload_bytes"),
                           opt_bytes=v.get("opt_bytes"),
                           pcie_bytes_per_s=pcie_bytes_per_s,
                           offload_overlap=offload_overlap)
        plan.microbatch = k
        sim = simulate(v["est_mem"], plan.actions, fixed_bytes,
                       v.get("output_bytes"), v.get("flops"),
                       offload_bytes=v.get("offload_bytes"),
                       opt_bytes=v.get("opt_bytes"),
                       pcie_bytes_per_s=pcie_bytes_per_s,
                       overlap=offload_overlap, microbatch=k,
                       accum_overhead_s=accum_overhead_s)
        # stamp full-step totals (greedy_plan filled per-microbatch)
        plan.recompute_flops = sim.recompute_flops
        plan.offload_bytes = sim.offload_bytes
        return plan, sim, float(v.get("pad_overhead_s", 0.0))

    if len(ks) == 1 and ks[0] == 1:
        # fast path: no search, bit-identical to the plain scheduler
        return plan_at(1)[0]
    cands = [plan_at(k) for k in ks]
    fits = [s.peak_bytes <= budget_bytes + 1e-6 for _, s, _ in cands]
    if any(fits):
        best = min((i for i in range(len(cands)) if fits[i]),
                   key=lambda i: (cands[i][1].step_overhead_s
                                  + cands[i][2],
                                  cands[i][0].microbatch))
    else:
        best = min(range(len(cands)), key=lambda i: cands[i][1].peak_bytes)
    return cands[best][0]


def greedy_plan_reference(est_mem: Sequence[float], budget_bytes: float,
                          fixed_bytes: float = 0.0, tol: float = 0.10) -> Plan:
    """The seed's python-list Algorithm 1 — equivalence oracle and the
    baseline the engine benchmark measures ``greedy_plan`` against."""
    est = [float(m) for m in est_mem]
    total = sum(est)
    excess = total + fixed_bytes - budget_bytes
    plan = [False] * len(est)
    if excess <= 0:
        return Plan(plan, excess, 0.0, total)

    # the seed's own sort-and-walk bucketing, deliberately NOT shared
    # with the vectorised build_buckets: the oracle must stay independent
    # so the equivalence test can catch a bucketing bug in the fast path
    order = sorted(range(len(est)), key=lambda i: -est[i])
    buckets: List[List[int]] = []
    i = 0
    while i < len(order):
        head = order[i]
        bucket = [head]
        j = i + 1
        while j < len(order) and est[order[j]] > est[head] * (1 - tol):
            bucket.append(order[j])
            j += 1
        bucket.sort()                       # timestamp ascending
        buckets.append(bucket)
        i = j
    remaining = excess
    covered = 0.0
    while remaining > 0 and any(buckets):
        # buckets whose largest member alone covers the remaining excess
        candidates = [b for b in buckets if b and max(est[i] for i in b) > remaining]
        if candidates:
            bucket = min(candidates, key=lambda b: max(est[i] for i in b))
        else:
            bucket = max((b for b in buckets if b),
                         key=lambda b: max(est[i] for i in b))
        pick = bucket[0]                    # earliest timestamp in the bucket
        bucket.remove(pick)
        plan[pick] = True
        remaining -= est[pick]
        covered += est[pick]
        buckets = [b for b in buckets if b]
    return Plan(plan, excess, covered, total)
