"""Responsive memory scheduler — Algorithm 1 of the paper, verbatim.

Greedy bucketed selection of which plan units to rematerialise:

  1. Sort units by estimated activation bytes, descending.
  2. Group units whose estimate is within -10% of the bucket head into a
     bucket; sort each bucket by forward timestamp, ascending (earlier
     blocks are cheaper to recompute at the tail of the backward pass —
     paper Fig. 11).
  3. excess = sum(est) + fixed - budget.
  4. While excess > 0: among buckets whose max member covers the excess,
     pick the one nearest the excess and take its earliest layer;
     otherwise take the earliest layer of the largest bucket.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Plan:
    remat: List[bool]                 # per plan-unit, timestamp order
    excess_bytes: float               # predicted overshoot before planning
    covered_bytes: float              # bytes the plan frees
    est_activation_bytes: float       # predicted total activation bytes
    n_remat: int = 0

    def __post_init__(self):
        self.n_remat = int(sum(self.remat))

    def as_tuple(self) -> Tuple[bool, ...]:
        return tuple(self.remat)


def build_buckets(est_mem: Sequence[float], tol: float = 0.10
                  ) -> List[List[int]]:
    """Bucket unit indices by similar estimated memory (paper lines 2-14)."""
    order = sorted(range(len(est_mem)), key=lambda i: -est_mem[i])
    buckets: List[List[int]] = []
    i = 0
    while i < len(order):
        head = order[i]
        bucket = [head]
        j = i + 1
        while j < len(order) and est_mem[order[j]] > est_mem[head] * (1 - tol):
            bucket.append(order[j])
            j += 1
        bucket.sort()                       # timestamp ascending
        buckets.append(bucket)
        i = j
    return buckets


def greedy_plan(est_mem: Sequence[float], budget_bytes: float,
                fixed_bytes: float = 0.0, tol: float = 0.10) -> Plan:
    """Algorithm 1.  est_mem[i] = predicted activation bytes of unit i."""
    est = [float(m) for m in est_mem]
    total = sum(est)
    excess = total + fixed_bytes - budget_bytes
    plan = [False] * len(est)
    if excess <= 0:
        return Plan(plan, excess, 0.0, total)

    buckets = build_buckets(est, tol)
    remaining = excess
    covered = 0.0
    while remaining > 0 and any(buckets):
        # buckets whose largest member alone covers the remaining excess
        candidates = [b for b in buckets if b and max(est[i] for i in b) > remaining]
        if candidates:
            # nearest above the excess (paper line 21: candidates.top())
            bucket = min(candidates, key=lambda b: max(est[i] for i in b))
        else:
            # largest activation as soon as possible (paper line 19)
            bucket = max((b for b in buckets if b),
                         key=lambda b: max(est[i] for i in b))
        pick = bucket[0]                    # earliest timestamp in the bucket
        bucket.remove(pick)
        plan[pick] = True
        remaining -= est[pick]
        covered += est[pick]
        buckets = [b for b in buckets if b]
    return Plan(plan, excess, covered, total)
