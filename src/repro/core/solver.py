"""Exact (microbatch k, action) assignment solver — the optimal-plan
tier (ROADMAP item 3).

The density greedy in ``core/scheduler.py`` approximates the joint
(k, KEEP/REMAT/OFFLOAD) assignment; Checkmate (arXiv 2010.14501) and
"Optimal checkpointing for heterogeneous chains" (arXiv 1911.13214)
solve it exactly.  This module closes the gap without giving up
Mimose's online property: greedy still serves the first steps of a new
bucket instantly, while ``BackgroundSolver`` (a daemon thread with a
bounded work queue, one in-flight solve per plan-cache key) runs
``solve()`` and atomically swaps a strictly better plan into the
planner's LRU cache — the trainer picks it up on the next cache hit,
recompiling at most the bucket it replaces (the jit-step key already
covers the action tuple and ``k``).

``solve()`` is exact because the liveness simulator's peak decomposes
per unit.  With ``c_j`` the forward contribution of unit j under its
action (KEEP ``act``, REMAT ``out``, OFFLOAD ``act - off``) and
``restore_j`` the backward restore (0 / ``act`` / ``off``), the
simulator's maxima are:

* forward transient at i:  ``fixed + sum_{j<i} c_j + act_i + out_i``
* end of forward:          ``fixed + sum_j c_j``
* backward at i:           ``fixed + sum_{j<=i} c_j
  + sum_{j>i, REMAT} out_j + restore_i + act_i``

(the backward identity follows from ``c_j + restore_j - act_j`` being 0
for KEEP/OFFLOAD and ``out_j`` for REMAT).  So a left-to-right DP over
the chain needs only the state ``(v, m)`` — ``v`` the accumulated
forward contribution, ``m`` the tightest remaining allowance for
remat-out bytes of still-undecided units — plus the plan's separable
cost (remat seconds + exposed transfer seconds per unit, from the same
``ActionTables`` the greedy scores with).  Pareto dominance
(``v' <= v``, ``m' >= m``, ``cost' <= cost``) prunes the state set; an
optional byte grid quantises ``v`` up / ``m`` down (conservative: an
accepted plan is always truly feasible) when the exact frontier grows
past ``max_states``.  Small instances skip the DP entirely and
brute-force all ``3^n`` rows through ``simulate_many``.

Every candidate the solver emits — DP optimum per k, exhaustive
optimum, the greedy plan, any caller-provided seed plans — is replayed
through the *scalar* ``simulate`` before comparison, so the reported
score is bit-identical to what ``tests/oracle.py`` computes and the
greedy plan competing makes ``solve() <= greedy()`` hold by
construction.
"""
from __future__ import annotations

import bisect
import contextlib
import dataclasses
import queue
import threading
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.actions import Action
from repro.core.scheduler import (ActionTables, Plan, action_tables,
                                  greedy_plan_adaptive)
from repro.obs.tracing import TRACK_SOLVER as _TRACK_SOLVER
from repro.core.simulator import simulate, simulate_many
from repro.launch.roofline import MICROBATCH_OVERHEAD_S, PCIE_BW

# feasibility tolerance — MUST match the scheduler's replay convention
# (`peak_bytes <= budget + 1e-6`) or the tiers would disagree at the
# boundary
_FEAS_TOL = 1e-6
_INF = float("inf")


class SolveTimeout(Exception):
    """Internal: the solve deadline expired mid-DP."""


# states below this count get the exact O(S log S) Pareto sweep (pure
# python, so only worth it while the frontier is small); above it the
# grid quantisation in _dp_actions is the sole growth control
_PARETO_CUTOFF = 4096


def _skyline_keep(v: np.ndarray, m: np.ndarray,
                  cost: np.ndarray) -> np.ndarray:
    """Exact Pareto mask over DP states: state A dominates B iff
    ``v_A <= v_B``, ``m_A >= m_B`` and ``cost_A <= cost_B``.  Sweeps in
    ascending ``v`` keeping an (m, cost) skyline."""
    order = np.lexsort((cost, -m, v))      # v asc, m desc, cost asc
    keep = np.zeros(v.size, dtype=bool)
    front_m: list = []                     # ascending m ...
    front_c: list = []                     # ... with strictly asc cost
    for idx in order:
        mm, cc = m[idx], cost[idx]
        lo = bisect.bisect_left(front_m, mm)
        if lo < len(front_m) and front_c[lo] <= cc:
            continue                       # dominated by a prior state
        hi = bisect.bisect_right(front_m, mm)
        j = hi
        while j > 0 and front_c[j - 1] >= cc:
            j -= 1
        del front_m[j:hi]
        del front_c[j:hi]
        front_m.insert(j, mm)
        front_c.insert(j, cc)
        keep[idx] = True
    return keep


def _dedup(v, m, cost, par, act):
    """Keep the min-cost state per exact ``(v, m)`` key (numpy)."""
    order = np.lexsort((cost, m, v))
    v, m = v[order], m[order]
    first = np.ones(v.size, dtype=bool)
    first[1:] = (v[1:] != v[:-1]) | (m[1:] != m[:-1])
    sel = order[first]
    return v[first], m[first], cost[sel], par[sel], act[sel]


def _dp_actions(tabs: ActionTables, headroom: float, *,
                deadline: Optional[float] = None,
                grid_bytes: float = 0.0,
                max_states: int = 30_000
                ) -> Optional[Tuple[Tuple[int, ...], float]]:
    """DP over one chain at fixed k.  ``headroom`` is
    ``budget - fixed``.  Returns ``(action codes, per-microbatch cost
    seconds)`` for the cheapest feasible plan found, or ``None`` when
    no assignment fits.  Exact while the state frontier stays under
    ``max_states`` (always the case for ``n <= 8``: at most ``3^n``
    states exist); past that the byte grid escalates with conservative
    rounding — ``v`` up, ``m`` down — so any plan returned is still
    truly feasible, it may just not be the global optimum.  Raises
    ``SolveTimeout`` past ``deadline`` (``time.monotonic`` seconds)."""
    est, out, off = tabs.est, tabs.out, tabs.off
    t_re, t_off = tabs.t_re, tabs.t_off
    n = est.size
    opt = tabs.opt if tabs.opt is not None else np.zeros(n)
    t_opt = tabs.t_opt if tabs.t_opt is not None else np.zeros(n)
    B = float(headroom) + _FEAS_TOL
    g = float(grid_bytes)
    v = np.zeros(1)
    m = np.full(1, _INF)
    cost = np.zeros(1)
    trail: list = []              # per unit: (parent index, action code)

    for i in range(n):
        if deadline is not None and time.monotonic() > deadline:
            raise SolveTimeout
        a_i, o_i, f_i = float(est[i]), float(out[i]), float(off[i])
        p_i = float(opt[i])
        ok_fwd = v + (a_i + o_i) <= B      # forward transient of unit i
        # (contribution, restore, remat-out, unit cost) per action code
        trans = [(a_i, 0.0, 0.0, 0.0),                      # KEEP
                 (o_i, a_i, o_i, float(t_re[i])),           # REMAT
                 (a_i - f_i, f_i, 0.0, float(t_off[i]))]    # OFFLOAD
        if p_i > 0:
            # OFFLOAD_OPT: KEEP liveness, but the parked moment bytes
            # raise the headroom.  Folding the credit into the forward
            # contribution grants it to positions >= i only (prefix-only
            # credit — conservative: the DP can over-, never
            # under-estimate a peak, and the winner is re-scored by the
            # exact scalar simulate).  ``t_opt`` is per STEP while
            # t_re/t_off are per microbatch, a ranking skew at k > 1
            # the exact replay also corrects.
            trans.append((a_i - p_i, 0.0, 0.0, float(t_opt[i])))
        cat: list = []
        for code, (cc, rr, qq, ww) in enumerate(trans):
            v2 = v + cc
            # backward peak at i caps the remat-out bytes of every
            # LATER unit; fold it into the running minimum m
            m2 = np.minimum(m - qq, B - v2 - rr - a_i)
            idx = np.nonzero(ok_fwd & (v2 <= B) & (m2 >= 0))[0]
            if idx.size:
                cat.append((v2[idx], m2[idx], cost[idx] + ww, idx,
                            np.full(idx.size, code, dtype=np.int8)))
        if not cat:
            return None                    # no feasible assignment
        v = np.concatenate([c[0] for c in cat])
        m = np.concatenate([c[1] for c in cat])
        cost = np.concatenate([c[2] for c in cat])
        par = np.concatenate([c[3] for c in cat])
        act = np.concatenate([c[4] for c in cat])
        if g > 0:                          # conservative: v up, m down
            v = np.ceil(v / g) * g
            m = np.floor(m / g) * g        # floor(inf) stays inf
            ok = (v <= B) & (m >= 0)
            v, m, cost, par, act = v[ok], m[ok], cost[ok], par[ok], act[ok]
            if not v.size:
                return None
        v, m, cost, par, act = _dedup(v, m, cost, par, act)
        if v.size <= _PARETO_CUTOFF:
            keep = _skyline_keep(v, m, cost)
            v, m, cost, par, act = (v[keep], m[keep], cost[keep],
                                    par[keep], act[keep])
        # frontier too wide: escalate the grid — conservative rounding
        # keeps every surviving plan feasible
        while v.size > max_states:
            g = g * 2.0 if g > 0 else max(B / 4096.0, 1.0)
            vq = np.ceil(v / g) * g
            mq = np.floor(m / g) * g
            ok = (vq <= B) & (mq >= 0)
            if not ok.any():
                return None
            v, m, cost, par, act = _dedup(vq[ok], mq[ok], cost[ok],
                                          par[ok], act[ok])
            if g > 16.0 * max(B, 1.0):
                break
        trail.append((par, act))
    best = int(np.argmin(cost))
    codes: list = []
    idx = best
    for par, act in reversed(trail):
        codes.append(int(act[idx]))
        idx = int(par[idx])
    codes.reverse()
    return tuple(codes), float(cost[best])


def enumerate_plans(n: int, base: int = 3) -> np.ndarray:
    """All ``base^n`` action-code rows, lexicographic — the shared
    enumeration of the exhaustive fallback and ``tests/oracle.py``.
    ``base=3`` covers KEEP/REMAT/OFFLOAD (n <= 12); ``base=4`` adds
    OFFLOAD_OPT (n <= 8: 4^8 = 65536 rows)."""
    if n == 0:
        return np.zeros((1, 0), dtype=np.int64)
    limit = 12 if base <= 3 else 8
    if n > limit:
        raise ValueError(f"{base}^{n} plans is too many to enumerate")
    codes = np.arange(base ** n, dtype=np.int64)
    place = base ** np.arange(n - 1, -1, -1, dtype=np.int64)
    return (codes[:, None] // place) % base


def _exhaustive_actions(tabs: ActionTables, budget: float, fixed: float,
                        k: int, pcie: float, overlap: float,
                        accum: float) -> Tuple[int, ...]:
    """Brute force all plans through ``simulate_many``; returns the
    feasible row with the lowest (overhead, n_host_actions, index), or
    the min-peak row when nothing fits.  Enumerates base 4 (OFFLOAD_OPT
    included) only when the opt vector has positive entries and the
    chain is short enough (n <= 8); otherwise base 3, bit-identical to
    the pre-opt solver."""
    n = tabs.est.size
    has_opt = tabs.opt is not None and bool(np.any(tabs.opt > 0))
    base = 4 if has_opt and n <= 8 else 3
    A = enumerate_plans(n, base=base)
    bs = simulate_many(tabs.est, A, fixed, tabs.out, tabs.fl,
                       offload_bytes=tabs.off, opt_bytes=tabs.opt,
                       pcie_bytes_per_s=pcie,
                       overlap=overlap, microbatch=k,
                       accum_overhead_s=accum)
    feas = np.nonzero(bs.peak_bytes <= budget + _FEAS_TOL)[0]
    if feas.size:
        # ties prefer fewer host-involved units (OFFLOAD + OFFLOAD_OPT;
        # identical to the old (A == 2) count for base-3 enumerations)
        n_off = (A[feas] >= 2).sum(axis=1)
        order = np.lexsort((feas, n_off, bs.step_overhead_s[feas]))
        best = int(feas[order[0]])
    else:
        best = int(np.argmin(bs.peak_bytes))
    return tuple(int(c) for c in A[best])


@dataclasses.dataclass
class SolveResult:
    """Outcome of one ``solve()`` call.  ``score`` is the plan's
    simulated step overhead plus its pad overhead — the exact quantity
    ``tests/oracle.py`` minimises."""
    plan: Optional[Plan]
    feasible: bool
    score: float
    overhead_s: float
    peak_bytes: float
    method: str                   # origin of the winner
    timed_out: bool = False
    solve_s: float = 0.0


def solve(vectors_of_k, budget_bytes: float, fixed_bytes: float = 0.0, *,
          candidate_ks: Sequence[int] = (1,), tol: float = 0.10,
          pcie_bytes_per_s: float = PCIE_BW, offload_overlap: float = 0.5,
          accum_overhead_s: float = MICROBATCH_OVERHEAD_S,
          method: str = "auto", deadline_s: Optional[float] = None,
          grid_bytes: float = 0.0, max_states: int = 30_000,
          exhaustive_max_units: int = 8,
          include_greedy: bool = True,
          seed_plans: Sequence[Plan] = ()) -> SolveResult:
    """Optimal (k, action) assignment under ``budget_bytes``.

    Same contract as ``scheduler.greedy_plan_adaptive``:
    ``vectors_of_k(k)`` returns the per-microbatch planning vectors at
    split ``k`` (``est_mem`` required; ``flops`` / ``output_bytes`` /
    ``offload_bytes`` / ``pad_overhead_s`` optional).  ``method``:

    * ``"dp"``         — the exact chain DP per candidate k;
    * ``"exhaustive"`` — brute-force ``3^n`` rows per k (n <= 12);
    * ``"auto"``       — exhaustive when ``n <= exhaustive_max_units``,
      DP otherwise.

    With ``include_greedy`` (default) the greedy plan competes as a
    candidate, so the result is never worse than greedy at equal budget
    — including on timeout, when the best candidate found so far is
    returned with ``timed_out=True``.  The winner among feasible
    candidates minimises ``(score, k, n_offload)``; when nothing fits
    the min-peak candidate wins (and ``feasible`` is False).
    """
    t0 = time.monotonic()
    deadline = t0 + float(deadline_s) if deadline_s else None
    ks = sorted(set(int(k) for k in candidate_ks))
    assert ks and ks[0] >= 1, ks
    budget = float(budget_bytes)
    fixed = float(fixed_bytes)
    cands: list = []              # (plan, sim, pad, origin)

    def evaluate(plan: Plan, origin: str) -> None:
        k = max(int(plan.microbatch), 1)
        v = vectors_of_k(k)
        if len(plan.actions) != np.asarray(v["est_mem"]).size:
            return                # stale seed from another geometry
        sim = simulate(v["est_mem"], plan.actions, fixed,
                       v.get("output_bytes"), v.get("flops"),
                       offload_bytes=v.get("offload_bytes"),
                       opt_bytes=v.get("opt_bytes"),
                       pcie_bytes_per_s=pcie_bytes_per_s,
                       overlap=offload_overlap, microbatch=k,
                       accum_overhead_s=accum_overhead_s)
        plan.recompute_flops = sim.recompute_flops
        plan.offload_bytes = sim.offload_bytes
        plan.opt_offload_bytes = sim.opt_offload_bytes
        cands.append((plan, sim, float(v.get("pad_overhead_s", 0.0)),
                      origin))

    if include_greedy:
        greedy = greedy_plan_adaptive(
            vectors_of_k, budget, fixed, candidate_ks=ks, tol=tol,
            pcie_bytes_per_s=pcie_bytes_per_s,
            offload_overlap=offload_overlap,
            accum_overhead_s=accum_overhead_s)
        evaluate(greedy, "greedy")
    for seed in seed_plans:
        try:
            evaluate(dataclasses.replace(seed), "seed")
        except Exception:
            continue              # a seed must never break the solve

    timed_out = False
    for k in ks:
        if deadline is not None and time.monotonic() > deadline:
            timed_out = True
            break
        v = vectors_of_k(k)
        tabs = action_tables(v["est_mem"], v.get("output_bytes"),
                             v.get("offload_bytes"), v.get("flops"),
                             opt_bytes=v.get("opt_bytes"),
                             pcie_bytes_per_s=pcie_bytes_per_s,
                             offload_overlap=offload_overlap)
        n = tabs.est.size
        use = method
        if use == "auto":
            use = "exhaustive" if n <= exhaustive_max_units else "dp"
        try:
            if use == "exhaustive":
                codes = _exhaustive_actions(
                    tabs, budget, fixed, k, pcie_bytes_per_s,
                    offload_overlap, accum_overhead_s)
            else:
                hit = _dp_actions(tabs, budget - fixed, deadline=deadline,
                                  grid_bytes=grid_bytes,
                                  max_states=max_states)
                if hit is None:
                    continue      # DP proved k infeasible
                codes = hit[0]
        except SolveTimeout:
            timed_out = True
            break
        total = float(tabs.est.sum())
        arr = np.asarray(codes, dtype=np.int64)
        covered = float(tabs.freed_re[arr == 1].sum()
                        + tabs.freed_off[arr == 2].sum()
                        + tabs.freed_opt[arr == 3].sum())
        plan = Plan([], total + fixed - budget, covered, total,
                    actions=tuple(Action(int(c)) for c in codes))
        plan.microbatch = k
        evaluate(plan, use)

    if not cands:
        return SolveResult(None, False, _INF, _INF, _INF, "none",
                           timed_out=timed_out,
                           solve_s=time.monotonic() - t0)
    fits = [s.peak_bytes <= budget + _FEAS_TOL for _, s, _, _ in cands]
    if any(fits):
        best = min((i for i in range(len(cands)) if fits[i]),
                   key=lambda i: (cands[i][1].step_overhead_s
                                  + cands[i][2],
                                  cands[i][0].microbatch,
                                  cands[i][0].n_offload))
        feasible = True
    else:
        best = min(range(len(cands)), key=lambda i: cands[i][1].peak_bytes)
        feasible = False
    plan, sim, pad, origin = cands[best]
    return SolveResult(plan, feasible, sim.step_overhead_s + pad,
                       sim.step_overhead_s, sim.peak_bytes, origin,
                       timed_out=timed_out,
                       solve_s=time.monotonic() - t0)


@dataclasses.dataclass
class SolveRequest:
    """One queued background solve.  The planning vectors are
    materialised on the MAIN thread at submit time (estimator predicts,
    flops geometry) so the daemon thread is pure numpy — no jax tracing
    off the training thread."""
    key: tuple                    # plan-cache key the result may replace
    bucket: int                   # bucket id, for per-bucket stats
    vectors: Dict[int, dict]      # k -> vectors_of_k(k) snapshot
    budget_bytes: float
    fixed_bytes: float
    candidate_ks: Tuple[int, ...]
    pcie_bytes_per_s: float
    offload_overlap: float
    accum_overhead_s: float
    baseline: Plan                # the cached greedy plan to beat


class BackgroundSolver:
    """Daemon-thread solver tier around a planner's LRU plan cache.

    Swap-in protocol: a solved plan replaces the cache entry only under
    the planner's ``_cache_lock`` AND only while the entry is still the
    *same object* the solve started from — the drift-audit refit
    (``cache.clear()``) and the OOM escalate/poison path both install
    new objects, so a stale solve is dropped without any epoch
    bookkeeping.  Swaps happen only on STRICT score improvement: a tie
    keeps the greedy plan and avoids a pointless recompile.
    """

    def __init__(self, planner, *, budget_ms: float = 50.0,
                 method: str = "auto", max_queue: int = 8,
                 grid_bytes: float = 0.0, max_states: int = 30_000):
        self.planner = planner
        self.budget_ms = float(budget_ms)
        self.method = method
        self.grid_bytes = float(grid_bytes)
        self.max_states = int(max_states)
        self.dropped = 0          # submissions rejected (queue full)
        self.errors = 0           # solves that raised (never propagate)
        self._queue: "queue.Queue[SolveRequest]" = queue.Queue(
            maxsize=max(int(max_queue), 1))
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._inflight: set = set()
        self._pending = 0
        self._thread: Optional[threading.Thread] = None

    def pending(self, key: tuple) -> bool:
        """Is a solve for this plan key queued or running?"""
        with self._lock:
            return key in self._inflight

    def submit(self, req: SolveRequest) -> bool:
        """Enqueue a solve; at most one in flight per key.  Returns
        False (without blocking the training loop) when the key is
        already pending or the bounded queue is full."""
        with self._lock:
            if req.key in self._inflight:
                return False
            try:
                self._queue.put_nowait(req)
            except queue.Full:
                self.dropped += 1
                return False
            self._inflight.add(req.key)
            self._pending += 1
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(
                    target=self._run, name="mimose-solver", daemon=True)
                self._thread.start()
        return True

    def drain(self, timeout: float = 30.0) -> bool:
        """Block until every queued solve finished (tests / shutdown
        reporting); True when the queue went idle in time."""
        with self._idle:
            return self._idle.wait_for(lambda: self._pending == 0,
                                       timeout=timeout)

    # -- daemon side ---------------------------------------------------
    def _run(self) -> None:
        while True:
            req = self._queue.get()
            try:
                self._process(req)
            except Exception:
                self.errors += 1  # a solver bug must never kill training
            finally:
                with self._idle:
                    self._inflight.discard(req.key)
                    self._pending -= 1
                    self._idle.notify_all()

    def _replay_score(self, req: SolveRequest, plan: Plan) -> float:
        k = max(int(plan.microbatch), 1)
        v = req.vectors[k]
        sim = simulate(v["est_mem"], plan.actions, req.fixed_bytes,
                       v.get("output_bytes"), v.get("flops"),
                       offload_bytes=v.get("offload_bytes"),
                       opt_bytes=v.get("opt_bytes"),
                       pcie_bytes_per_s=req.pcie_bytes_per_s,
                       overlap=req.offload_overlap, microbatch=k,
                       accum_overhead_s=req.accum_overhead_s)
        return sim.step_overhead_s + float(v.get("pad_overhead_s", 0.0))

    def _process(self, req: SolveRequest) -> None:
        stats = self.planner.stats
        tel = getattr(self.planner, "telemetry", None)
        span = (tel.tracer.span("solve", _TRACK_SOLVER,
                                args={"bucket": req.bucket}
                                if tel.trace_on else None)
                if tel is not None else contextlib.nullcontext())
        with span:
            res = solve(lambda k: req.vectors[int(k)], req.budget_bytes,
                        req.fixed_bytes, candidate_ks=req.candidate_ks,
                        pcie_bytes_per_s=req.pcie_bytes_per_s,
                        offload_overlap=req.offload_overlap,
                        accum_overhead_s=req.accum_overhead_s,
                        method=self.method,
                        deadline_s=self.budget_ms / 1e3,
                        grid_bytes=self.grid_bytes,
                        max_states=self.max_states,
                        include_greedy=False, seed_plans=(req.baseline,))
        req.baseline.solver_checked = True
        if res.timed_out:
            stats["solver_timeouts"] = stats.get("solver_timeouts", 0) + 1
        else:
            stats["solves"] = stats.get("solves", 0) + 1
        if res.plan is None:
            return
        base_score = self._replay_score(req, req.baseline)
        by = stats.setdefault("solver_delta_by_bucket", {})
        by[req.bucket] = {"greedy_s": base_score, "solved_s": res.score,
                          "improvement_pct":
                              (100.0 * (1.0 - res.score / base_score)
                               if base_score > 0 else 0.0)}
        win = (res.feasible
               and res.score < base_score - max(1e-12, 1e-9 * base_score))
        if not win:
            return
        stats["solver_wins"] = stats.get("solver_wins", 0) + 1
        plan = res.plan
        plan.source = "dp"
        plan.solver_checked = True
        lock = getattr(self.planner, "_cache_lock", None)
        cache = getattr(self.planner, "cache", None)
        if lock is None or cache is None:
            return
        with lock:
            if cache.get(req.key) is req.baseline:
                cache[req.key] = plan
                stats["solver_swaps"] = stats.get("solver_swaps", 0) + 1
                if tel is not None and tel.events_on:
                    tel.events.emit(
                        "solver_swap", bucket=req.bucket,
                        greedy_s=float(base_score),
                        solved_s=float(res.score),
                        improvement_pct=float(
                            100.0 * (1.0 - res.score / base_score)
                            if base_score > 0 else 0.0),
                        k=int(plan.microbatch))
                if tel is not None:
                    tel.tracer.instant("solver_swap", _TRACK_SOLVER,
                                       args={"bucket": req.bucket})
