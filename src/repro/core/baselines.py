"""Baseline checkpointing planners the paper compares against (§6.1).

* ``SublinearPlanner`` — static: one conservative plan computed for the
  *largest* input size the task can produce, applied to every batch
  (Chen et al. 2016 as deployed in the paper's Fig. 4 experiment).
* ``DTRSimPlanner`` — dynamic: greedy evict-on-OOM per iteration with no
  plan reuse and with DTR's measured memory-fragmentation inflation
  (paper §3.2 / Fig. 5); planning cost is re-paid on every batch.

Both accept the same ``mesh_budget`` as ``MimosePlanner`` so the paper's
comparisons stay apples-to-apples under a mesh: collection, fixed bytes
and the budget all switch to per-device quantities.  Like every
planner, both emit typed action plans (``Plan.as_actions()``);
``SublinearPlanner`` additionally takes the same ``offload=`` /
``pcie_gbps=`` knobs as ``MimosePlanner`` (its one static plan may then
OFFLOAD units to host), while DTR's evict-on-OOM semantics are
remat-only by construction.  Both thread ``max_microbatches=``:
Sublinear's one static plan may pick a gradient-accumulation split for
the largest size, and DTR escalates the split only when even
evict-everything cannot fit the budget.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.collector import ShuttlingCollector, input_size_of
from repro.core.estimator import PolyEstimator
from repro.core.planner import PlanInfo, PlannerBase
from repro.core.scheduler import Plan, greedy_plan, greedy_plan_adaptive
from repro.core.simulator import dtr_simulate, simulate
from repro.launch.roofline import MICROBATCH_OVERHEAD_S, plan_unit_flops
from repro.models.lm import LM
from repro.sharding.budget import MeshBudget


class SublinearPlanner(PlannerBase):
    name = "sublinear"

    def __init__(self, lm: LM, budget_bytes: Optional[float] = None,
                 max_input_size: int = 0, *,
                 fixed_bytes: Optional[float] = None,
                 shard_divisor: int = 1,
                 mesh_budget: Optional[MeshBudget] = None,
                 warmup_samples: int = 4,
                 cost_aware: bool = True,
                 offload: bool = False,
                 pcie_gbps: float = 16.0,
                 offload_overlap: float = 0.5,
                 max_microbatches: int = 1,
                 microbatch_overhead_s: float = MICROBATCH_OVERHEAD_S):
        self.lm = lm
        self.mesh_budget = mesh_budget
        if not max_input_size:
            raise ValueError("max_input_size is required")
        self.budget_bytes = self.resolve_budget_bytes(budget_bytes)
        self.max_input_size = int(max_input_size)
        self.fixed_bytes = fixed_bytes
        self.shard_divisor = shard_divisor
        self.cost_aware = cost_aware
        self.max_microbatches = max(int(max_microbatches), 1)
        self.microbatch_overhead_s = microbatch_overhead_s
        self._init_hybrid(offload=offload, pcie_gbps=pcie_gbps,
                          offload_overlap=offload_overlap,
                          cost_aware=cost_aware, degree=2,
                          min_samples=warmup_samples)
        self.collector = ShuttlingCollector(lm, mesh_budget=mesh_budget)
        self.estimator = PolyEstimator(2, min_samples=warmup_samples)
        self._plan: Optional[Plan] = None

    def _build_static_plan(self, params, batch):
        # collect a few sizes online (the static planner is allowed model
        # pre-analysis; we reuse the collector for it), then plan once at
        # the maximum input size.
        B, S = batch["tokens"].shape
        sizes = np.linspace(max(B, self.max_input_size // 8),
                            self.max_input_size,
                            self.estimator.min_samples).astype(int)
        probe = batch
        for s in sizes:
            probe = dict(batch)
            probe["tokens"] = np.zeros((B, max(1, int(s) // B)), np.int32)
            if "frames" in batch:
                probe["frames"] = np.zeros(
                    (B, max(1, int(s) // B), self.lm.cfg.d_model), np.float32)
            res = self.collector.collect(params, probe)
            self.estimator.add_sample(res.input_size,
                                      self.collected_vector(res))
            self._feed_hybrid_estimators(res.input_size, res)
        est = self.estimator.predict(self.max_input_size)
        # recompute cost at the planning geometry (the largest probe):
        # same cost-aware scoring as MimosePlanner, apples-to-apples
        flops = (plan_unit_flops(self.lm, probe) if self.cost_aware
                 else None)
        ks = self.candidate_microbatches(probe)
        if ks == [1]:
            self._plan = greedy_plan(
                est / self.activation_divisor_scalar(),
                self.budget_bytes,
                self.resolve_fixed_bytes(params),
                flops=self.planning_flops(flops),
                **self._hybrid_kwargs(self.max_input_size))
            return

        def vectors_of_k(k):
            # the static plan is built for the LARGEST input size, so
            # the per-microbatch vectors are the fits at max_size/k
            probe_k = self.microbatch_probe(probe, k)
            s_k = input_size_of(probe_k)
            div = self.activation_divisor_scalar()
            d = {"est_mem": self.estimator.predict(s_k) / div}
            if self.cost_aware:
                d["flops"] = self.planning_flops(
                    plan_unit_flops(self.lm, probe_k))
                d["pad_overhead_s"] = self.pad_waste_s(probe, k,
                                                       d["flops"])
            hv = self._hybrid_vectors(s_k)
            if hv is not None:
                d["output_bytes"], d["offload_bytes"] = hv
            return d

        self._plan = greedy_plan_adaptive(
            vectors_of_k, self.budget_bytes,
            self.resolve_fixed_bytes(params),
            candidate_ks=ks,
            pcie_bytes_per_s=self.pcie_gbps * 1e9,
            offload_overlap=self.offload_overlap,
            accum_overhead_s=self.microbatch_overhead_s)

    def plan(self, params, batch):
        if self._plan is None:
            self._build_static_plan(params, batch)
        s = input_size_of(batch)
        return self._plan.as_actions(), PlanInfo(s, self.bucket_key(batch),
                                                 True, False, self._plan)


class DTRSimPlanner(PlannerBase):
    name = "dtr"

    def __init__(self, lm: LM, budget_bytes: Optional[float] = None, *,
                 fixed_bytes: Optional[float] = None,
                 shard_divisor: int = 1,
                 mesh_budget: Optional[MeshBudget] = None,
                 frag_factor: float = 1.25,
                 plan_op_cost_s: float = 2e-5,
                 max_microbatches: int = 1):
        self.lm = lm
        self.mesh_budget = mesh_budget
        self.budget_bytes = self.resolve_budget_bytes(budget_bytes)
        self.fixed_bytes = fixed_bytes
        self.shard_divisor = shard_divisor
        self.frag_factor = frag_factor
        self.plan_op_cost_s = plan_op_cost_s
        self.max_microbatches = max(int(max_microbatches), 1)
        self.collector = ShuttlingCollector(lm, mesh_budget=mesh_budget)
        self._size_cache: Dict[tuple, np.ndarray] = {}
        self.stats = {"plan_ops": 0, "plan_time_s": 0.0, "replans": 0}

    def _act_vector(self, params, batch, k: int) -> np.ndarray:
        """Concrete per-unit byte vector at split ``k`` (DTR sees real
        tensor sizes, so a collection per (size, split) geometry)."""
        s = input_size_of(batch)
        if (s, k) not in self._size_cache:
            probe = batch if k == 1 else self.microbatch_probe(batch, k)
            res = self.collector.collect(params, probe)
            self._size_cache[(s, k)] = self.collected_vector(res)
        return self._size_cache[(s, k)] / self.activation_divisor_scalar()

    def plan(self, params, batch):
        s = input_size_of(batch)
        # DTR knows tensor sizes at runtime (they are concrete); it just
        # never reuses planning work across iterations.
        self.resolve_fixed_bytes(params)

        t0 = time.perf_counter()
        plan_ops = 0
        # DTR has no cost model: escalate the split only when the
        # evict-on-OOM replay cannot fit the budget (smallest feasible
        # k; largest k as best effort when nothing fits; the plain
        # single-shot behaviour when max_microbatches == 1)
        ks = self.candidate_microbatches(batch)
        act = mask = None
        chosen = 1
        for k in ks:
            act = self._act_vector(params, batch, k)
            mask, ops = dtr_simulate(act, self.budget_bytes,
                                     self.fixed_bytes, self.frag_factor)
            plan_ops += ops
            chosen = k
            # feasibility under DTR's OWN memory model: the replayed
            # peak inflated by the same fragmentation factor the
            # evict-on-OOM walk triggers on
            if (len(ks) == 1
                    or simulate(act, mask, self.fixed_bytes).peak_bytes
                    * self.frag_factor <= self.budget_bytes):
                break
        self.stats["plan_ops"] += plan_ops
        self.stats["replans"] += 1
        # model DTR's on-demand eviction search cost (paper: 4.4-6.1% of
        # iteration time); charged every iteration, cache-free.
        self.stats["plan_time_s"] += (time.perf_counter() - t0
                                      + plan_ops * self.plan_op_cost_s)
        p = Plan(list(mask), 0.0, float(act[np.asarray(mask)].sum()),
                 float(act.sum()))
        p.microbatch = chosen
        return p.as_actions(), PlanInfo(s, self.bucket_key(batch), False,
                                        False, p)
