"""Forward/backward memory-liveness timeline simulator.

Given per-unit activation bytes and a plan, replay the training step's
liveness and report the peak footprint plus the plan's overheads.  This
is how we (a) validate scheduler plans against the budget without
hardware, (b) reproduce the paper's Fig. 11 (peak memory vs *which*
encoder is checkpointed), and (c) drive the DTR-style baseline, whose
evict-on-OOM behaviour needs a memory timeline to trigger on.

Plans may be the legacy boolean remat mask or a typed ``Action`` tuple
(``repro.actions``).  The model per action:

* KEEP    — residuals accumulate on device through the forward pass and
  are freed after the unit's gradient;
* REMAT   — only the unit's boundary (output) tensor is kept; residuals
  are recomputed right before the gradient (``recompute_flops`` /
  ``recompute_time_s`` at the PEAK_FLOPS roofline) and freed after;
* OFFLOAD — the offloadable residual bytes are streamed to pinned host
  memory during the forward pass (only the non-offloadable residue
  stays on device) and fetched back before the gradient.  The traffic
  is charged at the PCIe link (``offload_time_s`` = 2 x bytes / BW);
  ``overlap`` models the fraction hidden under compute, leaving
  ``exposed_transfer_s`` on the critical path.
* OFFLOAD_OPT — the unit's optimizer moments (``opt_bytes[i]``) are
  parked in pinned host memory across steps (ZeRO-Offload style).
  Residual liveness is identical to KEEP; instead the FIXED footprint
  drops by the parked bytes for the whole step.  The traffic is one
  round trip of the moment bytes per step — the optimizer update
  fetches and rewrites them — charged at the same link and overlap
  model but NOT scaled by the microbatch split (the update runs once
  per step, not once per microbatch).

Microbatching (``microbatch=k``): the step runs ``k`` sequential
forward+backward passes with gradient accumulation, so the liveness
replay covers ONE microbatch — the byte vectors passed in must already
be the *per-microbatch* bytes (estimator predictions at input size
``s/k``, or a collection on the split geometry) — while the per-step
totals (recomputed bytes/FLOPs, offload traffic) scale by ``k`` and
``accum_overhead_s`` charges the fixed per-extra-microbatch
accumulation cost ``(k - 1) x accum_overhead_s`` on the critical path.

``SimResult.step_overhead_s`` — recompute time + non-overlapped
transfer + accumulation overhead — is the scalar the hybrid and
adaptive-microbatching schedulers' floors guarantee never exceeds the
remat-only / ``k=1`` plan's at equal budget.

A unit's internal working set is transiently live while it executes
whether or not it is rematted/offloaded; during backward (reverse
order) the gradient working set of unit i is charged at ~ its
activation bytes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.actions import Action, as_actions
from repro.launch.roofline import PCIE_BW, PEAK_FLOPS


@dataclasses.dataclass
class SimResult:
    peak_bytes: float
    recompute_bytes: float            # total bytes rematerialised
    recompute_units: int
    timeline: List[Tuple[str, float]]  # (event, live_bytes)
    # forward FLOPs re-executed by the plan (0.0 without a cost model)
    recompute_flops: float = 0.0
    # host-offload traffic: one-way bytes moved, units offloaded, and
    # the round-trip transfer time at the PCIe link
    offload_bytes: float = 0.0
    offload_units: int = 0
    offload_time_s: float = 0.0
    # transfer time NOT hidden under compute ((1 - overlap) x round trip)
    exposed_transfer_s: float = 0.0
    # optimizer-state offload (OFFLOAD_OPT): moment bytes parked on the
    # host, units parked, and the per-step round-trip update traffic
    opt_offload_bytes: float = 0.0
    opt_offload_units: int = 0
    opt_transfer_s: float = 0.0
    # gradient-accumulation split factor of the replayed step (1 = the
    # plain full-batch step) and the fixed accumulation cost it adds to
    # the critical path ((k - 1) x per-microbatch overhead)
    microbatches: int = 1
    accum_overhead_s: float = 0.0

    @property
    def recompute_time_s(self) -> float:
        """Recompute overhead at the roofline compute bound — the number
        the cost-aware scheduler minimises at equal budget."""
        return self.recompute_flops / PEAK_FLOPS

    @property
    def step_overhead_s(self) -> float:
        """Total plan overhead on the step's critical path: recompute
        plus the non-overlapped share of the offload traffic plus the
        gradient-accumulation cost.  The hybrid and microbatching
        schedulers' floor properties are stated on this number."""
        return (self.recompute_time_s + self.exposed_transfer_s
                + self.accum_overhead_s)

    def fits(self, budget: float) -> bool:
        return self.peak_bytes <= budget


def simulate(act_bytes: Sequence[float], remat: Sequence,
             fixed_bytes: float = 0.0,
             output_bytes: Sequence[float] | None = None,
             flops: Sequence[float] | None = None, *,
             offload_bytes: Sequence[float] | None = None,
             opt_bytes: Sequence[float] | None = None,
             pcie_bytes_per_s: float = PCIE_BW,
             overlap: float = 0.5,
             microbatch: int = 1,
             accum_overhead_s: float = 0.0) -> SimResult:
    """Replay one training step's liveness under ``remat`` (a bool mask
    or an ``Action`` plan).  ``offload_bytes[i]`` is the unit's
    offloadable residual bytes (defaults to all of ``act_bytes[i]``);
    only consulted for units the plan marks OFFLOAD.  ``opt_bytes[i]``
    is the unit's optimizer-moment bytes (defaults to zeros — which
    makes OFFLOAD_OPT a free no-op, so plans without a moment vector
    replay exactly as before); only consulted for OFFLOAD_OPT units,
    whose parked bytes leave the fixed footprint for the whole step.

    With ``microbatch=k > 1`` the byte/FLOP vectors must be the
    *per-microbatch* quantities; the replayed peak covers one
    microbatch (gradient accumulation runs them sequentially) while the
    per-step totals scale by ``k`` and ``(k - 1) * accum_overhead_s``
    is charged as fixed accumulation cost.  Optimizer-state traffic
    does NOT scale by ``k`` — the update runs once per step."""
    actions = as_actions(remat)
    n = len(act_bytes)
    act = [float(a) for a in act_bytes]
    out = ([float(o) for o in output_bytes] if output_bytes is not None
           else [0.0] * n)
    fl = ([float(f) for f in flops] if flops is not None else [0.0] * n)
    off = ([min(float(o), act[i]) for i, o in enumerate(offload_bytes)]
           if offload_bytes is not None else list(act))
    opt = ([max(float(o), 0.0) for o in opt_bytes]
           if opt_bytes is not None else [0.0] * n)
    # OFFLOAD_OPT parks moment shards on the host for the WHOLE step
    # (they live there across steps), so the fixed footprint drops
    # before the forward pass begins
    opt_moved = sum(opt[i] for i in range(n)
                    if actions[i] is Action.OFFLOAD_OPT)
    n_opt = sum(1 for a in actions if a is Action.OFFLOAD_OPT)
    live = fixed_bytes - opt_moved
    peak = live
    timeline: List[Tuple[str, float]] = []

    # ---- forward ----------------------------------------------------------
    saved = 0.0
    moved = 0.0                          # one-way bytes offloaded to host
    n_off = 0
    for i in range(n):
        # transient working set while unit i runs
        transient = live + saved + act[i] + out[i]
        peak = max(peak, transient)
        a = actions[i]
        if a is Action.REMAT:
            saved += out[i]               # only the boundary tensor is kept
        elif a is Action.OFFLOAD:
            saved += act[i] - off[i]      # non-offloadable residue stays
            moved += off[i]
            n_off += 1
        else:
            saved += act[i]
        timeline.append((f"fwd{i}", live + saved))
    peak = max(peak, live + saved)

    # ---- backward ---------------------------------------------------------
    recompute = 0.0
    recompute_fl = 0.0
    n_re = 0
    for i in reversed(range(n)):
        a = actions[i]
        if a is Action.REMAT:
            # replay forward of unit i: its residuals come back to life
            saved += act[i]
            recompute += act[i]
            recompute_fl += fl[i]
            n_re += 1
        elif a is Action.OFFLOAD:
            saved += off[i]               # fetched back from the host
        peak = max(peak, live + saved + act[i])   # grad working set ~ act_i
        saved -= act[i]
        timeline.append((f"bwd{i}", live + saved))

    # per-step totals: k sequential microbatches each recompute /
    # offload their own (1/k-scale) share — the peak above stays one
    # microbatch's, the traffic and recompute multiply out
    k = max(int(microbatch), 1)
    recompute *= k
    recompute_fl *= k
    moved *= k
    t_xfer = 2.0 * moved / float(pcie_bytes_per_s)
    # optimizer-state round trip is per STEP, not per microbatch
    t_opt = 2.0 * opt_moved / float(pcie_bytes_per_s)
    hidden = max(0.0, min(1.0, 1.0 - overlap))
    exposed = (t_xfer + t_opt) * hidden
    return SimResult(peak, recompute, n_re, timeline, recompute_fl,
                     offload_bytes=moved, offload_units=n_off,
                     offload_time_s=t_xfer, exposed_transfer_s=exposed,
                     opt_offload_bytes=opt_moved, opt_offload_units=n_opt,
                     opt_transfer_s=t_opt,
                     microbatches=k,
                     accum_overhead_s=(k - 1) * float(accum_overhead_s))


@dataclasses.dataclass
class BatchSimResult:
    """Vectorised replay of many action plans over ONE byte vector.

    Row ``j`` of every array is exactly ``simulate(act, plans[j], ...)``
    on the same inputs (same clipping, same per-action liveness model),
    up to float summation order — the agreement is fuzz-locked by
    ``tests/test_core.py::test_simulate_many_matches_simulate``.  Used
    by the solver tier (``repro.core.solver``) to score exhaustive
    plan enumerations in one numpy pass instead of ``3^n`` python
    replays.
    """
    peak_bytes: np.ndarray          # (m,) per-plan peak footprint
    step_overhead_s: np.ndarray     # (m,) recompute + exposed + accum
    recompute_flops: np.ndarray     # (m,) full-step recomputed FLOPs
    offload_bytes: np.ndarray       # (m,) full-step one-way host traffic
    exposed_transfer_s: np.ndarray  # (m,) non-overlapped transfer time
    microbatches: int
    accum_overhead_s: float         # (k - 1) x per-microbatch overhead
    # (m,) optimizer-moment bytes parked on host (zeros without an
    # opt_bytes vector — back-compat with 3-action consumers)
    opt_offload_bytes: np.ndarray = None


def simulate_many(act_bytes: Sequence[float], plans,
                  fixed_bytes: float = 0.0,
                  output_bytes: Sequence[float] | None = None,
                  flops: Sequence[float] | None = None, *,
                  offload_bytes: Sequence[float] | None = None,
                  opt_bytes: Sequence[float] | None = None,
                  pcie_bytes_per_s: float = PCIE_BW,
                  overlap: float = 0.5,
                  microbatch: int = 1,
                  accum_overhead_s: float = 0.0) -> BatchSimResult:
    """Replay ``m`` plans at once.  ``plans`` is an ``(m, n)`` array of
    action codes (0 KEEP / 1 REMAT / 2 OFFLOAD / 3 OFFLOAD_OPT).
    Semantically each row is ``simulate`` on the same vectors; see
    ``BatchSimResult``.

    The closed form this vectorises (with ``c_j`` the plan's forward
    contribution of unit j — KEEP/OFFLOAD_OPT ``act``, REMAT ``out``,
    OFFLOAD ``act - off`` — and ``restore_j`` the backward restore —
    0 / ``act`` / ``off`` / 0):

    * forward transient at i:  ``fixed' + sum_{j<i} c_j + act_i + out_i``
    * end of forward:          ``fixed' + sum_j c_j``
    * backward at i:  ``fixed' + sum_j c_j + sum_{j>i}(restore_j - act_j)
      + restore_i + act_i``

    where ``fixed' = fixed - sum_{j OFFLOAD_OPT} opt_j`` (the parked
    moment shards leave the device for the whole step).
    """
    A = np.asarray(plans, dtype=np.int64)
    if A.ndim != 2:
        raise ValueError(f"plans must be (m, n), got shape {A.shape}")
    m, n = A.shape
    act = np.asarray(act_bytes, dtype=np.float64)
    assert act.size == n, (act.size, n)
    out = (np.asarray(output_bytes, dtype=np.float64)
           if output_bytes is not None else np.zeros(n))
    fl = (np.asarray(flops, dtype=np.float64)
          if flops is not None else np.zeros(n))
    off = (np.minimum(np.asarray(offload_bytes, dtype=np.float64), act)
           if offload_bytes is not None else act.copy())
    opt = (np.maximum(np.asarray(opt_bytes, dtype=np.float64), 0.0)
           if opt_bytes is not None else np.zeros(n))
    fixed = float(fixed_bytes)

    re_mask = A == 1
    off_mask = A == 2
    opt_mask = A == 3
    c = np.where(re_mask, out, np.where(off_mask, act - off, act))
    restore = np.where(re_mask, act, np.where(off_mask, off, 0.0))
    # per-row fixed footprint: parked moment shards live on the host
    opt_moved = (opt_mask * opt).sum(axis=1)
    fixed_row = fixed - opt_moved

    if n:
        pre = np.cumsum(c, axis=1) - c               # exclusive prefix
        fwd_peak = (pre + act + out).max(axis=1)
        total = c.sum(axis=1)
        d = restore - act
        suf = np.cumsum(d[:, ::-1], axis=1)[:, ::-1] - d  # exclusive suffix
        bwd_peak = (total[:, None] + suf + restore + act).max(axis=1)
        peak = fixed_row + np.maximum(
            0.0, np.maximum(np.maximum(fwd_peak, total), bwd_peak))
    else:
        peak = fixed_row + np.zeros(m)

    k = max(int(microbatch), 1)
    rec_fl = (re_mask * fl).sum(axis=1) * k
    moved = (off_mask * off).sum(axis=1) * k
    t_xfer = 2.0 * moved / float(pcie_bytes_per_s)
    # optimizer-state round trip is per STEP, not per microbatch
    t_opt = 2.0 * opt_moved / float(pcie_bytes_per_s)
    hidden = max(0.0, min(1.0, 1.0 - overlap))
    exposed = (t_xfer + t_opt) * hidden
    accum = (k - 1) * float(accum_overhead_s)
    overhead = rec_fl / PEAK_FLOPS + exposed + accum
    return BatchSimResult(peak_bytes=peak, step_overhead_s=overhead,
                          recompute_flops=rec_fl, offload_bytes=moved,
                          exposed_transfer_s=exposed, microbatches=k,
                          accum_overhead_s=accum,
                          opt_offload_bytes=opt_moved)


@dataclasses.dataclass
class ShardedSimResult:
    """Per-device replay of one plan across a mesh.

    Under SPMD every device executes the same step over its shard, so
    the per-device timeline is one liveness replay of the *per-device*
    byte vector; ``global_peak_bytes`` is the mesh-wide footprint at the
    per-device peak instant (exact when sharding is homogeneous, an
    upper-bound approximation when some leaves stay replicated).
    """
    per_device: SimResult
    n_devices: int

    @property
    def peak_bytes_per_device(self) -> float:
        return self.per_device.peak_bytes

    @property
    def global_peak_bytes(self) -> float:
        return self.per_device.peak_bytes * self.n_devices

    @property
    def recompute_time_s(self) -> float:
        """Per-device recompute overhead (SPMD: every device replays its
        shard of each rematted unit concurrently)."""
        return self.per_device.recompute_time_s

    @property
    def offload_time_s(self) -> float:
        """Per-device round-trip offload traffic (each chip drives its
        own host link under SPMD)."""
        return self.per_device.offload_time_s

    @property
    def step_overhead_s(self) -> float:
        return self.per_device.step_overhead_s

    @property
    def microbatches(self) -> int:
        """Gradient-accumulation split factor of the replayed step
        (SPMD: every device runs the same k sequential microbatches)."""
        return self.per_device.microbatches

    def fits(self, budget_per_device: float) -> bool:
        return self.per_device.peak_bytes <= budget_per_device


def simulate_sharded(device_act_bytes: Sequence[float],
                     remat: Sequence,
                     fixed_device_bytes: float = 0.0,
                     n_devices: int = 1,
                     output_bytes: Sequence[float] | None = None,
                     flops: Sequence[float] | None = None, *,
                     offload_bytes: Sequence[float] | None = None,
                     opt_bytes: Sequence[float] | None = None,
                     pcie_bytes_per_s: float = PCIE_BW,
                     overlap: float = 0.5,
                     microbatch: int = 1,
                     accum_overhead_s: float = 0.0) -> ShardedSimResult:
    """Replay the training step's per-device memory timeline.

    ``device_act_bytes`` is the per-unit byte vector landing on one
    device (``CollectionResult.device_activation_vector``) and
    ``fixed_device_bytes`` the resident shard bytes
    (``budget.fixed_train_bytes_per_device``).  Validates a
    sharding-aware plan against ``MeshBudget.hbm_per_device_bytes``
    without hardware — the multi-device analogue of ``simulate``.
    ``flops`` should be the *per-device* per-unit recompute FLOPs
    (global FLOPs / n_devices under SPMD); ``offload_bytes`` the
    per-device offloadable residual bytes; ``opt_bytes`` the per-device
    optimizer-moment bytes (already ZeRO-divided — see
    ``MeshBudget.unit_moment_bytes``).  ``microbatch=k`` replays a
    k-way gradient-accumulation step per device (the vectors must then
    be per-microbatch per-device bytes) — under SPMD every device runs
    the same k sequential microbatches, so one per-device microbatched
    replay covers the whole mesh.
    """
    base = simulate(device_act_bytes, remat, fixed_device_bytes,
                    output_bytes, flops, offload_bytes=offload_bytes,
                    opt_bytes=opt_bytes,
                    pcie_bytes_per_s=pcie_bytes_per_s, overlap=overlap,
                    microbatch=microbatch,
                    accum_overhead_s=accum_overhead_s)
    return ShardedSimResult(base, int(n_devices))


def peak_if_checkpointing_unit(act_bytes: Sequence[float], which: int,
                               fixed_bytes: float = 0.0) -> float:
    """Paper Fig. 11: peak memory when exactly one unit is checkpointed."""
    remat = [i == which for i in range(len(act_bytes))]
    return simulate(act_bytes, remat, fixed_bytes).peak_bytes


def dtr_simulate(act_bytes: Sequence[float], budget: float,
                 fixed_bytes: float = 0.0,
                 frag_factor: float = 1.25) -> Tuple[List[bool], int]:
    """DTR-style greedy evict-on-OOM (paper §3.2 behaviour).

    Walk the forward pass; whenever live memory (inflated by the
    fragmentation factor the paper measured for DTR) exceeds the budget,
    evict the largest still-saved earlier activation.  Returns the
    effective remat mask and the number of planning (evict-search)
    operations performed — DTR pays this every iteration since it never
    caches plans.
    """
    n = len(act_bytes)
    act = [float(a) for a in act_bytes]
    saved = [False] * n                    # becomes True once materialised
    evicted = [False] * n
    plan_ops = 0
    live = fixed_bytes
    for i in range(n):
        live += act[i]
        saved[i] = True
        while live * frag_factor > budget + 1e-9:
            candidates = [j for j in range(i) if saved[j] and not evicted[j]]
            plan_ops += 1 + len(candidates)   # heuristic scan over tensors
            if not candidates:
                break
            victim = max(candidates, key=lambda j: act[j])
            evicted[victim] = True
            saved[victim] = False
            live -= act[victim]
    return evicted, plan_ops
