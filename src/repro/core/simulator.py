"""Forward/backward memory-liveness timeline simulator.

Given per-unit activation bytes and a remat plan, replay the training
step's liveness and report the peak footprint plus recompute cost.  This
is how we (a) validate scheduler plans against the budget without
hardware, (b) reproduce the paper's Fig. 11 (peak memory vs *which*
encoder is checkpointed), and (c) drive the DTR-style baseline, whose
evict-on-OOM behaviour needs a memory timeline to trigger on.

The model: during forward, saved (non-remat) activations accumulate; a
unit's internal working set is transiently live while it executes whether
or not it is rematted.  During backward (reverse order), a rematted
unit's residuals are recomputed right before its gradient and freed right
after; a saved unit's residuals are freed after its gradient.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.launch.roofline import PEAK_FLOPS


@dataclasses.dataclass
class SimResult:
    peak_bytes: float
    recompute_bytes: float            # total bytes rematerialised
    recompute_units: int
    timeline: List[Tuple[str, float]]  # (event, live_bytes)
    # forward FLOPs re-executed by the plan (0.0 without a cost model)
    recompute_flops: float = 0.0

    @property
    def recompute_time_s(self) -> float:
        """Recompute overhead at the roofline compute bound — the number
        the cost-aware scheduler minimises at equal budget."""
        return self.recompute_flops / PEAK_FLOPS

    def fits(self, budget: float) -> bool:
        return self.peak_bytes <= budget


def simulate(act_bytes: Sequence[float], remat: Sequence[bool],
             fixed_bytes: float = 0.0,
             output_bytes: Sequence[float] | None = None,
             flops: Sequence[float] | None = None) -> SimResult:
    n = len(act_bytes)
    act = [float(a) for a in act_bytes]
    out = ([float(o) for o in output_bytes] if output_bytes is not None
           else [0.0] * n)
    fl = ([float(f) for f in flops] if flops is not None else [0.0] * n)
    live = fixed_bytes
    peak = live
    timeline: List[Tuple[str, float]] = []

    # ---- forward ----------------------------------------------------------
    saved = 0.0
    for i in range(n):
        # transient working set while unit i runs
        transient = live + saved + act[i] + out[i]
        peak = max(peak, transient)
        if not remat[i]:
            saved += act[i]
        else:
            saved += out[i]               # only the boundary tensor is kept
        timeline.append((f"fwd{i}", live + saved))
    peak = max(peak, live + saved)

    # ---- backward ---------------------------------------------------------
    recompute = 0.0
    recompute_fl = 0.0
    n_re = 0
    for i in reversed(range(n)):
        if remat[i]:
            # replay forward of unit i: its residuals come back to life
            saved += act[i]
            recompute += act[i]
            recompute_fl += fl[i]
            n_re += 1
        peak = max(peak, live + saved + act[i])   # grad working set ~ act_i
        saved -= act[i]
        timeline.append((f"bwd{i}", live + saved))

    return SimResult(peak, recompute, n_re, timeline, recompute_fl)


@dataclasses.dataclass
class ShardedSimResult:
    """Per-device replay of one plan across a mesh.

    Under SPMD every device executes the same step over its shard, so
    the per-device timeline is one liveness replay of the *per-device*
    byte vector; ``global_peak_bytes`` is the mesh-wide footprint at the
    per-device peak instant (exact when sharding is homogeneous, an
    upper-bound approximation when some leaves stay replicated).
    """
    per_device: SimResult
    n_devices: int

    @property
    def peak_bytes_per_device(self) -> float:
        return self.per_device.peak_bytes

    @property
    def global_peak_bytes(self) -> float:
        return self.per_device.peak_bytes * self.n_devices

    @property
    def recompute_time_s(self) -> float:
        """Per-device recompute overhead (SPMD: every device replays its
        shard of each rematted unit concurrently)."""
        return self.per_device.recompute_time_s

    def fits(self, budget_per_device: float) -> bool:
        return self.per_device.peak_bytes <= budget_per_device


def simulate_sharded(device_act_bytes: Sequence[float],
                     remat: Sequence[bool],
                     fixed_device_bytes: float = 0.0,
                     n_devices: int = 1,
                     output_bytes: Sequence[float] | None = None,
                     flops: Sequence[float] | None = None
                     ) -> ShardedSimResult:
    """Replay the training step's per-device memory timeline.

    ``device_act_bytes`` is the per-unit byte vector landing on one
    device (``CollectionResult.device_activation_vector``) and
    ``fixed_device_bytes`` the resident shard bytes
    (``budget.fixed_train_bytes_per_device``).  Validates a
    sharding-aware plan against ``MeshBudget.hbm_per_device_bytes``
    without hardware — the multi-device analogue of ``simulate``.
    ``flops`` should be the *per-device* per-unit recompute FLOPs
    (global FLOPs / n_devices under SPMD).
    """
    base = simulate(device_act_bytes, remat, fixed_device_bytes,
                    output_bytes, flops)
    return ShardedSimResult(base, int(n_devices))


def peak_if_checkpointing_unit(act_bytes: Sequence[float], which: int,
                               fixed_bytes: float = 0.0) -> float:
    """Paper Fig. 11: peak memory when exactly one unit is checkpointed."""
    remat = [i == which for i in range(len(act_bytes))]
    return simulate(act_bytes, remat, fixed_bytes).peak_bytes


def dtr_simulate(act_bytes: Sequence[float], budget: float,
                 fixed_bytes: float = 0.0,
                 frag_factor: float = 1.25) -> Tuple[List[bool], int]:
    """DTR-style greedy evict-on-OOM (paper §3.2 behaviour).

    Walk the forward pass; whenever live memory (inflated by the
    fragmentation factor the paper measured for DTR) exceeds the budget,
    evict the largest still-saved earlier activation.  Returns the
    effective remat mask and the number of planning (evict-search)
    operations performed — DTR pays this every iteration since it never
    caches plans.
    """
    n = len(act_bytes)
    act = [float(a) for a in act_bytes]
    saved = [False] * n                    # becomes True once materialised
    evicted = [False] * n
    plan_ops = 0
    live = fixed_bytes
    for i in range(n):
        live += act[i]
        saved[i] = True
        while live * frag_factor > budget + 1e-9:
            candidates = [j for j in range(i) if saved[j] and not evicted[j]]
            plan_ops += 1 + len(candidates)   # heuristic scan over tensors
            if not candidates:
                break
            victim = max(candidates, key=lambda j: act[j])
            evicted[victim] = True
            saved[victim] = False
            live -= act[victim]
    return evicted, plan_ops
