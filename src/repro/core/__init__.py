"""Mimose core: the paper's primary contribution (input-aware checkpointing)."""
from repro.actions import Action, as_actions  # noqa: F401
from repro.core.cache import LRUCache  # noqa: F401
from repro.core.collector import (CollectionResult, ShuttlingCollector,  # noqa: F401
                                  input_size_of, unit_residual_bytes)
from repro.core.estimator import (DecisionTreeEstimator, ESTIMATORS,  # noqa: F401
                                  PolyEstimator)
from repro.core.planner import (MimosePlanner, NonePlanner, PlannerBase,  # noqa: F401
                                fixed_train_bytes)
from repro.core.baselines import DTRSimPlanner, SublinearPlanner  # noqa: F401
from repro.core.scheduler import (ActionTables, Plan, action_tables,  # noqa: F401
                                  build_buckets, escalate_plan, greedy_plan,
                                  greedy_plan_adaptive, greedy_plan_reference,
                                  greedy_plan_sharded)
from repro.core.simulator import (BatchSimResult, ShardedSimResult,  # noqa: F401
                                  SimResult, dtr_simulate,
                                  peak_if_checkpointing_unit, simulate,
                                  simulate_many, simulate_sharded)
from repro.core.solver import (BackgroundSolver, SolveRequest,  # noqa: F401
                               SolveResult, solve)
from repro.launch.roofline import (offload_transfer_s,  # noqa: F401
                                   plan_unit_flops, unit_fwd_flops)
from repro.sharding.budget import (MeshBudget,  # noqa: F401
                                   fixed_train_bytes_per_device)
