"""MimosePlanner — the input-aware checkpointing planner (paper §4).

Ties together the shuttling collector, the lightning estimator, the
responsive scheduler and the plan cache:

    planner = MimosePlanner(lm, budget_bytes=6 << 30)
    mask, info = planner.plan(params, batch)     # < 1 ms after warm-up
    loss, _ = lm.loss(params, batch, remat_mask=mask)

Phases (paper §4.1):
  * sheltered execution — while the estimator has fewer than
    ``warmup_samples`` distinct input sizes, each new size triggers the
    collector (the measured bytes are used directly for that iteration's
    plan, so training proceeds under budget from step one);
  * responsive execution — the estimator predicts per-unit bytes for any
    size, the greedy scheduler emits a plan in O(n log n), and the plan
    cache keyed by quantised input size makes repeats free.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collector import ShuttlingCollector, input_size_of, _tree_bytes
from repro.core.estimator import PolyEstimator
from repro.core.scheduler import Plan, greedy_plan
from repro.data.pipeline import bucket_length
from repro.models.lm import LM


def fixed_train_bytes(params, optimizer: str = "adamw",
                      grad_dtype_bytes: Optional[int] = None) -> int:
    """Resident bytes independent of input size: params + grads + opt state."""
    pb = _tree_bytes(params)
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(params))
    gb = pb if grad_dtype_bytes is None else n_params * grad_dtype_bytes
    ob = 2 * 4 * n_params if optimizer == "adamw" else 0   # fp32 m + v
    return pb + gb + ob


@dataclasses.dataclass
class PlanInfo:
    input_size: int
    quantized_size: int
    cache_hit: bool
    collected: bool
    plan: Plan
    estimate_time_s: float = 0.0
    schedule_time_s: float = 0.0
    collect_time_s: float = 0.0


class PlannerBase:
    name = "base"
    quantum: int = 1          # batch geometry granularity (1 = no bucketing)

    def plan(self, params, batch) -> Tuple[Tuple[bool, ...], PlanInfo]:
        raise NotImplementedError

    def bucket_key(self, batch) -> int:
        """The shared bucket id: quantised input size.  Batches padded to
        ``quantum`` (data layer or trainer) make this key align 1:1 with
        the jitted-step cache, so a repeated bucket never replans *or*
        recompiles — the engine's compile count is O(#buckets)."""
        return bucket_length(input_size_of(batch), self.quantum)


class NonePlanner(PlannerBase):
    """No checkpointing (the paper's PyTorch Baseline)."""
    name = "none"

    def __init__(self, lm: LM):
        self.lm = lm

    def plan(self, params, batch):
        n = self.lm.num_plan_units()
        p = Plan([False] * n, 0.0, 0.0, 0.0)
        return p.as_tuple(), PlanInfo(input_size_of(batch), 0, True, False, p)


class MimosePlanner(PlannerBase):
    name = "mimose"

    def __init__(self, lm: LM, budget_bytes: float, *,
                 fixed_bytes: Optional[float] = None,
                 shard_divisor: int = 1,
                 quantum: int = 256,
                 degree: int = 2,
                 warmup_samples: int = 4,
                 bucket_tol: float = 0.10,
                 audit_every: int = 0,
                 audit_tol: float = 0.02):
        self.lm = lm
        self.budget_bytes = float(budget_bytes)
        self.fixed_bytes = fixed_bytes          # resolved lazily from params
        self.shard_divisor = shard_divisor      # activation sharding ways/device
        self.quantum = quantum
        self.warmup_samples = warmup_samples
        self.bucket_tol = bucket_tol
        # adaptive-estimator extension (the paper's §4.3 future work):
        # every ``audit_every``-th unseen size, re-collect abstractly and
        # re-fit if the prediction drifted beyond ``audit_tol``.
        self.audit_every = audit_every
        self.audit_tol = audit_tol
        self.collector = ShuttlingCollector(lm)
        self.estimator = PolyEstimator(degree, min_samples=warmup_samples)
        self.cache: Dict[int, Plan] = {}
        # stats (paper Table 2)
        self.stats = {"cache_hits": 0, "cache_misses": 0, "collections": 0,
                      "collect_time_s": 0.0, "estimate_time_s": 0.0,
                      "schedule_time_s": 0.0, "audits": 0, "refits": 0}

    # ------------------------------------------------------------------
    def _quantize(self, s: int) -> int:
        # MUST stay identical to bucket_key's rounding: the plan cache
        # (keyed here) and the trainer's jit cache (keyed by bucket_key)
        # align only because both delegate to the same bucket_length
        return bucket_length(s, self.quantum)

    def _fixed(self, params) -> float:
        if self.fixed_bytes is None:
            self.fixed_bytes = fixed_train_bytes(params) / self.shard_divisor
        return self.fixed_bytes

    def plan(self, params, batch):
        s = input_size_of(batch)
        qs = self._quantize(s)
        if qs in self.cache:
            self.stats["cache_hits"] += 1
            p = self.cache[qs]
            return p.as_tuple(), PlanInfo(s, qs, True, False, p)
        self.stats["cache_misses"] += 1

        collected = False
        t_est = t_col = 0.0
        if not self.estimator.ready:
            # sheltered execution: collect this size online
            res = self.collector.collect(params, batch)
            self.estimator.add_sample(s, res.activation_vector())
            est = res.activation_vector()
            collected = True
            t_col = res.collect_time_s
            self.stats["collections"] += 1
            self.stats["collect_time_s"] += t_col
        else:
            t0 = time.perf_counter()
            est = self.estimator.predict(s)
            t_est = time.perf_counter() - t0
            self.stats["estimate_time_s"] += t_est
            if (self.audit_every
                    and self.stats["cache_misses"] % self.audit_every == 0):
                # drift audit: exact abstract re-collection for this size
                self.stats["audits"] += 1
                res = self.collector.collect(params, batch)
                truth = res.activation_vector()
                err = abs(truth.sum() - est.sum()) / max(truth.sum(), 1.0)
                if err > self.audit_tol:
                    self.estimator.add_sample(s, truth)
                    self.estimator.fit()
                    est = truth
                    self.stats["refits"] += 1
                    self.cache.clear()      # stale plans out

        t0 = time.perf_counter()
        plan = greedy_plan(est / self.shard_divisor, self.budget_bytes,
                           self._fixed(params), tol=self.bucket_tol)
        t_sch = time.perf_counter() - t0
        self.stats["schedule_time_s"] += t_sch

        self.cache[qs] = plan
        return plan.as_tuple(), PlanInfo(s, qs, False, collected, plan,
                                         t_est, t_sch, t_col)
