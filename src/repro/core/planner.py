"""MimosePlanner — the input-aware checkpointing planner (paper §4).

Ties together the shuttling collector, the lightning estimator, the
responsive scheduler and the plan cache:

    planner = MimosePlanner(lm, budget_bytes=6 << 30)
    mask, info = planner.plan(params, batch)     # < 1 ms after warm-up
    loss, _ = lm.loss(params, batch, remat_mask=mask)

Phases (paper §4.1):
  * sheltered execution — while the estimator has fewer than
    ``warmup_samples`` distinct input sizes, each new size triggers the
    collector (the measured bytes are used directly for that iteration's
    plan, so training proceeds under budget from step one);
  * responsive execution — the estimator predicts per-unit bytes for any
    size, the greedy scheduler emits a plan in O(n log n), and the plan
    cache keyed by quantised input size makes repeats free.

Cost-aware selection (default): every plan is scored on bytes freed per
recompute-FLOP using the ``launch/roofline.py`` per-unit cost model, so
the scheduler rematerialises cheap MLP/SSM units before FLOP-heavy
attention units that free the same bytes — and never does worse than the
paper's byte-only Algorithm 1 (``cost_aware=False`` restores it).

Sharding-aware mode: pass ``mesh_budget=MeshBudget.from_shape(...)`` and
every quantity above becomes *per-device* — the collector divides each
activation leaf by its PartitionSpec divisor, the estimator fits
per-device bytes, the fixed bytes are the param/grad/optimizer *shards*
(ZeRO-1 aware), and the scheduler plans against
``mesh_budget.hbm_per_device_bytes``.  Plan-cache keys embed the mesh
signature so plans never leak across mesh shapes.

Hybrid remat+offload mode (``offload=True``): plans become typed action
tuples (``repro.actions.Action``) and every unit may also be OFFLOADed
to pinned host memory — priced at the ``pcie_gbps`` link with
``offload_overlap`` of the traffic hidden under compute.  Two extra
estimators (same PolyEstimator machinery) track the per-unit boundary
and offloadable byte vectors the hybrid scheduler needs.  All planners
return ``Plan.as_actions()`` now; a plan with no OFFLOAD unit is
value-identical to the old bool mask (``KEEP == 0 == False``,
``REMAT == 1 == True``).

Adaptive microbatching (``max_microbatches > 1``): the candidate search
additionally spans the gradient-accumulation split factor ``k`` per
bucket — the per-unit byte vectors at split ``k`` come straight from
the PolyEstimator fits evaluated at input size ``s/k`` (or an abstract
collection on the split geometry during sheltered execution), and the
``(k, action-plan)`` pair with the lowest simulated step overhead wins
(``scheduler.greedy_plan_adaptive``).  ``k = 1`` always competes, so
enabling microbatching never loses at equal budget; plan-cache keys
grow the ``max_microbatches`` component so plans never leak across
knob settings, and ``Plan.microbatch`` tells the trainer to execute
the step as ``k`` accumulated microbatches
(``repro.train.accumulate``).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import LRUCache
from repro.core.collector import ShuttlingCollector, input_size_of, _tree_bytes
from repro.core.estimator import PolyEstimator
from repro.obs import StatsView, Telemetry, TRACK_PLANNER
from repro.core.scheduler import (Plan, escalate_plan, greedy_plan,
                                  greedy_plan_adaptive)
from repro.core.solver import BackgroundSolver, SolveRequest
from repro.data.pipeline import bucket_length
from repro.launch.roofline import MICROBATCH_OVERHEAD_S, plan_unit_flops
from repro.models.lm import LM
from repro.sharding.budget import MeshBudget, fixed_train_bytes_per_device


def fixed_train_bytes(params, optimizer: str = "adamw",
                      grad_dtype_bytes: Optional[int] = None) -> int:
    """Resident bytes independent of input size: params + grads + opt state."""
    pb = _tree_bytes(params)
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(params))
    gb = pb if grad_dtype_bytes is None else n_params * grad_dtype_bytes
    ob = 2 * 4 * n_params if optimizer == "adamw" else 0   # fp32 m + v
    return pb + gb + ob


@dataclasses.dataclass
class PlanInfo:
    input_size: int
    quantized_size: int
    cache_hit: bool
    collected: bool
    plan: Plan
    estimate_time_s: float = 0.0
    schedule_time_s: float = 0.0
    collect_time_s: float = 0.0


class PlannerBase:
    name = "base"
    telemetry: Optional[Telemetry] = None
    quantum: int = 1          # batch geometry granularity (1 = no bucketing)
    mesh_budget: Optional[MeshBudget] = None
    fixed_bytes: Optional[float] = None
    shard_divisor: int = 1    # legacy scalar activation ways (global mode)
    # hybrid remat+offload knobs (set via _init_hybrid; off by default)
    offload: bool = False
    pcie_gbps: float = 16.0
    offload_overlap: float = 0.5
    # optimizer-state offload (ZeRO-Offload style): let the scheduler
    # park a unit's fp32 AdamW moments on the host for the whole step
    opt_offload: bool = False
    _opt_vector = None        # cached: moment bytes are input-independent
    # adaptive microbatching: largest gradient-accumulation split the
    # planner may pick per bucket (1 = plain full-batch steps), and the
    # fixed per-extra-microbatch cost it prices the split at
    max_microbatches: int = 1
    microbatch_overhead_s: float = MICROBATCH_OVERHEAD_S

    def plan(self, params, batch) -> Tuple[tuple, PlanInfo]:
        """Returns ``(Plan.as_actions(), PlanInfo)`` — a typed action
        tuple; bool-mask consumers keep working because KEEP/REMAT are
        value-identical to False/True."""
        raise NotImplementedError

    # -- observability (repro.obs) ---------------------------------------
    def bind_telemetry(self, telemetry: Telemetry) -> None:
        """Re-home this planner's metrics into ``telemetry``'s registry.

        Called by the trainer (and the serve engine) so every component
        of a run shares ONE registry: same-named metrics merge, which is
        exactly how planner and watchdog converge on a single
        ``train_oom_events`` counter instead of double-booking."""
        self.telemetry = telemetry
        st = getattr(self, "stats", None)
        if isinstance(st, StatsView):
            st.attach(telemetry.metrics)

    # -- OOM-watchdog hooks (repro.train.resilience) ---------------------
    def record_oom(self, bucket: int) -> None:
        """Book a device-OOM (real or injected) against ``bucket`` in
        ``stats`` — a planner without a stats mapping just drops it.

        NOTE: when the planner shares a registry with an
        ``OOMWatchdog`` (the trainer binds both), the watchdog's
        ``on_oom`` bumps the SAME ``train_oom_events`` counter — call
        one or the other per OOM, never both."""
        st = getattr(self, "stats", None)
        if isinstance(st, StatsView):
            st.inc("oom_events", bucket=bucket)
        elif isinstance(st, dict):
            st["oom_events"] = st.get("oom_events", 0) + 1
            by = st.setdefault("oom_by_bucket", {})
            by[bucket] = by.get(bucket, 0) + 1

    def escalate(self, params, batch) -> bool:
        """Replace the cached plan for this batch's bucket with a more
        memory-aggressive one (DTR-style recovery after an OOM).  The
        base planner cannot — only planners with an online estimator
        implement the ladder; returning False tells the watchdog to
        re-raise instead of retrying."""
        return False

    # -- shared mesh-vs-global accounting (one implementation for the
    # Mimose planner and both baselines, so their byte accounting can
    # never drift apart) --------------------------------------------------
    def resolve_budget_bytes(self, budget_bytes: Optional[float]) -> float:
        """The planning budget: explicit bytes win (interpreted
        per-device when a mesh budget is set), else the budget's HBM."""
        if budget_bytes is None:
            if self.mesh_budget is None:
                raise ValueError("pass budget_bytes or mesh_budget")
            budget_bytes = self.mesh_budget.hbm_per_device_bytes
        return float(budget_bytes)

    def collected_vector(self, res) -> np.ndarray:
        """The byte vector planning runs on: per-device when sharding-
        aware, global otherwise."""
        return (res.device_activation_vector()
                if self.mesh_budget is not None
                else res.activation_vector())

    def collected_output_vector(self, res) -> np.ndarray:
        """Boundary-tensor bytes per unit, in the same (per-device or
        global) frame as ``collected_vector``."""
        return (res.device_output_vector()
                if self.mesh_budget is not None
                else res.output_vector())

    def collected_offload_vector(self, res) -> np.ndarray:
        """Offloadable residual bytes per unit, same frame as above."""
        return (res.device_offloadable_vector()
                if self.mesh_budget is not None
                else res.offloadable_vector())

    def collected_opt_vector(self, res) -> np.ndarray:
        """Optimizer-moment bytes per unit (fp32 AdamW m+v), same frame
        as above.  Input-size independent — pure parameter-shape math."""
        return (res.device_opt_vector()
                if self.mesh_budget is not None
                else res.opt_vector())

    def planning_flops(self, flops):
        """Recompute-cost vector in the SAME frame as the byte vectors:
        per-device under a mesh budget (SPMD divides every unit's
        recompute across the chips), global otherwise.  Remat-only
        selection is scale-invariant so the frame never mattered before,
        but the hybrid path compares recompute seconds against
        per-device PCIe transfer seconds — mixed frames would inflate
        remat cost by n_devices and over-offload."""
        if flops is None or self.mesh_budget is None:
            return flops
        return np.asarray(flops, dtype=np.float64) / self.mesh_budget.n_devices

    # -- shared hybrid remat+offload state (Mimose + Sublinear) ----------
    def _init_hybrid(self, *, offload: bool, pcie_gbps: float,
                     offload_overlap: float, cost_aware: bool,
                     degree: int, min_samples: int,
                     opt_offload: bool = False) -> None:
        """One implementation of the offload knobs + the two extra
        per-unit fits (boundary and offloadable bytes) the hybrid
        scheduler needs, so the planners cannot drift apart."""
        if offload and not cost_aware:
            raise ValueError("offload=True needs cost_aware=True: the "
                             "hybrid selection compares remat FLOPs "
                             "against transfer time")
        if opt_offload and not offload:
            raise ValueError("opt_offload=True needs offload=True: "
                             "moment parking rides the same host link "
                             "and link pricing as residual offload")
        self.offload = offload
        self.opt_offload = opt_offload
        self.pcie_gbps = pcie_gbps
        self.offload_overlap = offload_overlap
        self.est_output = PolyEstimator(degree, min_samples=min_samples)
        self.est_offload = PolyEstimator(degree, min_samples=min_samples)
        # NOT an estimator: moment bytes depend only on the parameter
        # shapes, so the first collection pins the vector exactly (and
        # the snapshot estimator dict keeps its three-key format)
        self._opt_vector = None

    def _feed_hybrid_estimators(self, s: int, res) -> None:
        self.est_output.add_sample(s, self.collected_output_vector(res))
        self.est_offload.add_sample(s, self.collected_offload_vector(res))
        if self._opt_vector is None:
            v = self.collected_opt_vector(res)
            if v is not None and len(v):
                self._opt_vector = np.asarray(v, dtype=np.float64)

    def _hybrid_vectors(self, size: int, res=None):
        """Boundary/offloadable byte vectors in the planning frame —
        exact from a collection when ``res`` is given, predicted
        otherwise.  ``None`` when offload is disabled."""
        if not self.offload:
            return None
        div = self.activation_divisor_scalar()
        out_v = (self.collected_output_vector(res) if res is not None
                 else self.est_output.predict(size))
        off_v = (self.collected_offload_vector(res) if res is not None
                 else self.est_offload.predict(size))
        return out_v / div, off_v / div

    def _opt_bytes_planning(self):
        """The moment-bytes vector in the planning frame, or ``None``
        when optimizer offload is off / not yet pinned.  The per-device
        frame is already divided by the mesh moment sharding, so only
        the legacy scalar divisor applies here."""
        if not self.opt_offload or self._opt_vector is None:
            return None
        cfg = getattr(getattr(self, "lm", None), "cfg", None)
        if cfg is not None and getattr(cfg, "remat_mode", "") == "scan":
            # scan-mode moments are stacked across a chunk's layers in
            # ONE leaf — parking a chunk cannot free a slice of a live
            # buffer, so the trainer could not realise the bytes the
            # plan would claim; don't offer the action
            return None
        return self._opt_vector / self.activation_divisor_scalar()

    def _hybrid_kwargs(self, size: int, res=None) -> dict:
        """The extra ``greedy_plan`` arguments for hybrid selection:
        the ``_hybrid_vectors`` plus the link pricing.  Empty when
        offload is disabled."""
        v = self._hybrid_vectors(size, res)
        if v is None:
            return {}
        d = dict(output_bytes=v[0],
                 offload_bytes=v[1],
                 pcie_bytes_per_s=self.pcie_gbps * 1e9,
                 offload_overlap=self.offload_overlap)
        ov = self._opt_bytes_planning()
        if ov is not None:
            d["opt_bytes"] = ov
        return d

    def resolve_fixed_bytes(self, params) -> float:
        """Resident (input-independent) bytes, resolved lazily from the
        params: the per-device param/grad/optimizer shards under a mesh
        budget, the legacy global bytes / shard_divisor otherwise."""
        if self.fixed_bytes is None:
            if self.mesh_budget is not None:
                self.fixed_bytes = fixed_train_bytes_per_device(
                    params, self.mesh_budget,
                    scanned=self.lm.cfg.remat_mode == "scan")
            else:
                self.fixed_bytes = (fixed_train_bytes(params)
                                    / self.shard_divisor)
        return self.fixed_bytes

    def activation_divisor_scalar(self) -> int:
        """Mesh-aware vectors are already per-device; the legacy scalar
        divisor only applies in global mode."""
        return 1 if self.mesh_budget is not None else self.shard_divisor

    def bucket_key(self, batch) -> int:
        """The shared bucket id: quantised input size.  Batches padded to
        ``quantum`` (data layer or trainer) make this key align 1:1 with
        the jitted-step cache, so a repeated bucket never replans *or*
        recompiles — the engine's compile count is O(#buckets)."""
        return bucket_length(input_size_of(batch), self.quantum)

    def mesh_sig(self) -> tuple:
        """Mesh identity component of every cache key: () when planning
        for a single global budget, the MeshBudget signature otherwise.
        Plans (and jitted steps, via the trainer) built for one mesh
        shape must never be replayed under another."""
        return (self.mesh_budget.sig()
                if self.mesh_budget is not None else ())

    def plan_key(self, batch) -> tuple:
        """Full plan-cache key: (bucket id, mesh signature, microbatch
        ceiling, PCIe GB/s, offload overlap).  ``max_microbatches`` is
        part of the key so plans built under one microbatching knob are
        never replayed under another (the chosen ``k`` itself is plan
        *output*, carried by ``Plan.microbatch``); the roofline knobs
        are part of it so a background-solved plan priced at one link
        speed can never be resurrected — from the cache or a snapshot —
        after a ``--pcie-gbps`` / ``--offload-overlap`` change that
        would re-rank its actions."""
        return (self.bucket_key(batch), self.mesh_sig(),
                self.max_microbatches,
                round(float(self.pcie_gbps), 6),
                round(float(self.offload_overlap), 6))

    # -- shared adaptive-microbatching machinery -------------------------
    def candidate_microbatches(self, batch) -> list:
        """Candidate gradient-accumulation splits for this batch: every
        ``k`` in ``1..max_microbatches``, capped at the batch size (a
        split cannot produce more microbatches than there are rows)."""
        B = int(np.shape(batch["tokens"])[0])
        kmax = max(min(int(self.max_microbatches), B), 1)
        return list(range(1, kmax + 1))

    @staticmethod
    def pad_waste_s(batch, k: int, flops_mb) -> float:
        """Per-step time a non-divisor split wastes on batch-axis pad
        rows: ``split_batch`` pads ``B`` up to ``ceil(B/k)*k`` rows and
        the step computes a full forward+backward over them.  The
        per-microbatch flops vector is already priced at the padded
        ``ceil(B/k)``-row geometry, so the waste is its pad-row share
        across all ``k`` microbatches at the roofline (backward ~= 2x
        forward).  Zero when ``k`` divides ``B`` — the simulator's
        overhead model covers everything else, so divisor splits stay
        exactly the floor-property candidates."""
        from repro.launch.roofline import PEAK_FLOPS
        B = int(np.shape(batch["tokens"])[0])
        k = max(int(k), 1)
        rows = -(-B // k) * k
        if rows == B or flops_mb is None:
            return 0.0
        frac = (rows - B) / rows
        return frac * 3.0 * k * float(np.sum(flops_mb)) / PEAK_FLOPS

    @staticmethod
    def microbatch_probe(batch, k: int) -> dict:
        """The batch geometry of ONE microbatch at split ``k``: every
        entry's batch axis cut to ``ceil(B/k)`` rows.  Works on arrays
        and ``ShapeDtypeStruct`` batches alike (the abstract dry-run
        plans through here too) — only shapes matter downstream
        (collection is abstract, ``plan_unit_flops`` reads geometry).
        """
        B = int(np.shape(batch["tokens"])[0])
        Bk = max(-(-B // max(int(k), 1)), 1)

        def cut(v):
            if isinstance(v, jax.ShapeDtypeStruct):
                return jax.ShapeDtypeStruct((Bk,) + tuple(v.shape[1:]),
                                            v.dtype)
            return v[:Bk]

        return {key: cut(v) for key, v in batch.items()}


class NonePlanner(PlannerBase):
    """No checkpointing (the paper's PyTorch Baseline)."""
    name = "none"

    def __init__(self, lm: LM):
        self.lm = lm

    def plan(self, params, batch):
        n = self.lm.num_plan_units()
        p = Plan([False] * n, 0.0, 0.0, 0.0)
        s = input_size_of(batch)
        # report the real bucket id (not a hard-coded 0) so
        # launch/report.engine_report groups baseline runs by bucket
        return p.as_actions(), PlanInfo(s, self.bucket_key(batch), True,
                                        False, p)


class MimosePlanner(PlannerBase):
    name = "mimose"

    def __init__(self, lm: LM, budget_bytes: Optional[float] = None, *,
                 fixed_bytes: Optional[float] = None,
                 shard_divisor: int = 1,
                 mesh_budget: Optional[MeshBudget] = None,
                 quantum: int = 256,
                 degree: int = 2,
                 warmup_samples: int = 4,
                 bucket_tol: float = 0.10,
                 cost_aware: bool = True,
                 offload: bool = False,
                 opt_offload: bool = False,
                 pcie_gbps: float = 16.0,
                 offload_overlap: float = 0.5,
                 max_microbatches: int = 1,
                 microbatch_overhead_s: float = MICROBATCH_OVERHEAD_S,
                 max_plans: int = 256,
                 audit_every: int = 0,
                 audit_tol: float = 0.02,
                 escalate_shrink: float = 0.85,
                 solver: str = "off",
                 solver_budget_ms: float = 50.0,
                 telemetry: Optional[Telemetry] = None):
        self.lm = lm
        self.telemetry = telemetry if telemetry is not None \
            else Telemetry.disabled()
        self.mesh_budget = mesh_budget
        self.budget_bytes = self.resolve_budget_bytes(budget_bytes)
        self.fixed_bytes = fixed_bytes          # resolved lazily from params
        self.shard_divisor = shard_divisor
        self.quantum = quantum
        self.warmup_samples = warmup_samples
        self.bucket_tol = bucket_tol
        # adaptive microbatching: the scheduler may split a bucket into
        # up to this many gradient-accumulation microbatches when that
        # beats (or alone fits) the budget
        self.max_microbatches = max(int(max_microbatches), 1)
        self.microbatch_overhead_s = microbatch_overhead_s
        # cost-aware selection (bytes freed per recompute-FLOP, floored
        # by the byte-only oracle); False = the paper's Algorithm 1
        self.cost_aware = cost_aware
        # hybrid remat+offload: let the scheduler also stream a unit's
        # residuals to pinned host memory, priced at the PCIe link (the
        # shared base helper also builds the two extra per-unit fits)
        self._init_hybrid(offload=offload, pcie_gbps=pcie_gbps,
                          offload_overlap=offload_overlap,
                          cost_aware=cost_aware, degree=degree,
                          min_samples=warmup_samples,
                          opt_offload=opt_offload)
        # adaptive-estimator extension (the paper's §4.3 future work):
        # every ``audit_every``-th unseen size, re-collect abstractly and
        # re-fit if the prediction drifted beyond ``audit_tol``.
        self.audit_every = audit_every
        self.audit_tol = audit_tol
        self.collector = ShuttlingCollector(lm, mesh_budget=mesh_budget)
        self.estimator = PolyEstimator(degree, min_samples=warmup_samples)
        # bounded: a long-tailed bucket distribution must not grow the
        # plan cache without limit (the jit-step cache is bounded too)
        self.cache = LRUCache(max_plans)
        # OOM recovery (repro.train.resilience): per-plan-key escalation
        # level, and the per-rung budget shrink that keeps each retry
        # strictly more aggressive than the last
        self.escalate_shrink = float(escalate_shrink)
        self._escalation: dict = {}
        # every (input size, batch geometry) the estimators were fed —
        # collection is abstract and shape-determined, so this log IS
        # the warmup state: a snapshot carries it and a restore onto a
        # different mesh replays it (eval_shape, zero FLOPs) instead of
        # re-paying the online warmup Mimose exists to avoid
        self._sample_log: list = []
        # stats (paper Table 2) + resilience counters (watchdog/restore)
        # + optimal-plan-tier counters (repro.core.solver) — a
        # dict-shaped view over the shared metrics registry, so one
        # store serves the legacy ``stats[...]`` call sites, Prometheus
        # export and the exit report alike
        self.stats = StatsView(
            self.telemetry.metrics,
            scalars={"cache_hits": "plan_cache_hits",
                     "cache_misses": "plan_cache_misses",
                     "collections": "planner_collections",
                     "collect_time_s": "planner_collect_time_s",
                     "estimate_time_s": "planner_estimate_time_s",
                     "schedule_time_s": "planner_schedule_time_s",
                     "audits": "planner_audits",
                     "refits": "planner_refits",
                     "evictions": "plan_cache_evictions",
                     "oom_events": "train_oom_events",
                     "escalations": "train_escalations",
                     "poisoned_plans": "plan_cache_poisoned",
                     "restored_samples": "planner_restored_samples",
                     "restored_plans": "planner_restored_plans",
                     "dropped_plans": "planner_dropped_plans",
                     "solves": "solver_solves",
                     "solver_swaps": "solver_swaps",
                     "solver_wins": "solver_wins",
                     "solver_timeouts": "solver_timeouts",
                     "offload_fallbacks": "offload_fallbacks"},
            labeled={"oom_by_bucket": ("train_oom_events", "bucket"),
                     "escalations_by_bucket": ("train_escalations",
                                               "bucket")})
        # optimal-plan tier: a daemon thread solves the (k, action)
        # assignment exactly and swaps strictly better plans into the
        # cache above — all cache access goes through _cache_lock so
        # the swap is atomic against the training thread
        if solver not in ("off", "dp"):
            raise ValueError(f"solver must be 'off' or 'dp', got "
                             f"{solver!r}")
        self.solver = solver
        self.solver_budget_ms = float(solver_budget_ms)
        self._cache_lock = threading.RLock()
        self.background_solver = (
            BackgroundSolver(self, budget_ms=self.solver_budget_ms)
            if solver == "dp" else None)

    # ------------------------------------------------------------------
    def _quantize(self, s: int) -> int:
        # MUST stay identical to bucket_key's rounding: the plan cache
        # (keyed here) and the trainer's jit cache (keyed by bucket_key)
        # align only because both delegate to the same bucket_length
        return bucket_length(s, self.quantum)

    def _feed_estimators(self, s: int, res, probe=None) -> None:
        """One collection feeds all three per-unit fits (activation,
        boundary, offloadable) so they become ready together.  The
        probe's geometry is logged so a snapshot can replay the sample
        abstractly under a different mesh (``train/resilience.py``)."""
        self.estimator.add_sample(s, self.collected_vector(res))
        self._feed_hybrid_estimators(s, res)
        if probe is not None:
            self._sample_log.append(
                {"size": int(s),
                 "probe": {k: [list(np.shape(v)),
                               str(getattr(v, "dtype", "int32"))]
                           for k, v in probe.items()
                           if np.shape(v)}})

    def _record_drift_point(self, bucket: int, size: int, est, truth,
                            rel_err: float = 0.0,
                            refit: bool = False) -> None:
        """One point of the predicted-vs-actual peak-bytes series: the
        drift-audit (and every sheltered collection) compares the
        estimator's activation-byte prediction against an exact
        abstract re-collection — this publishes that comparison as
        per-bucket gauges and a ``drift`` event instead of discarding
        it after the refit decision."""
        div = self.activation_divisor_scalar()
        fixed = float(self.fixed_bytes) if self.fixed_bytes is not None \
            else 0.0
        pred = fixed + float(np.sum(est)) / div
        act = fixed + float(np.sum(truth)) / div
        m = self.telemetry.metrics
        m.gauge("plan_predicted_peak_bytes",
                "predicted per-device peak bytes at the bucket's "
                "geometry").set(pred, bucket=bucket)
        m.gauge("plan_actual_peak_bytes",
                "collected (ground-truth) per-device peak bytes").set(
                    act, bucket=bucket)
        if self.telemetry.events_on:
            self.telemetry.events.emit(
                "drift", bucket=int(bucket), size=int(size),
                predicted_bytes=pred, actual_bytes=act,
                rel_err=float(rel_err), refit=bool(refit))

    def _microbatch_vectors(self, params, batch, k: int, est1, flops1,
                            res) -> dict:
        """Per-microbatch planning vectors at split ``k`` for
        ``greedy_plan_adaptive``: estimator predictions at the
        microbatch input size ``~s/k`` once the fits are ready, an
        abstract collection on the split geometry during sheltered
        execution (the extra sample also feeds the fits).  ``k == 1``
        reuses the vectors the plain path already derived."""
        div = self.activation_divisor_scalar()
        if k == 1:
            est, flops, size, res_k = est1, flops1, input_size_of(batch), res
        else:
            probe = self.microbatch_probe(batch, k)
            size = input_size_of(probe)
            res_k = None
            if res is None and self.estimator.ready:
                # responsive execution: the per-unit fits price any
                # split for free
                est = self.estimator.predict(size)
            else:
                # sheltered execution (this plan() already collected at
                # k=1): collect the split geometry too — exact vectors,
                # and the extra sample feeds the fits
                res_k = self.collector.collect(params, probe)
                self._feed_estimators(size, res_k, probe)
                self.stats["collections"] += 1
                self.stats["collect_time_s"] += res_k.collect_time_s
                est = self.collected_vector(res_k)
            flops = None
            if self.cost_aware:
                flops = (res_k.flops_vector() if res_k is not None
                         else plan_unit_flops(self.lm, probe))
        d = {"est_mem": est / div}
        if flops is not None:
            d["flops"] = self.planning_flops(flops)
            d["pad_overhead_s"] = self.pad_waste_s(batch, k, d["flops"])
        hv = self._hybrid_vectors(size, res_k)
        if hv is not None:
            d["output_bytes"], d["offload_bytes"] = hv
        ov = self._opt_bytes_planning()
        if ov is not None:
            d["opt_bytes"] = ov
        return d

    def plan(self, params, batch):
        s = input_size_of(batch)
        qs = self._quantize(s)
        # the ONE cache-key construction (PlannerBase.plan_key): growing
        # a key component there covers every planner at once
        key = self.plan_key(batch)
        with self._cache_lock:
            p = self.cache.get(key)
        if p is not None:
            self.stats["cache_hits"] += 1
            # a background-solved plan lands here on the next step of
            # its bucket — no blocking, the daemon already swapped it in
            self._maybe_submit_solve(params, batch, key, p)
            return p.as_actions(), PlanInfo(s, qs, True, False, p)
        self.stats["cache_misses"] += 1

        tel = self.telemetry
        collected = False
        audited = False
        flops = None
        res = None
        t_est = t_col = 0.0
        if not self.estimator.ready:
            # sheltered execution: collect this size online (the
            # collection carries the recompute-cost vector for this
            # geometry, so the scheduler reads it straight off)
            with tel.tracer.span("collect", TRACK_PLANNER):
                res = self.collector.collect(params, batch)
            self._feed_estimators(s, res, batch)
            est = self.collected_vector(res)
            if self.cost_aware:
                flops = res.flops_vector()
            collected = True
            t_col = res.collect_time_s
            self.stats["collections"] += 1
            self.stats["collect_time_s"] += t_col
            self._record_drift_point(qs, s, est, est)
        else:
            t0 = time.perf_counter()
            with tel.tracer.span("predict", TRACK_PLANNER):
                est = self.estimator.predict(s)
            t_est = time.perf_counter() - t0
            self.stats["estimate_time_s"] += t_est
            if (self.audit_every
                    and self.stats["cache_misses"] % self.audit_every == 0):
                # drift audit: exact abstract re-collection for this size
                self.stats["audits"] += 1
                with tel.tracer.span("collect", TRACK_PLANNER):
                    audit_res = self.collector.collect(params, batch)
                truth = self.collected_vector(audit_res)
                err = abs(truth.sum() - est.sum()) / max(truth.sum(), 1.0)
                refit = err > self.audit_tol
                audited = True
                self._record_drift_point(qs, s, est, truth,
                                         rel_err=err, refit=refit)
                if refit:
                    self._feed_estimators(s, audit_res, batch)
                    self.estimator.fit()
                    self.est_output.fit()
                    self.est_offload.fit()
                    est = truth
                    res = audit_res          # exact vectors for this plan
                    self.stats["refits"] += 1
                    with self._cache_lock:
                        self.cache.clear()  # stale plans out — also
                    # invalidates in-flight solves: their swap is
                    # identity-checked against the evicted objects
                    if tel.events_on:
                        tel.events.emit("refit", bucket=qs, size=s,
                                        rel_err=float(err))
                    tel.tracer.instant("refit", TRACK_PLANNER,
                                       args={"bucket": qs})

        t0 = time.perf_counter()
        # analytic recompute cost at this bucket's geometry (pure python
        # math, microseconds) — makes selection cost-aware: cheap units
        # are rematerialised before FLOP-heavy ones freeing equal bytes
        if self.cost_aware and flops is None:
            flops = plan_unit_flops(self.lm, batch)
        ks = self.candidate_microbatches(batch)
        with tel.tracer.span("schedule", TRACK_PLANNER):
            if ks == [1]:
                # plain path — bit-identical to planning without the
                # microbatching subsystem
                div = self.activation_divisor_scalar()
                plan = greedy_plan(est / div,
                                   self.budget_bytes,
                                   self.resolve_fixed_bytes(params),
                                   tol=self.bucket_tol,
                                   flops=self.planning_flops(flops),
                                   **self._hybrid_kwargs(s, res))
            else:
                plan = greedy_plan_adaptive(
                    lambda k: self._microbatch_vectors(params, batch, k,
                                                       est, flops, res),
                    self.budget_bytes,
                    self.resolve_fixed_bytes(params),
                    candidate_ks=ks,
                    tol=self.bucket_tol,
                    pcie_bytes_per_s=self.pcie_gbps * 1e9,
                    offload_overlap=self.offload_overlap,
                    accum_overhead_s=self.microbatch_overhead_s)
        t_sch = time.perf_counter() - t0
        self.stats["schedule_time_s"] += t_sch
        if not collected and not audited:
            # responsive plans carry a prediction but no ground truth;
            # keep the predicted-peak gauge current for the drift column
            # (an audit this call already published the fresher
            # predicted/actual pair — don't clobber it)
            div = self.activation_divisor_scalar()
            self.telemetry.metrics.gauge(
                "plan_predicted_peak_bytes").set(
                    float(self.fixed_bytes or 0.0)
                    + float(np.sum(est)) / div, bucket=qs)

        ev_before = self.cache.evictions
        with self._cache_lock:
            self.cache[key] = plan
        self.stats["evictions"] = self.cache.evictions
        if tel.events_on:
            tel.events.emit(
                "plan", bucket=qs, size=s, source=plan.source,
                collected=bool(collected),
                k=int(getattr(plan, "microbatch", 1) or 1),
                n_remat=int(plan.n_remat),
                n_offload=int(plan.n_offload),
                n_opt=int(plan.n_opt),
                recompute_flops=float(plan.recompute_flops),
                offload_bytes=float(plan.offload_bytes),
                schedule_time_s=t_sch)
            if self.cache.evictions > ev_before:
                tel.events.emit("plan_evicted", bucket=qs,
                                evictions=int(self.cache.evictions))
        self._maybe_submit_solve(params, batch, key, plan)
        return plan.as_actions(), PlanInfo(s, qs, False, collected, plan,
                                           t_est, t_sch, t_col)

    # ------------------------------------------------------------------
    def _maybe_submit_solve(self, params, batch, key, plan) -> None:
        """Queue an exact background solve for this bucket (the
        optimal-plan tier, ``repro.core.solver``).  Greedy already
        served the step — this never blocks.  Skipped while the
        estimator is warming up (the sheltered plans are exact for
        their collections), for plans the solver already produced or
        checked, and for OOM-escalated buckets (their repaired plan
        encodes information the simulator does not have).  The
        planning vectors are materialised HERE, on the training
        thread, so the daemon stays numpy-only."""
        bs = self.background_solver
        if (bs is None or not self.estimator.ready
                or getattr(plan, "solver_checked", False)
                or plan.source == "dp"
                or self._escalation.get(key, 0)
                or bs.pending(key)):
            return
        s = input_size_of(batch)
        est1 = self.estimator.predict(s)
        flops1 = plan_unit_flops(self.lm, batch) if self.cost_aware else None
        ks = self.candidate_microbatches(batch)
        vectors = {int(k): self._microbatch_vectors(params, batch, k,
                                                    est1, flops1, None)
                   for k in ks}
        req = SolveRequest(key=key, bucket=self.bucket_key(batch),
                           vectors=vectors,
                           budget_bytes=self.budget_bytes,
                           fixed_bytes=self.resolve_fixed_bytes(params),
                           candidate_ks=tuple(ks),
                           pcie_bytes_per_s=self.pcie_gbps * 1e9,
                           offload_overlap=self.offload_overlap,
                           accum_overhead_s=self.microbatch_overhead_s,
                           baseline=plan)
        if bs.submit(req):
            # one submission per cached plan object; the daemon re-marks
            # it after the solve completes (covers the queue-full path,
            # where a later hit may retry)
            plan.solver_checked = True

    # ------------------------------------------------------------------
    def escalate(self, params, batch) -> bool:
        """DTR-style recovery ladder after a device OOM on this batch's
        bucket (called by the ``repro.train.resilience`` watchdog).

        The predicted plan was wrong — reality ran out of memory — so
        each call replaces the cached plan with a strictly more
        aggressive one, planned against a budget shrunk by
        ``escalate_shrink ** level`` (the prediction error is unknown;
        the shrink is the safety margin).  Rungs, in order:

          1. **more remat** — re-plan remat-only at the shrunken budget;
          2. **offload** — upgrade the current plan's actions
             (KEEP -> REMAT -> OFFLOAD) in density order via
             ``scheduler.escalate_plan`` until the liveness replay fits;
          3. **higher microbatch k** — double the gradient-accumulation
             split (``greedy_plan_adaptive`` with the forced candidate),
             repeating until ``k`` reaches the batch size.

        The escalated plan is cached under the same plan key (the old
        entry is poisoned), so later steps of the bucket reuse it.
        Returns False when the ladder is exhausted (``k`` cannot grow
        further) — the watchdog then re-raises the OOM.
        """
        key = self.plan_key(batch)
        level = self._escalation.get(key, 0) + 1
        s = input_size_of(batch)
        bucket = self.bucket_key(batch)
        B = int(np.shape(batch["tokens"])[0])

        res = None
        if not self.estimator.ready:
            res = self.collector.collect(params, batch)
            self._feed_estimators(s, res, batch)
            self.stats["collections"] += 1
            self.stats["collect_time_s"] += res.collect_time_s
            est = self.collected_vector(res)
        else:
            est = self.estimator.predict(s)
        div = self.activation_divisor_scalar()
        flops = (res.flops_vector() if res is not None
                 else plan_unit_flops(self.lm, batch))
        fixed = self.resolve_fixed_bytes(params)
        budget = self.budget_bytes * (self.escalate_shrink ** level)
        with self._cache_lock:
            prev = self.cache.get(key)
        prev_k = max(int(getattr(prev, "microbatch", 1) or 1), 1)

        if level == 1 and prev_k == 1:
            # rung 1: more remat — the full cost-aware replan at the
            # shrunken budget frees strictly more bytes than the plan
            # that just OOMed
            plan = greedy_plan(est / div, budget, fixed,
                               tol=self.bucket_tol,
                               flops=self.planning_flops(flops))
        elif level == 2 and prev_k == 1:
            # rung 2: offload — upgrade the failed plan's actions until
            # the replayed peak fits (works even when the offload knob
            # is off: the hybrid estimators are fed on every collection)
            out_v = (self.collected_output_vector(res) if res is not None
                     else self.est_output.predict(s)) / div
            off_v = (self.collected_offload_vector(res) if res is not None
                     else self.est_offload.predict(s)) / div
            base = prev.actions if prev is not None else None
            plan = escalate_plan(base, est / div,
                                 self.planning_flops(flops), budget, fixed,
                                 output_bytes=out_v, offload_bytes=off_v,
                                 pcie_bytes_per_s=self.pcie_gbps * 1e9,
                                 offload_overlap=self.offload_overlap,
                                 opt_bytes=self._opt_bytes_planning())
        else:
            # rung 3+: gradient accumulation — shrink the per-microbatch
            # footprint itself, the one lever that reaches below the
            # bucket's k=1 minimum footprint
            k_new = min(B, max(2, prev_k * 2))
            if k_new <= prev_k:
                self._escalation[key] = level
                return False
            plan = greedy_plan_adaptive(
                lambda k: self._microbatch_vectors(params, batch, k,
                                                   est, flops, res),
                budget, fixed, candidate_ks=[k_new],
                tol=self.bucket_tol,
                pcie_bytes_per_s=self.pcie_gbps * 1e9,
                offload_overlap=self.offload_overlap,
                accum_overhead_s=self.microbatch_overhead_s)

        plan.source = "escalated"
        with self._cache_lock:
            if key in self.cache:
                self.stats["poisoned_plans"] += 1
                if self.telemetry.events_on:
                    self.telemetry.events.emit("plan_poisoned",
                                               bucket=bucket, level=level)
            # installing a NEW object also invalidates any in-flight
            # solve for this key (identity-checked swap)
            self.cache[key] = plan
        self._escalation[key] = level
        self.stats.inc("escalations", bucket=bucket)
        tel = self.telemetry
        if tel.events_on:
            tel.events.emit("escalation", bucket=bucket, level=level,
                            k=int(getattr(plan, "microbatch", 1) or 1),
                            n_remat=int(plan.n_remat),
                            n_offload=int(plan.n_offload))
        tel.tracer.instant("escalation", TRACK_PLANNER,
                           args={"bucket": bucket, "level": level})
        return True
