"""Lightning memory estimator (paper §4.3).

Per plan-unit polynomial regression of activation bytes against input
size.  The paper finds activation memory is at most quadratic in the
input size (attention materialises a (seqlen, seqlen) score tensor) and
picks the n=2 polynomial as the best accuracy/latency trade-off
(Tables 3-4).  We implement polynomial degrees 1..3 plus a small CART
decision tree used for the Table 3 comparison benchmark.

All fitting is plain numpy least squares — training on 10 samples takes
~1 ms and prediction ~15 us, matching the paper's reported overheads.

The estimator is unit-agnostic about sharding: a sharding-aware planner
feeds it *per-device* byte vectors (global bytes already divided by the
MeshBudget divisors, which are constant per unit across input sizes) —
bytes stay polynomial in input size either way, so one fit serves any
mesh shape via the divisor and nothing here needs to know the mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np


class PolyEstimator:
    """Fit bytes(s) = sum_k c_k s^k independently per plan unit."""

    def __init__(self, degree: int = 2, min_samples: Optional[int] = None):
        self.degree = degree
        self.min_samples = min_samples or (degree + 1)
        self._sizes: List[float] = []
        self._acts: List[np.ndarray] = []     # (n_units,) per sample
        self._coeffs: Optional[np.ndarray] = None   # (n_units, degree+1)
        self.fit_time_s = 0.0

    # -- online accumulation ------------------------------------------------
    def add_sample(self, input_size: int, activation_bytes: Sequence[float]):
        self._sizes.append(float(input_size))
        self._acts.append(np.asarray(activation_bytes, dtype=np.float64))
        self._coeffs = None

    @property
    def num_samples(self) -> int:
        return len(self._sizes)

    @property
    def ready(self) -> bool:
        return len(set(self._sizes)) >= self.min_samples

    # -- fit / predict --------------------------------------------------------
    def fit(self):
        if not self._sizes:
            raise RuntimeError(
                "PolyEstimator has no samples: predict/fit was called "
                "before sheltered execution collected any input size — "
                "call add_sample(input_size, activation_bytes) first "
                "(or check estimator.ready before predicting).")
        t0 = time.perf_counter()
        s = np.asarray(self._sizes)
        Y = np.stack(self._acts)                       # (n_samples, n_units)
        # Vandermonde in normalised size to keep the system well conditioned
        scale = s.max() if s.max() > 0 else 1.0
        V = np.vander(s / scale, self.degree + 1)       # (n_samples, d+1)
        coef, *_ = np.linalg.lstsq(V, Y, rcond=None)    # (d+1, n_units)
        self._scale = scale
        self._coeffs = coef.T                           # (n_units, d+1)
        self.fit_time_s = time.perf_counter() - t0
        return self

    def predict(self, input_size: float) -> np.ndarray:
        if self._coeffs is None:
            self.fit()
        v = np.vander(np.array([input_size / self._scale]), self.degree + 1)[0]
        return np.maximum(self._coeffs @ v, 0.0)

    def predict_total(self, input_size: float) -> float:
        return float(np.sum(self.predict(input_size)))

    # -- persistence (preemption-safe checkpointing) --------------------------
    def state_dict(self) -> dict:
        """Serializable snapshot of the fit: the raw samples, which fully
        determine the coefficients (fitting is ~1 ms, so restore refits
        rather than trusting stored coefficients against drifted code)."""
        return {"degree": int(self.degree),
                "min_samples": int(self.min_samples),
                "sizes": [float(s) for s in self._sizes],
                "acts": [np.asarray(a, dtype=np.float64).tolist()
                         for a in self._acts]}

    def load_state(self, state: dict) -> "PolyEstimator":
        """Restore from ``state_dict`` output.  ``degree``/``min_samples``
        stay as constructed (the planner owns those knobs); only the
        sample log is adopted.  Refits immediately when ready."""
        sizes = list(state.get("sizes", []))
        acts = state.get("acts", [])
        if len(sizes) != len(acts):
            raise ValueError(
                f"estimator state corrupt: {len(sizes)} sizes vs "
                f"{len(acts)} activation vectors")
        self._sizes = [float(s) for s in sizes]
        self._acts = [np.asarray(a, dtype=np.float64) for a in acts]
        self._coeffs = None
        if self.ready:
            self.fit()
        return self

    # -- evaluation helpers ----------------------------------------------------
    def mape(self, sizes: Sequence[float], truth: np.ndarray) -> float:
        """truth: (n_samples, n_units) actual bytes."""
        preds = np.stack([self.predict(s) for s in sizes])
        tot_p, tot_t = preds.sum(1), truth.sum(1)
        return float(np.mean(np.abs(tot_p - tot_t) / np.maximum(tot_t, 1.0)))


class DecisionTreeEstimator:
    """Tiny CART regressor on total activation bytes (Table 3 baseline)."""

    def __init__(self, max_depth: int = 4, min_leaf: int = 1):
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self._sizes: List[float] = []
        self._acts: List[np.ndarray] = []
        self._tree = None

    def add_sample(self, input_size, activation_bytes):
        self._sizes.append(float(input_size))
        self._acts.append(np.asarray(activation_bytes, dtype=np.float64))
        self._tree = None

    @property
    def ready(self):
        return len(self._sizes) >= 2

    def _build(self, xs, ys, depth):
        if depth >= self.max_depth or len(xs) <= self.min_leaf:
            return ("leaf", ys.mean(axis=0))
        order = np.argsort(xs)
        xs, ys = xs[order], ys[order]
        best = None
        for i in range(1, len(xs)):
            if xs[i] == xs[i - 1]:
                continue
            sse = (((ys[:i] - ys[:i].mean(0)) ** 2).sum()
                   + ((ys[i:] - ys[i:].mean(0)) ** 2).sum())
            if best is None or sse < best[0]:
                best = (sse, (xs[i - 1] + xs[i]) / 2, i)
        if best is None:
            return ("leaf", ys.mean(axis=0))
        _, thr, i = best
        return ("node", thr, self._build(xs[:i], ys[:i], depth + 1),
                self._build(xs[i:], ys[i:], depth + 1))

    def fit(self):
        if not self._sizes:
            raise RuntimeError(
                "DecisionTreeEstimator has no samples: call add_sample() "
                "before predict/fit.")
        t0 = time.perf_counter()
        self._tree = self._build(np.asarray(self._sizes),
                                 np.stack(self._acts), 0)
        self.fit_time_s = time.perf_counter() - t0
        return self

    def predict(self, input_size: float) -> np.ndarray:
        if self._tree is None:
            self.fit()
        node = self._tree
        while node[0] == "node":
            node = node[2] if input_size <= node[1] else node[3]
        return node[1]

    def predict_total(self, input_size: float) -> float:
        return float(np.sum(self.predict(input_size)))

    def mape(self, sizes, truth) -> float:
        preds = np.stack([self.predict(s) for s in sizes])
        tot_p, tot_t = preds.sum(1), truth.sum(1)
        return float(np.mean(np.abs(tot_p - tot_t) / np.maximum(tot_t, 1.0)))


ESTIMATORS = {
    "poly1": lambda: PolyEstimator(1),
    "poly2": lambda: PolyEstimator(2),
    "poly3": lambda: PolyEstimator(3),
    "tree": lambda: DecisionTreeEstimator(),
}
