"""Shuttling online collector (paper §4.2), adapted to JAX.

The paper's collector runs every block's forward twice on the GPU — once
to measure per-layer activation memory from the CUDA allocator, once
checkpointed to keep the footprint at the Sublinear level.  Under XLA
there is no runtime allocator to poll, but there is something strictly
better: the residuals JAX AD will save for a block are *exactly* the
leaves of the ``jax.vjp`` closure, and they can be obtained abstractly
with ``jax.eval_shape`` — zero FLOPs, zero bytes allocated, and the
numbers are exact rather than sampled.  The "shuttle" (forward twice)
degenerates to a single abstract trace per block; we keep the paper's
online character: the collector runs lazily, on the live training batch,
only when a new input size appears, with no model pre-analysis.

For wall-time data (used in the paper's Table 2 overhead breakdown) the
collector can also time a concrete forward per block on request.

Sharding-aware collection: given a ``MeshBudget`` the collector also
records each unit's *per-device* activation bytes — every leaf of the
vjp closure is divided by its ``MeshBudget.activation_divisor`` (the
``sharding/specs.py`` rules: batch over the data axes, tensor-parallel
intermediates over ``model``), so downstream estimation and planning can
run against a per-device HBM budget instead of a fictitious global one.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.roofline import plan_unit_flops
from repro.models.lm import LM, PlanUnit
from repro.sharding.budget import MeshBudget, unit_moment_bytes


def _tree_bytes(tree) -> int:
    return sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree_util.tree_leaves(tree)
               if hasattr(l, "shape"))


@dataclasses.dataclass
class UnitRecord:
    name: str
    index: int                 # forward timestamp
    activation_bytes: int      # residuals AD would save (excluding weights)
    output_bytes: int          # inter-block tensor (kept even when rematted)
    param_bytes: int
    forward_time_s: float = 0.0
    # per-device residual bytes after the unit's PartitionSpec divisors
    # (== activation_bytes when collected without a MeshBudget)
    device_activation_bytes: int = 0
    # analytic forward FLOPs at the collection geometry — the recompute
    # cost of rematerialising this unit (launch/roofline.py cost model)
    flops: float = 0.0
    # residual bytes worth a host DMA (matrix-shaped leaves; scalars and
    # 1-d leaves stay on device) — what an OFFLOAD action can free
    offloadable_bytes: int = 0
    device_offloadable_bytes: int = 0
    # per-device boundary-tensor bytes (the checkpoint REMAT must keep)
    device_output_bytes: int = 0
    # fp32 AdamW moment bytes (m + v) owned by the unit — what an
    # OFFLOAD_OPT action parks on the host.  Param-shape-determined
    # (input-size-independent), ZeRO-divided in the device_ variant.
    opt_bytes: int = 0
    device_opt_bytes: int = 0


@dataclasses.dataclass
class CollectionResult:
    input_size: int            # elements in the mini-batch input tensor
    records: List[UnitRecord]
    collect_time_s: float = 0.0
    traced_units: int = 0      # abstract traces actually run
    dedup_hits: int = 0        # units served from an identical unit's trace

    def activation_vector(self) -> np.ndarray:
        return np.array([r.activation_bytes for r in self.records], dtype=np.float64)

    def device_activation_vector(self) -> np.ndarray:
        """Per-unit bytes landing on ONE device under the collection's
        MeshBudget (identical to ``activation_vector`` without one)."""
        return np.array([r.device_activation_bytes for r in self.records],
                        dtype=np.float64)

    def flops_vector(self) -> np.ndarray:
        """Per-unit analytic forward FLOPs (= recompute cost) at the
        collection geometry — the scheduler's cost-aware score input."""
        return np.array([r.flops for r in self.records], dtype=np.float64)

    def output_vector(self) -> np.ndarray:
        """Per-unit boundary (inter-block) tensor bytes — what REMAT
        keeps on device as its recompute checkpoint."""
        return np.array([r.output_bytes for r in self.records],
                        dtype=np.float64)

    def device_output_vector(self) -> np.ndarray:
        return np.array([r.device_output_bytes for r in self.records],
                        dtype=np.float64)

    def offloadable_vector(self) -> np.ndarray:
        """Per-unit residual bytes an OFFLOAD action can stream to host
        (DMA-worthy matrix leaves; always <= ``activation_vector``)."""
        return np.array([r.offloadable_bytes for r in self.records],
                        dtype=np.float64)

    def device_offloadable_vector(self) -> np.ndarray:
        return np.array([r.device_offloadable_bytes for r in self.records],
                        dtype=np.float64)

    def opt_vector(self) -> np.ndarray:
        """Per-unit fp32 AdamW moment bytes — the OFFLOAD_OPT action's
        price vector.  Input-size-independent (param shapes only)."""
        return np.array([r.opt_bytes for r in self.records],
                        dtype=np.float64)

    def device_opt_vector(self) -> np.ndarray:
        """Per-device (ZeRO-divided) counterpart of ``opt_vector``."""
        return np.array([r.device_opt_bytes for r in self.records],
                        dtype=np.float64)

    def total_activation_bytes(self) -> int:
        return int(sum(r.activation_bytes for r in self.records))


def unit_residual_bytes(unit: PlanUnit, x_struct,
                        mesh_budget: Optional[MeshBudget] = None
                        ) -> Dict[str, int]:
    """Exact residual footprint of one block, computed abstractly.

    ``jax.vjp(f, x)[1]`` is a pytree whose array leaves are precisely the
    tensors AD keeps live between forward and backward.  Weights appear in
    that closure too but are resident anyway, so they are subtracted.

    With a ``mesh_budget`` the per-device footprint is also computed:
    closure leaves matching a parameter's (shape, dtype) are excluded
    (they are counted in the fixed per-device bytes instead) and each
    remaining activation leaf is divided by its sharding divisor.

    Offloadable bytes (what an OFFLOAD action can stream to pinned host
    memory) are the non-param residual leaves with >= 2 dimensions —
    scalars and 1-d leaves are not worth a DMA descriptor and stay on
    device — clamped to never exceed the activation bytes.
    """
    def capture(p, x):
        out, vjp_fn = jax.vjp(lambda xx: unit.apply(p, xx), x)
        return out, vjp_fn

    out_struct, vjp_struct = jax.eval_shape(capture, unit.params, x_struct)
    resid = _tree_bytes(vjp_struct)
    params = _tree_bytes(unit.params)
    info = {
        "activation_bytes": max(0, resid - params),
        "output_bytes": _tree_bytes(out_struct),
        "param_bytes": params,
    }

    B = int(x_struct.shape[0])
    d_model = int(x_struct.shape[-1])

    def divisor(shape) -> float:
        if mesh_budget is None:
            return 1.0
        return mesh_budget.activation_divisor(shape, batch=B,
                                              d_model=d_model)

    # params appear in the closure at their own (sharded) residency; match
    # them out by (shape, dtype) multiset so only activations are counted
    param_sig = collections.Counter(
        (tuple(l.shape), str(jnp.dtype(l.dtype)))
        for l in jax.tree_util.tree_leaves(unit.params)
        if hasattr(l, "shape"))
    dev = offl = dev_offl = 0.0
    for leaf in jax.tree_util.tree_leaves(vjp_struct):
        if not hasattr(leaf, "shape"):
            continue
        key = (tuple(leaf.shape), str(jnp.dtype(leaf.dtype)))
        if param_sig.get(key, 0) > 0:
            param_sig[key] -= 1
            continue
        nbytes = int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
        dev += nbytes / divisor(leaf.shape)
        if len(leaf.shape) >= 2:
            offl += nbytes
            dev_offl += nbytes / divisor(leaf.shape)
    # global activation bytes keep the seed's aggregate formula (resid -
    # params) so existing byte accounting is bit-identical; per-device
    # bytes come from the leaf-wise walk as before
    info["device_activation_bytes"] = (info["activation_bytes"]
                                       if mesh_budget is None else int(dev))
    info["offloadable_bytes"] = int(min(offl, info["activation_bytes"]))
    info["device_offloadable_bytes"] = int(
        min(dev_offl, info["device_activation_bytes"]))
    info["device_output_bytes"] = int(sum(
        int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
        / divisor(l.shape)
        for l in jax.tree_util.tree_leaves(out_struct)
        if hasattr(l, "shape")))
    return info


def input_size_of(batch) -> int:
    """Paper §3.1: input size = number of elements in the input tensor."""
    t = batch["tokens"]
    size = int(np.prod(t.shape))
    if "frames" in batch:
        size += int(np.prod(batch["frames"].shape[:2]))
    if "vision_embeds" in batch:
        size += int(np.prod(batch["vision_embeds"].shape[:2]))
    return size


def _tree_struct_sig(tree) -> tuple:
    """Hashable (treedef, leaf shapes/dtypes) signature of a pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return (treedef,
            tuple((tuple(l.shape), str(jnp.dtype(l.dtype))) for l in leaves))


class ShuttlingCollector:
    """Collects per-unit activation bytes for the live batch geometry.

    Plan units are deduplicated by (behavioural signature, param-shape
    signature, input-struct signature): a 24-layer homogeneous model needs
    ONE ``eval_shape`` trace per *unique* block, not 24 — sheltered
    execution becomes O(#unique units).  The trace cache persists across
    calls (keys embed the input geometry, so a new input size only
    re-traces the unique units).  ``dedup=False`` restores the seed's
    per-unit behaviour; the dedup path is byte-for-byte identical because
    ``eval_shape`` depends only on shapes, never on parameter values.
    """

    def __init__(self, lm: LM, measure_time: bool = False,
                 dedup: bool = True,
                 mesh_budget: Optional[MeshBudget] = None):
        self.lm = lm
        self.measure_time = measure_time
        self.dedup = dedup
        # sharding-aware mode: also record per-device bytes under this
        # budget's divisors.  Part of the trace-cache key so a collector
        # is safe to rebuild with a different mesh shape.
        self.mesh_budget = mesh_budget
        self._mesh_sig = mesh_budget.sig() if mesh_budget is not None else None
        self._trace_cache: Dict[tuple, dict] = {}
        self.stats = {"traces": 0, "dedup_hits": 0, "collections": 0}

    def collect(self, params, batch) -> CollectionResult:
        t0 = time.perf_counter()
        units = self.lm.plan_units(params, batch)
        # analytic recompute cost per unit (pure python math, ~us): rides
        # along with the byte records so schedulers can score bytes
        # freed per recompute-FLOP without re-deriving geometry
        unit_flops = plan_unit_flops(self.lm, batch)
        x_struct = self._residual_stream_struct(params, batch)
        records: List[UnitRecord] = []
        traced = hits = 0
        for u in units:
            if u.name.startswith("enc"):
                xs = self._encoder_stream_struct(batch)
            else:
                xs = x_struct
            key = None
            info = None
            if self.dedup and u.signature is not None:
                key = (u.signature, _tree_struct_sig(u.params),
                       tuple(xs.shape), str(xs.dtype), self._mesh_sig)
                info = self._trace_cache.get(key)
            if info is None:
                info = dict(unit_residual_bytes(u, xs, self.mesh_budget))
                if key is not None:
                    self._trace_cache[key] = info
                traced += 1
            else:
                hits += 1
            # wall-clock is NOT shape-determined: unlike the byte counts,
            # timings must be measured per unit, never replayed from the
            # trace cache (they feed the paper's Table 2 overhead data)
            t_fwd = self._time_unit(u, xs) if self.measure_time else 0.0
            # optimizer-moment bytes are param-shape math (no tracing):
            # scan chunks carry stacked leaves whose leading layer axis
            # needs the synthetic ``blocks`` path prefix
            scanned_u = u.name.startswith("chunk")
            opt_b = unit_moment_bytes(u.params, None, scanned=scanned_u)
            dev_opt_b = (unit_moment_bytes(u.params, self.mesh_budget,
                                           scanned=scanned_u)
                         if self.mesh_budget is not None else opt_b)
            rec = UnitRecord(u.name, u.index, info["activation_bytes"],
                             info["output_bytes"], info["param_bytes"],
                             t_fwd, info["device_activation_bytes"],
                             float(unit_flops[u.index]),
                             offloadable_bytes=info["offloadable_bytes"],
                             device_offloadable_bytes=info[
                                 "device_offloadable_bytes"],
                             device_output_bytes=info["device_output_bytes"],
                             opt_bytes=int(opt_b),
                             device_opt_bytes=int(dev_opt_b))
            records.append(rec)
        self.stats["traces"] += traced
        self.stats["dedup_hits"] += hits
        self.stats["collections"] += 1
        return CollectionResult(input_size_of(batch), records,
                                time.perf_counter() - t0,
                                traced_units=traced, dedup_hits=hits)

    # ------------------------------------------------------------------
    def _residual_stream_struct(self, params, batch):
        cfg = self.lm.cfg
        B, S = batch["tokens"].shape
        if cfg.family == "vlm" and cfg.vision_tokens:
            S = S + cfg.vision_tokens
        return jax.ShapeDtypeStruct((B, S, cfg.d_model), self.lm.dtype)

    def _encoder_stream_struct(self, batch):
        cfg = self.lm.cfg
        B, F = batch["frames"].shape[:2]
        return jax.ShapeDtypeStruct((B, F, cfg.d_model), self.lm.dtype)

    def _time_unit(self, u: PlanUnit, x_struct) -> float:
        x = jnp.zeros(x_struct.shape, x_struct.dtype)
        fn = jax.jit(u.apply)
        fn(u.params, x).block_until_ready()          # compile + warm
        t0 = time.perf_counter()
        fn(u.params, x).block_until_ready()
        return time.perf_counter() - t0
