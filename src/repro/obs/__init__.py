"""Unified observability layer: metrics, events and span traces.

One :class:`Telemetry` object bundles the three surfaces and is
threaded through trainer, planner, watchdog, transfer lane and serve
engine:

* ``telemetry.metrics`` — a :class:`~repro.obs.metrics.MetricsRegistry`
  that is **always live**: component ``stats`` mappings are
  :class:`~repro.obs.metrics.StatsView` facades over it, so counting
  costs the same whether telemetry is "on" or "off" and a snapshot is
  always available for reports (`to_prometheus()` / `to_json()`).
* ``telemetry.events`` — a structured JSONL
  :class:`~repro.obs.events.EventLog` (or a no-op
  :class:`~repro.obs.events.NullEventLog`).  Guard emission at call
  sites with ``telemetry.events_on`` so the disabled path never builds
  kwargs.
* ``telemetry.tracer`` — a Perfetto
  :class:`~repro.obs.tracing.SpanTracer` (or
  :class:`~repro.obs.tracing.NullTracer` whose ``span()`` returns a
  shared singleton — zero allocation when disabled).

``Telemetry.disabled()`` is the default everywhere: metrics only, no
events, no spans, no sinks — and is behavior-identical to the
pre-telemetry code (enforced by a bench gate).
"""
from __future__ import annotations

from typing import Optional

from .events import SCHEMA_VERSION, EventLog, NullEventLog, read_events
from .metrics import (Counter, Gauge, Histogram, LabelView,
                      MetricsRegistry, StatsView)
from .tracing import (NULL_SPAN, NullTracer, SpanTracer, TRACK_PLANNER,
                      TRACK_SERVE, TRACK_SOLVER, TRACK_STEP,
                      TRACK_TRANSFER)

__all__ = [
    "Telemetry", "build_telemetry",
    "MetricsRegistry", "StatsView", "LabelView",
    "Counter", "Gauge", "Histogram",
    "EventLog", "NullEventLog", "read_events", "SCHEMA_VERSION",
    "SpanTracer", "NullTracer", "NULL_SPAN",
    "TRACK_STEP", "TRACK_PLANNER", "TRACK_TRANSFER", "TRACK_SERVE",
    "TRACK_SOLVER",
]


class Telemetry:
    """Bundle of (metrics registry, event log, span tracer)."""

    __slots__ = ("metrics", "events", "tracer", "events_on", "trace_on",
                 "_paths")

    def __init__(self, metrics: Optional[MetricsRegistry] = None,
                 events=None, tracer=None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.events = events if events is not None else NullEventLog()
        self.tracer = tracer if tracer is not None else NullTracer()
        self.events_on = bool(getattr(self.events, "enabled", False))
        self.trace_on = bool(getattr(self.tracer, "enabled", False))

    @classmethod
    def disabled(cls) -> "Telemetry":
        """Metrics-only telemetry: no events, no spans, no sinks."""
        return cls()

    @classmethod
    def enabled(cls, events_path: Optional[str] = None,
                ring_capacity: int = 4096,
                trace_capacity: int = 200_000) -> "Telemetry":
        return cls(events=EventLog(capacity=ring_capacity,
                                   path=events_path),
                   tracer=SpanTracer(capacity=trace_capacity))

    def close(self) -> None:
        self.events.close()


def build_telemetry(metrics_path: Optional[str] = None,
                    events_path: Optional[str] = None,
                    trace_path: Optional[str] = None) -> Telemetry:
    """Construct Telemetry from launch-driver flags.

    Any non-None path turns its surface on; ``flush_telemetry`` writes
    the artifacts at exit.  All three None → fully disabled."""
    events = EventLog(path=events_path) if events_path else None
    tracer = SpanTracer() if trace_path else None
    tel = Telemetry(events=events, tracer=tracer)
    tel._paths = {"metrics": metrics_path, "events": events_path,  # type: ignore[attr-defined]
                  "trace": trace_path}
    return tel


def flush_telemetry(tel: Telemetry) -> dict:
    """Write driver-requested artifacts (metrics file by extension:
    ``.json`` → JSON snapshot, anything else → Prometheus text),
    flush the event sink and save the trace.  Returns
    ``{kind: path}`` for every artifact actually written."""
    paths = getattr(tel, "_paths", {})
    written = {}
    mp = paths.get("metrics")
    if mp:
        with open(mp, "w") as f:
            if mp.endswith(".json"):
                f.write(tel.metrics.to_json(indent=2))
            else:
                f.write(tel.metrics.to_prometheus())
        written["metrics"] = mp
    tp = paths.get("trace")
    if tp:
        tel.tracer.save(tp)
        written["trace"] = tp
    ep = paths.get("events")
    if ep:
        written["events"] = ep
    tel.events.close()
    return written
