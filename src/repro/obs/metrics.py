"""Metrics registry: counters, gauges and histograms with labels.

The registry is the single store for every runtime counter in the
system — trainer JIT-cache stats, planner decision counters, watchdog
OOM tallies, transfer-lane byte counts and serve-engine admission
outcomes all live here instead of in per-component ad-hoc dicts.

Design constraints:

* **Lock-free hot path.**  ``Counter.inc`` never takes a lock: each
  (labelset, thread) pair owns a private accumulator cell, so
  concurrent writers (the background solver daemon and the training
  thread) can bump the same metric without losing increments — dict
  item stores are atomic under the GIL and every cell has exactly one
  writer.  Locks are only taken when *creating* a metric (registry
  mutation) and when *snapshotting* (read side).
* **Dict-shaped compatibility.**  :class:`StatsView` exposes a set of
  registry metrics through the ``MutableMapping`` protocol so existing
  call sites (``planner.stats["cache_hits"] += 1``, ``dict(wd.stats)``)
  keep working unchanged while the storage is shared and exportable.
* **Export.**  ``snapshot()`` returns plain data; ``to_prometheus()``
  renders the text exposition format; ``to_json()`` a stable JSON doc.
"""
from __future__ import annotations

import bisect
import json
import threading
from collections.abc import Mapping, MutableMapping
from typing import Callable, Dict, Iterable, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "StatsView",
    "LabelView",
]

_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: dict) -> _LabelKey:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Metric:
    """Shared plumbing: name/help/kind plus per-(labelset, thread) cells."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        # labelset -> {thread_id -> cell}; each cell is written by
        # exactly one thread, so no lock is needed on the write path.
        self._cells: Dict[_LabelKey, dict] = {}

    def _per_thread(self, labels: dict) -> dict:
        key = _label_key(labels)
        per = self._cells.get(key)
        if per is None:
            # setdefault is atomic under the GIL: two racing threads
            # converge on one shared dict for this labelset.
            per = self._cells.setdefault(key, {})
        return per

    def labelsets(self) -> Iterable[_LabelKey]:
        return list(self._cells.keys())

    # -- merge support (single-threaded, used when re-binding a
    #    component's metrics into a shared registry) ------------------
    def _merge_from(self, other: "_Metric") -> None:
        for key, per in other._cells.items():
            dst = self._cells.setdefault(key, {})
            for tid, cell in per.items():
                if tid in dst:
                    dst[(tid, id(other))] = cell
                else:
                    dst[tid] = cell


class Counter(_Metric):
    """Monotonic (but resettable) float counter with optional labels."""

    kind = "counter"

    def inc(self, n: float = 1.0, **labels) -> None:
        per = self._per_thread(labels)
        tid = threading.get_ident()
        per[tid] = per.get(tid, 0.0) + n

    def set(self, v: float, **labels) -> None:
        """Absolute set (single-writer contexts, e.g. mirroring an LRU
        eviction count).  Collapses all cells for the labelset."""
        key = _label_key(labels)
        self._cells[key] = {threading.get_ident(): float(v)}

    def value(self, **labels) -> float:
        per = self._cells.get(_label_key(labels))
        return float(sum(per.values())) if per else 0.0

    def total(self) -> float:
        return float(sum(sum(per.values()) for per in self._cells.values()))

    def items(self) -> Dict[_LabelKey, float]:
        return {k: float(sum(per.values())) for k, per in self._cells.items()}


class Gauge(_Metric):
    """Last-written value per labelset (plus ``set_max`` for peaks)."""

    kind = "gauge"

    def set(self, v: float, **labels) -> None:
        self._cells[_label_key(labels)] = {0: float(v)}

    def set_max(self, v: float, **labels) -> None:
        cur = self.value(**labels)
        if v > cur:
            self.set(v, **labels)

    def value(self, **labels) -> float:
        per = self._cells.get(_label_key(labels))
        return float(sum(per.values())) if per else 0.0

    total = value

    def items(self) -> Dict[_LabelKey, float]:
        return {k: float(sum(per.values())) for k, per in self._cells.items()}

    def _merge_from(self, other: "_Metric") -> None:
        # gauges are last-writer-wins, not additive
        for key, per in other._cells.items():
            if key not in self._cells:
                self._cells[key] = per


DEFAULT_BOUNDS = (1e-5, 1e-4, 1e-3, 5e-3, 0.01, 0.05, 0.1, 0.5, 1.0,
                  5.0, 10.0, 60.0)


class Histogram(_Metric):
    """Fixed-bound histogram; observe() is lock-free like Counter.inc."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 bounds: Tuple[float, ...] = DEFAULT_BOUNDS):
        super().__init__(name, help)
        self.bounds = tuple(bounds)

    def observe(self, v: float, **labels) -> None:
        per = self._per_thread(labels)
        tid = threading.get_ident()
        cell = per.get(tid)
        if cell is None:
            cell = per[tid] = [[0] * (len(self.bounds) + 1), 0.0, 0]
        i = bisect.bisect_left(self.bounds, v)
        cell[0][i] += 1
        cell[1] += v
        cell[2] += 1

    def _agg(self, per: dict):
        counts = [0] * (len(self.bounds) + 1)
        total, n = 0.0, 0
        for cell in per.values():
            for i, c in enumerate(cell[0]):
                counts[i] += c
            total += cell[1]
            n += cell[2]
        return counts, total, n

    def value(self, **labels):
        per = self._cells.get(_label_key(labels))
        if not per:
            return {"counts": [0] * (len(self.bounds) + 1),
                    "sum": 0.0, "count": 0}
        counts, total, n = self._agg(per)
        return {"counts": counts, "sum": total, "count": n}

    def items(self):
        return {k: self.value(**dict(k)) for k in self._cells.keys()}

    def total(self) -> float:
        return float(sum(self._agg(per)[2] for per in self._cells.values()))


class MetricsRegistry:
    """Name-indexed directory of metric objects with export helpers."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, cls, name: str, help: str, **kw) -> _Metric:
        m = self._metrics.get(name)
        if m is not None:
            return m
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, **kw)
                self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  bounds: Tuple[float, ...] = DEFAULT_BOUNDS) -> Histogram:
        return self._get_or_create(Histogram, name, help, bounds=bounds)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def adopt(self, metric: _Metric) -> _Metric:
        """Register ``metric`` under its name; if a metric with that
        name already exists, merge values into the existing object and
        return it.  This is how two components that count the same
        thing (e.g. planner and watchdog ``oom_events``) converge on
        one shared counter when bound to one registry."""
        with self._lock:
            cur = self._metrics.get(metric.name)
            if cur is None:
                self._metrics[metric.name] = metric
                return metric
            if cur is metric:
                return cur
            cur._merge_from(metric)
            return cur

    # ----------------------------------------------------------- export
    def snapshot(self) -> dict:
        """Plain-data view: name -> {kind, help, total, values:[...]}."""
        out = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            values = [{"labels": dict(k), "value": v}
                      for k, v in sorted(m.items().items())]
            entry = {"kind": m.kind, "help": m.help, "values": values}
            if m.kind != "histogram":
                entry["total"] = m.total()
            out[name] = entry
        return out

    def to_json(self, indent: int = 0) -> str:
        return json.dumps(self.snapshot(), indent=indent or None,
                          sort_keys=True)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (v0.0.4)."""
        lines = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if m.kind == "histogram":
                for key in sorted(m.labelsets()):
                    val = m.value(**dict(key))
                    cum = 0
                    base = dict(key)
                    for bound, c in zip(m.bounds, val["counts"]):
                        cum += c
                        lbl = _fmt_labels({**base, "le": repr(bound)})
                        lines.append(f"{name}_bucket{lbl} {cum}")
                    cum += val["counts"][-1]
                    lbl = _fmt_labels({**base, "le": "+Inf"})
                    lines.append(f"{name}_bucket{lbl} {cum}")
                    lines.append(
                        f"{name}_sum{_fmt_labels(base)} {val['sum']:.9g}")
                    lines.append(
                        f"{name}_count{_fmt_labels(base)} {val['count']}")
                if not m.labelsets():
                    lines.append(f"{name}_sum 0")
                    lines.append(f"{name}_count 0")
                continue
            items = m.items()
            if not items:
                lines.append(f"{name} 0")
                continue
            for key, v in sorted(items.items()):
                lines.append(f"{name}{_fmt_labels(dict(key))} {v:.9g}")
        return "\n".join(lines) + "\n"


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_esc(v)}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _esc(v) -> str:
    return str(v).replace("\\", r"\\").replace('"', r"\"").replace(
        "\n", r"\n")


class LabelView(Mapping):
    """Live read-only mapping over one label dimension of a metric.

    ``LabelView(counter, "bucket")`` behaves like
    ``{128: 3, 256: 1}`` — keys are label values (int-parsed when
    possible), values are the summed counter for that label."""

    def __init__(self, metric: _Metric, label: str):
        self._metric = metric
        self._label = label

    def _materialize(self) -> dict:
        out = {}
        for key, v in self._metric.items().items():
            d = dict(key)
            if self._label not in d:
                continue
            raw = d[self._label]
            try:
                k = int(raw)
            except (TypeError, ValueError):
                k = raw
            out[k] = out.get(k, 0) + v
        return {k: _intify(v) for k, v in out.items()}

    def __getitem__(self, k):
        return self._materialize()[k]

    def __iter__(self):
        return iter(self._materialize())

    def __len__(self):
        return len(self._materialize())

    def __repr__(self):
        return repr(self._materialize())

    def __eq__(self, other):
        return self._materialize() == other

    def __ne__(self, other):
        return not self.__eq__(other)


def _intify(v: float):
    if isinstance(v, float) and v.is_integer():
        return int(v)
    return v


class StatsView(MutableMapping):
    """Dict-shaped facade over registry metrics.

    Maps legacy stats keys onto shared metric objects so existing call
    sites (``stats["cache_hits"] += 1``, ``dict(stats)``, ``stats.get``)
    keep working while the storage lives in a
    :class:`MetricsRegistry`.  Four key classes:

    * ``scalars``: key -> metric name; reads return the metric total
      (ints stay ints), writes set the absolute value.
    * ``labeled``: key -> (metric name, label) exposing a live
      :class:`LabelView` (e.g. ``oom_by_bucket``).
    * ``composite``: key -> zero-arg callable producing the value.
    * ``aux``: plain dict passthrough for irregular structures.
    """

    def __init__(self, registry: MetricsRegistry,
                 scalars: Dict[str, str],
                 labeled: Optional[Dict[str, Tuple[str, str]]] = None,
                 composite: Optional[Dict[str, Callable]] = None,
                 aux: Optional[dict] = None,
                 float_keys: Iterable[str] = ()):
        self._registry = registry
        self._scalars = dict(scalars)
        self._labeled = dict(labeled or {})
        self._composite = dict(composite or {})
        self._aux = aux if aux is not None else {}
        self._float_keys = set(float_keys) | {
            k for k in self._scalars if k.endswith("_s")}
        self._metrics: Dict[str, _Metric] = {}
        for key, name in self._scalars.items():
            self._metrics[key] = registry.counter(name)
        for key, (name, _lbl) in self._labeled.items():
            self._metrics[key] = registry.counter(name)

    # -- binding ------------------------------------------------------
    def attach(self, registry: MetricsRegistry) -> None:
        """Re-home every backing metric into ``registry`` (merging with
        same-named metrics already there) and keep serving reads/writes
        through the shared objects."""
        if registry is self._registry:
            return
        for key in list(self._metrics):
            self._metrics[key] = registry.adopt(self._metrics[key])
        self._registry = registry

    def metric(self, key: str) -> _Metric:
        return self._metrics[key]

    # -- MutableMapping -----------------------------------------------
    def __getitem__(self, key):
        if key in self._scalars:
            v = self._metrics[key].total()
            return v if key in self._float_keys else _intify(v)
        if key in self._labeled:
            return LabelView(self._metrics[key], self._labeled[key][1])
        if key in self._composite:
            return self._composite[key]()
        return self._aux[key]

    def __setitem__(self, key, value):
        if key in self._scalars:
            self._metrics[key].set(float(value))
        elif key in self._labeled or key in self._composite:
            raise TypeError(
                f"stats key {key!r} is registry-backed; bump the metric "
                "instead of assigning the view")
        else:
            self._aux[key] = value

    def __delitem__(self, key):
        if key in self._aux:
            del self._aux[key]
        else:
            raise TypeError(f"cannot delete registry-backed key {key!r}")

    def __iter__(self):
        seen = set()
        for src in (self._scalars, self._labeled, self._composite,
                    self._aux):
            for k in src:
                if k not in seen:
                    seen.add(k)
                    yield k

    def __len__(self):
        return sum(1 for _ in self)

    def __contains__(self, key):
        return (key in self._scalars or key in self._labeled
                or key in self._composite or key in self._aux)

    def __repr__(self):
        return repr({k: self[k] for k in self})

    # convenience: bump a scalar counter without read-modify-write
    def inc(self, key: str, n: float = 1.0, **labels) -> None:
        self._metrics[key].inc(n, **labels)
