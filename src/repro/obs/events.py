"""Structured event log: schema-versioned JSONL with a bounded ring.

Every discrete runtime *decision* is recorded here with provenance —
plan creation (bucket, source, predicted vs. actual peak bytes), solver
swaps, cache evictions and OOM poisonings, serve admissions/defers/
rejects, snapshot writes/restores, drift audits and refits.  The ring
buffer (``collections.deque(maxlen=...)``) keeps the newest events
in-memory for reports; an optional file sink streams every event to
JSONL for offline analysis with ``tools/trace_view.py``.

Schema: every record is one JSON object per line with at least
``{"v": SCHEMA_VERSION, "ts": <float seconds>, "kind": <str>}`` plus
kind-specific fields.  Unknown fields must be ignored by readers so the
schema can grow additively.
"""
from __future__ import annotations

import io
import json
import time
from collections import deque
from typing import Iterator, List, Optional

__all__ = ["SCHEMA_VERSION", "EventLog", "NullEventLog", "read_events"]

SCHEMA_VERSION = 1


class EventLog:
    """Bounded in-memory ring of events with an optional JSONL sink."""

    enabled = True

    def __init__(self, capacity: int = 4096, path: Optional[str] = None,
                 clock=time.time):
        self._ring: deque = deque(maxlen=int(capacity))
        self._clock = clock
        self._path = path
        self._sink: Optional[io.TextIOBase] = None
        if path:
            self._sink = open(path, "w", buffering=1 << 16)

    def emit(self, kind: str, **fields) -> dict:
        rec = {"v": SCHEMA_VERSION, "ts": self._clock(), "kind": kind}
        rec.update(fields)
        self._ring.append(rec)
        if self._sink is not None:
            self._sink.write(json.dumps(rec, default=_jsonable) + "\n")
        return rec

    def tail(self, n: Optional[int] = None,
             kind: Optional[str] = None) -> List[dict]:
        evs = list(self._ring)
        if kind is not None:
            evs = [e for e in evs if e.get("kind") == kind]
        return evs[-n:] if n else evs

    def flush(self) -> None:
        if self._sink is not None:
            self._sink.flush()

    def close(self) -> None:
        if self._sink is not None:
            self._sink.flush()
            self._sink.close()
            self._sink = None

    def __len__(self):
        return len(self._ring)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class NullEventLog:
    """Disabled event log: ``emit`` is a constant no-op."""

    enabled = False

    def emit(self, kind: str, **fields) -> None:  # pragma: no cover
        return None

    def tail(self, n=None, kind=None) -> List[dict]:
        return []

    def flush(self) -> None:
        return None

    def close(self) -> None:
        return None

    def __len__(self):
        return 0


def read_events(path: str, kind: Optional[str] = None) -> Iterator[dict]:
    """Stream events back from a JSONL file, skipping malformed lines
    (a truncated final line after a crash must not poison analysis)."""
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if kind is not None and rec.get("kind") != kind:
                continue
            yield rec


def _jsonable(o):
    """Fallback serializer: numpy scalars and arrays degrade to plain
    Python numbers/lists instead of crashing the sink."""
    if hasattr(o, "tolist"):          # arrays AND numpy scalars
        return o.tolist()
    if hasattr(o, "item"):
        return o.item()
    return str(o)
