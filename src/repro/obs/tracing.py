"""Span tracer emitting Chrome ``trace_event`` JSON for Perfetto.

Spans are complete events (``"ph": "X"``) with microsecond timestamps,
grouped into named tracks (Chrome "threads"): the train step loop,
the planner, the transfer lane and the serve scheduler each get their
own row in the Perfetto UI, so the transfer lane's measured
``exposed`` spans sit visually under the ``execute`` span they steal
time from.

The disabled path is a strict no-op: :class:`NullTracer.span` returns
one shared :data:`NULL_SPAN` singleton (no allocation per call) whose
``__enter__``/``__exit__`` do nothing.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import List, Optional

__all__ = ["SpanTracer", "NullTracer", "NULL_SPAN",
           "TRACK_STEP", "TRACK_PLANNER", "TRACK_TRANSFER", "TRACK_SERVE",
           "TRACK_SOLVER"]

# stable Chrome "thread ids" = Perfetto tracks
TRACK_STEP = 1
TRACK_PLANNER = 2
TRACK_TRANSFER = 3
TRACK_SERVE = 4
TRACK_SOLVER = 5

_TRACK_NAMES = {
    TRACK_STEP: "train.step",
    TRACK_PLANNER: "planner",
    TRACK_TRANSFER: "transfer",
    TRACK_SERVE: "serve",
    TRACK_SOLVER: "solver",
}


class _Span:
    """Context manager recording one complete event on exit."""

    __slots__ = ("_tracer", "name", "track", "args", "_t0")

    def __init__(self, tracer: "SpanTracer", name: str, track: int,
                 args: Optional[dict]):
        self._tracer = tracer
        self.name = name
        self.track = track
        self.args = args
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._tracer.complete(self.name, self._t0,
                              time.perf_counter() - self._t0,
                              track=self.track, args=self.args)
        return False


class _NullSpan:
    """Shared do-nothing span for the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class SpanTracer:
    """Collects Chrome ``trace_event`` complete events in memory.

    ``span()`` measures with ``time.perf_counter``; ``complete()``
    accepts explicit (start, duration) pairs so retroactive spans
    (serve queue-wait, virtual-clock engines) land on the same tracks.
    Appends to the event list are GIL-atomic, so the transfer-lane
    worker thread and the train thread can trace concurrently.
    """

    enabled = True

    def __init__(self, capacity: int = 200_000):
        self._events: List[dict] = []
        self._capacity = int(capacity)
        self._pid = os.getpid()
        self._meta_emitted = set()
        self._lock = threading.Lock()

    def span(self, name: str, track: int = TRACK_STEP,
             args: Optional[dict] = None) -> _Span:
        return _Span(self, name, track, args)

    def complete(self, name: str, start_s: float, dur_s: float,
                 track: int = TRACK_STEP,
                 args: Optional[dict] = None) -> None:
        if len(self._events) >= self._capacity:
            return
        self._ensure_track(track)
        ev = {"ph": "X", "name": name, "pid": self._pid, "tid": track,
              "ts": start_s * 1e6, "dur": max(dur_s, 0.0) * 1e6}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def instant(self, name: str, track: int = TRACK_STEP,
                args: Optional[dict] = None,
                ts_s: Optional[float] = None) -> None:
        """Zero-duration marker (plan swaps, OOM events, refits)."""
        if len(self._events) >= self._capacity:
            return
        self._ensure_track(track)
        ev = {"ph": "i", "s": "t", "name": name, "pid": self._pid,
              "tid": track,
              "ts": (time.perf_counter() if ts_s is None else ts_s) * 1e6}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def _ensure_track(self, track: int) -> None:
        if track in self._meta_emitted:
            return
        with self._lock:
            if track in self._meta_emitted:
                return
            self._events.append({
                "ph": "M", "name": "thread_name", "pid": self._pid,
                "tid": track,
                "args": {"name": _TRACK_NAMES.get(track, f"track{track}")},
            })
            self._meta_emitted.add(track)

    def events(self) -> List[dict]:
        return list(self._events)

    def to_json(self) -> str:
        return json.dumps({"traceEvents": self.events(),
                           "displayTimeUnit": "ms"})

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    def __len__(self):
        return len(self._events)


class NullTracer:
    """Disabled tracer: every span is the shared :data:`NULL_SPAN`."""

    enabled = False

    def span(self, name: str, track: int = TRACK_STEP,
             args: Optional[dict] = None) -> _NullSpan:
        return NULL_SPAN

    def complete(self, name, start_s, dur_s, track=TRACK_STEP,
                 args=None) -> None:
        return None

    def instant(self, name, track=TRACK_STEP, args=None,
                ts_s=None) -> None:
        return None

    def events(self) -> List[dict]:
        return []

    def to_json(self) -> str:
        return json.dumps({"traceEvents": []})

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    def __len__(self):
        return 0
