"""AdamW with cosine/linear schedules and global-norm clipping (pure JAX)."""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jax.tree_util.tree_map(
            lambda a: jnp.zeros(a.shape, jnp.float32), p)
        return AdamWState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.clip_norm:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree_util.tree_leaves(grads)))
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

        m = jax.tree_util.tree_map(
            lambda mu, g: self.b1 * mu + (1 - self.b1) * g.astype(jnp.float32),
            state.m, grads)
        v = jax.tree_util.tree_map(
            lambda nu, g: self.b2 * nu
            + (1 - self.b2) * jnp.square(g.astype(jnp.float32)),
            state.v, grads)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, mu, nu):
            u = (mu / bc1) / (jnp.sqrt(nu / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, m, v)
        return new_params, AdamWState(step, m, v)


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return lr
